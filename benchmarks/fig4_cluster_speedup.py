"""Fig.4 reproduction: matrix-matrix multiplication parallelized over
clusters (1/2/4/6/8), bus vs NoC interconnect.

Two measurements:
  1. real multi-(virtual-)device run: shard_map row-tiled matmul over a
     'cluster' mesh axis, wall-clock per iteration (run in a subprocess with
     8 host devices so the rest of the suite keeps seeing 1 device);
  2. the analytic interconnect model (core/cluster.py) reproducing the
     paper's observation: ideal speedup at 2/4/6 clusters, ~2% below ideal
     at 8 on the bus, recovered by the NoC.

Also reports the §1 nominal-GIPS throughput scaling (--throughput).
"""
from __future__ import annotations

import json
import subprocess
import sys
import os

from repro.core.cluster import ClusterConfig, interconnect_model

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

M = N = K = 1024
a = jnp.asarray(np.random.default_rng(0).standard_normal((M, K), np.float32))
b = jnp.asarray(np.random.default_rng(1).standard_normal((K, N), np.float32))
out = {}
for n in [1, 2, 4, 8]:
    mesh = Mesh(np.array(jax.devices()[:n]), ("cluster",))
    f = jax.jit(shard_map(lambda at, bt: at @ bt, mesh=mesh,
                          in_specs=(P("cluster", None), P(None, None)),
                          out_specs=P("cluster", None)))
    r = f(a, b); r.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        r = f(a, b)
    r.block_until_ready()
    out[n] = (time.perf_counter() - t0) / 10
print(json.dumps(out))
"""


def measured_speedups():
    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    times = json.loads(r.stdout.strip().splitlines()[-1])
    base = times["1"]
    return {int(k): base / v for k, v in times.items()}


def modeled_speedups():
    rows = []
    # per-cluster work for an n-cluster row-tiled 2048^3 matmul
    total_compute_s = 1.0
    total_bytes = 512 * 2 ** 20
    for ic in ("bus", "noc"):
        for n in (1, 2, 4, 6, 8):
            cfg = ClusterConfig(n_clusters=n, interconnect=ic)
            m = interconnect_model(cfg, total_bytes // max(n, 1),
                                   total_compute_s / max(n, 1))
            rows.append(m)
    return rows


def main(throughput: bool = False):
    print("# Fig.4: cluster-parallel matmul speedup")
    print("## analytic interconnect model (bus vs NoC)")
    print("interconnect,n_clusters,speedup,ideal,efficiency")
    for m in modeled_speedups():
        print(f"{m['interconnect']},{m['n_clusters']},{m['speedup']:.3f},"
              f"{m['ideal']},{m['efficiency']:.4f}")
    print("## measured (8 virtual devices, shard_map row tiling)")
    try:
        sp = measured_speedups()
        for n, s in sorted(sp.items()):
            print(f"measured,{n},{s:.3f}")
    except Exception as e:  # single-core container: contention expected
        print(f"measured,unavailable,{e}")
    if throughput:
        print("## nominal GIPS (paper §1: 64 PEs @ >30 MHz -> >1.9 GIPS)")
        for n in (1, 2, 4, 8):
            cfg = ClusterConfig(n_clusters=n)
            print(f"gips,{cfg.total_pes},{cfg.nominal_gips():.2f}")


if __name__ == "__main__":
    main(throughput="--throughput" in sys.argv)
