"""Fig.5 reproduction: offload + kernel time, copy-based SM vs zero-copy SVM.

Four benchmarks with the paper's cost structure:
  (a) PageRank        — pointer-rich linked graph; copy mode pays pointer
                        flattening (adjacency dict -> CSR) on every offload;
  (b) Random Hough Forests — large tree ensemble, only a fraction touched;
                        copy mode ships the entire forest;
  (c) MemCopy         — streaming; copy mode's staging dominates;
  (d) MatMul          — compute amortizes the copy cost partially.

Paper's reductions: (a) ~60%, (b) >60%, (c) >95%, (d) ~80%.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import OffloadTarget
from repro.core.tracing import TraceBuffer


def _graph(n=4096, deg=8, seed=0):
    """Adjacency dict: the pointer-rich host structure."""
    rng = np.random.default_rng(seed)
    return {v: rng.integers(0, n, deg).tolist() for v in range(n)}


def _graph_to_csr(g: Dict[int, List[int]]):
    """The pointer-flattening step copy-based offload must do every time."""
    indptr = np.zeros(len(g) + 1, np.int32)
    flat = []
    for v in range(len(g)):
        flat.extend(g[v])
        indptr[v + 1] = len(flat)
    return indptr, np.asarray(flat, np.int32)


def pagerank_kernel(indptr, indices, rank):
    n = rank.shape[0]
    deg = (indptr[1:] - indptr[:-1]).astype(jnp.float32)
    contrib = rank / jnp.maximum(deg, 1.0)

    def body(r, _):
        inc = jnp.zeros(n, jnp.float32).at[indices].add(
            jnp.repeat(contrib, deg.astype(jnp.int32), total_repeat_length=indices.shape[0]))
        return 0.85 * inc + 0.15 / n, None

    out, _ = jax.lax.scan(body, rank, None, length=10)
    return out


def forest_kernel(feat_idx, thresh, children, x):
    """Classify batch x through depth-8 trees via gathers (partial access)."""
    node = jnp.zeros((x.shape[0], feat_idx.shape[0]), jnp.int32)
    for _ in range(8):
        _f = feat_idx[jnp.arange(feat_idx.shape[0])[None, :], node]
        t = thresh[jnp.arange(feat_idx.shape[0])[None, :], node]
        go_right = x[:, 0][:, None] > t
        node = children[jnp.arange(feat_idx.shape[0])[None, :], node,
                        go_right.astype(jnp.int32)]
    return node.sum(axis=1)


def run_benchmarks(repeats: int = 3):
    tgt = OffloadTarget(tracer=TraceBuffer())
    rows = []

    def bench(name, copy_fn, zero_setup, zero_fn):
        copy_total, zc_total, copy_off, zc_kern = [], [], [], []
        handles = zero_setup()
        for _ in range(repeats):
            out_c, rep_c = copy_fn()
            out_h, rep_z = zero_fn(handles)
            copy_total.append(rep_c.total_s)
            zc_total.append(rep_z.total_s)
            copy_off.append(rep_c.offload_s + rep_c.writeback_s)
            zc_kern.append(rep_z.kernel_s)
        c, z = float(np.median(copy_total)), float(np.median(zc_total))
        rows.append({
            "bench": name, "copy_total_s": c, "svm_total_s": z,
            "copy_offload_s": float(np.median(copy_off)),
            "svm_kernel_s": float(np.median(zc_kern)),
            "reduction_pct": 100.0 * (1 - z / c),
        })

    # (a) PageRank — linked data structure
    g = _graph()
    n = len(g)

    def pr_copy():
        indptr, indices = _graph_to_csr(g)            # pointer fixing
        rank = np.full(n, 1.0 / n, np.float32)
        return tgt.run_copy_based(pagerank_kernel, indptr, indices, rank)

    def pr_setup():
        indptr, indices = _graph_to_csr(g)
        return [tgt.svm.share(jax.device_put(indptr)),
                tgt.svm.share(jax.device_put(indices)),
                tgt.svm.share(jax.device_put(np.full(n, 1.0 / n, np.float32)))]

    bench("pagerank", pr_copy, pr_setup,
          lambda hs: tgt.run_zero_copy(pagerank_kernel, *hs))

    # (b) Random Hough Forests — big, partially-accessed
    rng = np.random.default_rng(1)
    n_trees, n_nodes = 64, 2048
    feat = rng.integers(0, 16, (n_trees, n_nodes)).astype(np.int32)
    thr = rng.standard_normal((n_trees, n_nodes)).astype(np.float32)
    child = rng.integers(0, n_nodes, (n_trees, n_nodes, 2)).astype(np.int32)
    xq = rng.standard_normal((256, 16)).astype(np.float32)

    bench("hough_forest",
          lambda: tgt.run_copy_based(forest_kernel, feat, thr, child, xq),
          lambda: [tgt.svm.share(jax.device_put(a))
                   for a in (feat, thr, child, xq)],
          lambda hs: tgt.run_zero_copy(forest_kernel, *hs))

    # (c) MemCopy — streaming
    big = rng.standard_normal((1 << 22,)).astype(np.float32)  # 16 MiB
    def ident(x):
        return x + 0.0
    bench("memcopy",
          lambda: tgt.run_copy_based(ident, big),
          lambda: [tgt.svm.share(jax.device_put(big))],
          lambda hs: tgt.run_zero_copy(ident, *hs))

    # (d) MatMul
    A = rng.standard_normal((768, 768)).astype(np.float32)
    B = rng.standard_normal((768, 768)).astype(np.float32)
    def mm(a, b):
        return a @ b
    bench("matmul",
          lambda: tgt.run_copy_based(mm, A, B),
          lambda: [tgt.svm.share(jax.device_put(A)),
                   tgt.svm.share(jax.device_put(B))],
          lambda hs: tgt.run_zero_copy(mm, *hs))
    return rows


def main():
    print("# Fig.5: copy-based SM vs zero-copy SVM offload")
    print("bench,copy_total_s,svm_total_s,copy_offload_s,svm_kernel_s,"
          "reduction_pct,paper_claim_pct")
    claims = {"pagerank": "~60", "hough_forest": ">60", "memcopy": ">95",
              "matmul": "~80"}
    for r in run_benchmarks():
        print(f"{r['bench']},{r['copy_total_s']:.5f},{r['svm_total_s']:.5f},"
              f"{r['copy_offload_s']:.5f},{r['svm_kernel_s']:.5f},"
              f"{r['reduction_pct']:.1f},{claims[r['bench']]}")
    print("# NOTE: memcopy under-reproduces the paper's >95% because on "
          "CPU-JAX the kernel's copy bandwidth equals the host staging "
          "bandwidth; in the HESoC the host's *uncached* staging path is "
          "~20x slower than the PMCA DMA. Normalizing the kernel to DMA "
          "bandwidth recovers the paper's ratio (EXPERIMENTS.md Fig.5).")


if __name__ == "__main__":
    main()
