"""Fig.6 reproduction: three RAB programs traced and analyzed.

 (a) L1-hit load:   translation completes in a single cycle;
 (b) hit-under-miss: core B's L1 hit completes while core A's L2 search is
                     outstanding (verified by a definable assertion);
 (c) full miss:      core sleeps, handler walks the table, configures an
                     entry, wakes the core.

Events come from the same tracer the serving engine uses; the analyzer's
three layers decode them into the Fig.6-style per-core timeline.
"""
from __future__ import annotations

from repro.core.rab import RAB, RABConfig
from repro.core.tracing import TraceBuffer
from repro.core.analysis import (
    Assertion, assert_hit_under_miss, assert_wake_follows_handle,
    layer1_decode, layer2_tlb_transactions, layer3_run, render_timeline,
)


def main():
    tracer = TraceBuffer()
    rab = RAB(RABConfig(l1_entries=2, l2_entries=8, l2_assoc=4, l2_banks=2),
              tracer)
    page_table = {v: 100 + v for v in range(32)}

    # program (a): L1 hit
    rab.lookup(3, requester=0)
    rab.handle_misses(page_table)       # warm
    rab.lookup(3, requester=0)          # single-cycle L1 hit

    # program (b): hit-under-miss — core 1 misses L1 (L2 search), core 2's
    # L1 hit completes independently
    rab.lookup(7, requester=1)
    rab.handle_misses(page_table)
    rab.lookup(8, requester=1)          # evicts, 7 -> L2
    rab.handle_misses(page_table)
    rab.lookup(9, requester=1)
    rab.handle_misses(page_table)
    rab.lookup(7, requester=1)          # L2 hit (multi-cycle search)
    rab.lookup(3, requester=2)          # interleaved L1 hit

    # program (c): full miss -> sleep -> handler walk -> wake -> retry
    rab.lookup(20, requester=4)
    rab.handle_misses(page_table)
    rab.lookup(20, requester=4)

    events = layer1_decode(tracer.drain())
    print("# Fig.6: per-core RAB event timeline")
    print(render_timeline(events))
    print("\n# layer-2 TLB transactions")
    for tx in layer2_tlb_transactions(events):
        print(tx)
    print("\n# layer-3 assertions")
    results = layer3_run(events, [
        Assertion("hit_under_miss", assert_hit_under_miss,
                  "hits complete while another core's miss is outstanding"),
        Assertion("wake_follows_handle", assert_wake_follows_handle,
                  "cores only wake after their miss was handled"),
    ])
    for name, ok in results.items():
        print(f"{name}: {'PASS' if ok else 'FAIL'}")
    print("\n# RAB stats:", rab.stats)
    assert all(results.values())


if __name__ == "__main__":
    main()
