"""Seeded open-loop load generator for the serving front door.

Builds a timed arrival schedule — Poisson arrivals (seeded exponential
interarrival gaps) with uniform prompt/output length distributions —
and replays it through :class:`repro.runtime.FrontDoor` on a
:class:`repro.runtime.VirtualClock`.  Every engine iteration costs a
fixed ``iter_time_s`` of virtual time, so the latency report
(p50/p95/p99 TTFT and TPOT, SLO goodput) is a pure function of
(seed, workload knobs, engine config): two same-seed runs must be
byte-identical, and ``--selfcheck`` asserts exactly that by running the
workload twice on fresh engines and comparing the serialized JSON.

Requests are capped by ``max_new`` only (no stop tokens), so output
lengths — and with them every virtual-time metric — depend on the
schedule, not on model numerics.  This is the load side of HERO's
split: the host driver owns arrival, admission and deadline policy
while the accelerator engine only ever sees per-iteration work.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.planner.workload import WorkloadSpec
from repro.runtime import (
    Arrival, CacheConfig, EngineConfig, FrontDoor, GenerationRequest,
    SamplingParams,
    TokenBudgetPolicy, VirtualClock, latency_report, make_engine,
)


def arrivals_from_spec(spec: WorkloadSpec, vocab: int):
    """Sample ``spec``'s schedule and wrap it for the front door: one
    :class:`Arrival` per :class:`repro.planner.SampledRequest`.  This is
    the bridge between the shared workload schema and the engine — the
    planner's simulator consumes the *same* sampled schedule, so a
    prediction and this generator's measurement describe identical
    traffic."""
    return [Arrival(t=r.t, request=GenerationRequest(
                rid=r.rid, prompt=r.prompt,
                sampling=SamplingParams(max_new=r.max_new)))
            for r in spec.sample_arrivals(vocab)]


def make_arrivals(*, rate_rps: float, requests: int, prompt_min: int,
                  prompt_max: int, output_min: int, output_max: int,
                  vocab: int, seed: int = 0):
    """Seeded arrival schedule: Poisson arrivals at ``rate_rps``, prompt
    lengths uniform in [prompt_min, prompt_max], output budgets uniform
    in [output_min, output_max].  Deterministic for a given seed.

    Delegates to :class:`repro.planner.WorkloadSpec` — the draw order is
    that class's contract now, and historical seeds produce bit-identical
    schedules."""
    spec = WorkloadSpec(
        rate_rps=rate_rps, requests=requests, prompt_min=prompt_min,
        prompt_max=prompt_max, output_min=output_min,
        output_max=output_max, seed=seed)
    return arrivals_from_spec(spec, vocab)


def run_load(cfg, params, arrivals, *, page_size: int, max_lanes: int,
             chunk: int, token_budget: int, iter_time_s: float,
             slo_ttft_s: float, slo_tpot_s: float,
             use_kernel: bool = False) -> dict:
    """One fresh engine + virtual clock + front door over ``arrivals``;
    returns the :func:`latency_report` summary."""
    longest = max(len(a.request.prompt) + a.request.sampling.max_new
                  for a in arrivals)
    per_seq = -(-longest // page_size) + 1
    engine_cfg = EngineConfig(
        cache=CacheConfig(num_pages=per_seq * max_lanes + 8,
                          page_size=page_size,
                          max_pages_per_seq=per_seq),
        max_lanes=max_lanes, chunk=chunk,
        use_kernel=use_kernel, clock=VirtualClock(),
        scheduler_policy=TokenBudgetPolicy(token_budget))
    engine = make_engine(cfg, params, engine_cfg)
    door = FrontDoor(engine, iter_time_s=iter_time_s)
    records = door.serve(arrivals)
    rep = latency_report(records, slo_ttft_s=slo_ttft_s,
                         slo_tpot_s=slo_tpot_s)
    rep["iterations"] = engine.iterations
    rep["virtual_duration_s"] = round(engine.clock.now(), 9)
    return rep


def run_load_gen(*, arch: str = "yi-6b", rate_rps: float = 50.0,
                 requests: int = 16, prompt_min: int = 8,
                 prompt_max: int = 24, output_min: int = 2,
                 output_max: int = 8, seed: int = 0,
                 prefix_share_ratio: float = 0.0, page_size: int = 4,
                 max_lanes: int = 4, chunk: int = 8,
                 token_budget: int = 12, iter_time_s: float = 0.01,
                 slo_ttft_s: float = 0.25, slo_tpot_s: float = 0.05,
                 use_kernel: bool = False, cfg=None, params=None,
                 spec: WorkloadSpec = None) -> dict:
    """Full load-gen run: schedule + fresh engine + report.  ``cfg`` /
    ``params`` may be passed in to reuse an already-initialised model
    (the engine itself is always built fresh).  Pass ``spec`` to drive
    the generator from an existing :class:`WorkloadSpec` (e.g. one
    deserialized from ``--workload``); the individual knobs are ignored
    then.  The spec rides along in the report under
    ``workload["spec"]``, so a report is always replayable."""
    if cfg is None:
        cfg = get_config(arch).smoke()
    if params is None:
        params = M.init_params(cfg, jax.random.PRNGKey(0))
    if spec is None:
        spec = WorkloadSpec(
            rate_rps=rate_rps, requests=requests, prompt_min=prompt_min,
            prompt_max=prompt_max, output_min=output_min,
            output_max=output_max, seed=seed,
            prefix_share_ratio=prefix_share_ratio)
    arrivals = arrivals_from_spec(spec, cfg.vocab_size)
    rep = run_load(cfg, params, arrivals, page_size=page_size,
                   max_lanes=max_lanes, chunk=chunk,
                   token_budget=token_budget, iter_time_s=iter_time_s,
                   slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s,
                   use_kernel=use_kernel)
    return {
        "workload": {
            "arch": cfg.name, "rate_rps": spec.rate_rps,
            "requests": spec.requests,
            "prompt_len": [spec.prompt_min, spec.prompt_max],
            "output_len": [spec.output_min, spec.output_max],
            "seed": spec.seed,
            "page_size": page_size, "max_lanes": max_lanes,
            "chunk": chunk, "token_budget": token_budget,
            "iter_time_s": iter_time_s,
            "spec": spec.to_json(),
        },
        **rep,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="mean arrival rate, requests per virtual second")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=24)
    ap.add_argument("--output-min", type=int, default=2)
    ap.add_argument("--output-max", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="fraction of requests whose prompt starts with "
                         "one shared prompt_min-token block")
    ap.add_argument("--workload", default=None,
                    help="read the WorkloadSpec from this JSON file "
                         "(overrides the individual workload knobs)")
    ap.add_argument("--workload-out", default=None,
                    help="serialize the WorkloadSpec to this JSON file "
                         "(round-trips through --workload)")
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--max-lanes", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--token-budget", type=int, default=12,
                    help="TokenBudgetPolicy total tokens per iteration")
    ap.add_argument("--iter-time", type=float, default=0.01,
                    help="virtual seconds charged per engine iteration")
    ap.add_argument("--slo-ttft", type=float, default=0.25,
                    help="TTFT service-level objective, virtual seconds")
    ap.add_argument("--slo-tpot", type=float, default=0.05,
                    help="TPOT service-level objective, virtual seconds")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny workload, seconds on CPU")
    ap.add_argument("--selfcheck", action="store_true",
                    help="run the workload twice on fresh engines and "
                         "assert the serialized reports are byte-identical")
    ap.add_argument("--out", default=None,
                    help="write the report JSON here (default: stdout only)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 8)
        args.prompt_min, args.prompt_max = 4, 12
        args.output_min, args.output_max = 2, 5
        args.max_lanes, args.chunk, args.token_budget = 2, 4, 6

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = None
    if args.workload:
        with open(args.workload) as f:
            spec = WorkloadSpec.from_json(json.load(f))
    knobs = dict(
        rate_rps=args.rate, requests=args.requests,
        prompt_min=args.prompt_min, prompt_max=args.prompt_max,
        output_min=args.output_min, output_max=args.output_max,
        seed=args.seed, prefix_share_ratio=args.prefix_share,
        page_size=args.page_size,
        max_lanes=args.max_lanes, chunk=args.chunk,
        token_budget=args.token_budget, iter_time_s=args.iter_time,
        slo_ttft_s=args.slo_ttft, slo_tpot_s=args.slo_tpot,
        cfg=cfg, params=params, spec=spec)
    if args.workload_out:
        dump = spec if spec is not None else WorkloadSpec(
            rate_rps=args.rate, requests=args.requests,
            prompt_min=args.prompt_min, prompt_max=args.prompt_max,
            output_min=args.output_min, output_max=args.output_max,
            seed=args.seed, prefix_share_ratio=args.prefix_share)
        with open(args.workload_out, "w") as f:
            json.dump(dump.to_json(), f, indent=2)

    result = run_load_gen(**knobs)
    if args.selfcheck:
        replay = run_load_gen(**knobs)
        a = json.dumps(result, sort_keys=True)
        b = json.dumps(replay, sort_keys=True)
        assert a == b, "same-seed load-gen runs diverged:\n" \
            f"  first : {a}\n  replay: {b}"
        result["replay_identical"] = True
        print("selfcheck: two same-seed runs byte-identical", file=sys.stderr)

    print(json.dumps(result, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    main()
