"""Planner accuracy gate: predict the bench, then compare to the bench.

Replays the workloads ``BENCH_serve.json`` already measures — ``latency``,
``speculation``, ``quantized_kv``, ``hierarchical_cache`` and
``cluster_sweep`` — through the capacity planner's discrete-event
simulator (:mod:`repro.planner`), prices iterations exactly as each
bench run did, and writes a ``planner_accuracy`` section back into the
bench JSON: per-workload predicted vs measured metrics with relative
errors.  ``scripts/check_bench.py`` gates the section, so a scheduler
change that silently breaks the planner's engine replica fails CI the
same way a perf regression does.

Workload knobs are read from the bench's own recorded ``workload``
blocks (so smoke and full runs both replay faithfully); prompt streams
come from the *same* builders ``serve_throughput.py`` used, imported —
not copied — so the two can't drift apart.

Model limits, documented here and visible in the emitted section as
``gated: false`` metrics:

* ``speculation.spec_on`` — the simulator models acceptance as a
  deterministic per-lane rate, but the real n-gram drafter has a
  warm-up (it proposes nothing until the pattern recurs) and
  position-correlated acceptance, so predicted iterations undershoot.
  The spec-off arm is exact and stays gated.
* ``hierarchical_cache.tiered.demoted_pages`` IS gated but not exact:
  the simulator's cached-free LRU evicts in key order where the engine's
  eviction interleaves with in-flight promotion bookkeeping, costing a
  page or two of demotion traffic (~2% here, well under the ceiling).

    PYTHONPATH=src python benchmarks/plan_accuracy.py            # updates
    PYTHONPATH=src python benchmarks/plan_accuracy.py --bench BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs import get_config
from repro.core.roofline import kv_bytes_per_token
from repro.planner import (
    Calibration, FixedIterationCost, SLOSpec, SampledRequest, WorkloadSpec,
    plan_capacity, simulate,
)
from repro.runtime import EngineConfig, CacheConfig, TokenBudgetPolicy

try:                                  # script launch: sibling module
    import serve_throughput as ST
except ImportError:                   # package launch
    from benchmarks import serve_throughput as ST

TOLERANCE = 0.25


def _rel(predicted, measured) -> float:
    if measured == 0:
        return 0.0 if predicted == 0 else float("inf")
    return round((predicted - measured) / measured, 9)


class Section:
    """Accumulates {workload: {metric: {predicted, measured, rel_err,
    gated}}} plus the flat gated map check_bench reads."""

    def __init__(self, tolerance: float):
        self.tolerance = tolerance
        self.workloads: dict = {}

    def add(self, workload: str, metric: str, predicted, measured,
            gated: bool = True):
        w = self.workloads.setdefault(workload, {"metrics": {}})
        w["metrics"][metric] = {
            "predicted": predicted, "measured": measured,
            "rel_err": _rel(float(predicted), float(measured)),
            "gated": gated,
        }

    def finish(self) -> dict:
        gated = {}
        for wname, w in self.workloads.items():
            errs = [m["rel_err"] for m in w["metrics"].values()
                    if m["gated"]]
            w["within_tolerance"] = all(abs(e) <= self.tolerance
                                        for e in errs)
            for mname, m in w["metrics"].items():
                if m["gated"]:
                    gated[f"{wname}.{mname}"] = m["rel_err"]
        return {
            "tolerance": self.tolerance,
            "workloads": self.workloads,
            "gated": gated,
            "workloads_within_tolerance": sum(
                1 for w in self.workloads.values()
                if w["within_tolerance"]),
            "max_gated_abs_rel_err": max(
                (abs(e) for e in gated.values()), default=0.0),
        }


def _as_arrivals(prompts, max_new):
    return [SampledRequest(rid=i, t=0.0, prompt=tuple(p), max_new=max_new)
            for i, p in enumerate(prompts)]


def _per_seq(longest: int, page_size: int) -> int:
    return -(-longest // page_size) + 1


def replay_latency(bench: dict, sec: Section, vocab: int):
    lt = bench["latency"]
    w = lt["workload"]
    spec = WorkloadSpec(
        rate_rps=w["rate_rps"], requests=w["requests"],
        prompt_min=w["prompt_len"][0], prompt_max=w["prompt_len"][1],
        output_min=w["output_len"][0], output_max=w["output_len"][1],
        seed=w["seed"])
    arrivals = spec.sample_arrivals(vocab)
    longest = max(len(a.prompt) + a.max_new for a in arrivals)
    per_seq = _per_seq(longest, w["page_size"])
    engine = EngineConfig(
        cache=CacheConfig(num_pages=per_seq * w["max_lanes"] + 8,
                          page_size=w["page_size"],
                          max_pages_per_seq=per_seq),
        max_lanes=w["max_lanes"], chunk=w["chunk"],
        scheduler_policy=TokenBudgetPolicy(w["token_budget"]),
        use_kernel=False)
    cal = Calibration(iter_time_s=w["iter_time_s"])
    rep = simulate(arrivals, engine, iteration_cost=cal.cost())
    measured_tput = lt["completed"] / lt["virtual_duration_s"]
    sec.add("latency", "throughput_rps", rep["throughput_rps"],
            round(measured_tput, 9))
    for m in ("ttft_p50_s", "ttft_p95_s", "tpot_p95_s", "iterations",
              "virtual_duration_s"):
        sec.add("latency", m, rep[m], lt[m])
    return spec, engine, rep


def replay_speculation(bench: dict, sec: Section, vocab: int):
    sd = bench["speculation"]
    w = sd["workload"]
    prompts = ST._make_repeated_suffix_prompts(
        w["requests"], w["pat_len"], w["reps"], w["tail_len"], vocab)
    per_seq = _per_seq(w["prompt_len"] + w["max_new"],
                       bench["workload"]["page_size"])
    lanes = w["requests"]             # one request per lane, by design
    common = dict(
        cache=CacheConfig(num_pages=per_seq * lanes + 8,
                          page_size=bench["workload"]["page_size"],
                          max_pages_per_seq=per_seq),
        max_lanes=lanes, chunk=sd["spec_off"]["chunk"], use_kernel=False)
    arrivals = _as_arrivals(prompts, w["max_new"])
    cost = FixedIterationCost(0.0)
    off = simulate(arrivals, EngineConfig(**common), iteration_cost=cost)
    on = simulate(arrivals, EngineConfig(spec_k=w["spec_k"], **common),
                  iteration_cost=cost,
                  spec_acceptance=sd["acceptance_rate"])
    sec.add("speculation", "spec_off.iterations",
            off["iterations"], sd["spec_off"]["iterations"])
    sec.add("speculation", "spec_off.generated_tokens",
            off["generated_tokens"], sd["spec_off"]["generated_tokens"])
    sec.add("speculation", "spec_off.prefill_tokens",
            off["prefill_tokens"], sd["spec_off"]["prefill_tokens"])
    # model limit: rate-based acceptance vs the n-gram drafter's warm-up
    sec.add("speculation", "spec_on.iterations",
            on["iterations"], sd["spec_on"]["iterations"], gated=False)


def replay_quantized(bench: dict, sec: Section, vocab: int, model_cfg):
    qk = bench["quantized_kv"]
    w = qk["workload"]
    page_size = bench["workload"]["page_size"]
    lanes = bench["workload"]["max_lanes"]
    prompts = ST._make_repeated_suffix_prompts(
        w["requests"], w["pat_len"], w["reps"], w["tail_len"], vocab)
    per_seq = _per_seq(w["prompt_len"] + w["max_new"], page_size)
    arrivals = _as_arrivals(prompts, w["max_new"])
    for kv in ("bf16", "int8"):
        engine = EngineConfig(
            cache=CacheConfig(num_pages=per_seq * lanes + 32,
                              page_size=page_size,
                              max_pages_per_seq=per_seq, kv_dtype=kv),
            max_lanes=lanes, chunk=qk[kv]["chunk"], use_kernel=False)
        rep = simulate(arrivals, engine,
                       iteration_cost=FixedIterationCost(0.0))
        sec.add("quantized_kv", f"{kv}.iterations",
                rep["iterations"], qk[kv]["iterations"])
        sec.add("quantized_kv", f"{kv}.bytes_per_token",
                kv_bytes_per_token(model_cfg, kv, page_size),
                qk[kv]["bytes_per_token"])
    sec.add("quantized_kv", "bytes_per_token_ratio",
            kv_bytes_per_token(model_cfg, "int8", page_size) /
            kv_bytes_per_token(model_cfg, "bf16", page_size),
            qk["bytes_per_token_ratio"])


def replay_hierarchical(bench: dict, sec: Section, vocab: int):
    hc = bench["hierarchical_cache"]
    w = hc["workload"]
    prompts, _order = ST._make_tenant_prompts(
        w["tenants"], w["visits"], w["sys_len"], w["tail_len"], vocab)
    per_seq = _per_seq(w["sys_len"] + w["tail_len"] + w["max_new"],
                       w["page_size"])
    arrivals = _as_arrivals(prompts, w["max_new"])
    corpus = w["corpus_pages"]
    # chunk/lanes/tier sizing mirror run_hierarchical_cache (not recorded
    # in the workload block)
    for tag, tiered in (("device_only", False), ("tiered", True)):
        engine = EngineConfig(
            cache=CacheConfig(
                num_pages=w["device_pages"], page_size=w["page_size"],
                max_pages_per_seq=per_seq,
                host_tier_pages=corpus // 4 if tiered else 0,
                disk_tier_pages=2 * corpus if tiered else 0,
                prefetch_depth=2,
                promote_latency_s=0.002 if tiered else 0.0),
            max_lanes=2, chunk=4, use_kernel=False)
        rep = simulate(arrivals, engine,
                       iteration_cost=FixedIterationCost(0.0))
        for m in ("iterations", "prefill_tokens", "hits_device_pages"):
            sec.add("hierarchical_cache", f"{tag}.{m}", rep[m], hc[tag][m])
        if tiered:
            for m in ("virtual_duration_s", "hits_host_pages",
                      "hits_disk_pages", "promoted_pages",
                      "demoted_pages"):
                sec.add("hierarchical_cache", f"{tag}.{m}",
                        rep[m], hc[tag][m])


def replay_cluster_sweep(bench: dict, sec: Section, vocab: int):
    sw = bench["cluster_sweep"]
    w = bench["workload"]
    prompts = ST._make_prompts(w["requests"], w["prompt_len"], vocab)
    per_seq = _per_seq(w["prompt_len"] + w["max_new"], w["page_size"])
    arrivals = _as_arrivals(prompts, w["max_new"])
    for cname, measured in sw["configs"].items():
        engine = EngineConfig(
            cache=CacheConfig(num_pages=per_seq * w["max_lanes"] + 8,
                              page_size=w["page_size"],
                              max_pages_per_seq=per_seq),
            max_lanes=w["max_lanes"], chunk=measured["chunk"],
            clusters=int(cname), heads=sw["heads"], sharded=True,
            use_kernel=False)
        rep = simulate(arrivals, engine,
                       iteration_cost=FixedIterationCost(0.0))
        sec.add("cluster_sweep", f"{cname}.iterations",
                rep["iterations"], measured["iterations"])
        sec.add("cluster_sweep", f"{cname}.generated_tokens",
                rep["generated_tokens"], measured["generated_tokens"])
        for c, (pp, mp) in enumerate(zip(
                rep["peak_pages_per_cluster"],
                measured["peak_pages_per_cluster"])):
            sec.add("cluster_sweep", f"{cname}.peak_pages.c{c}", pp, mp)


def capacity_demo(bench: dict, spec: WorkloadSpec, model_cfg) -> dict:
    """End-to-end inversion on the bench's own latency workload: the
    recommended config's predicted report must meet the bench SLO."""
    iter_time = bench["latency"]["workload"]["iter_time_s"]
    slo = SLOSpec(ttft_p95_s=bench["latency"]["slo"]["ttft_s"],
                  tpot_p95_s=bench["latency"]["slo"]["tpot_s"])
    plan = plan_capacity(spec, slo, model_cfg=model_cfg,
                         page_size=bench["latency"]["workload"]["page_size"],
                         calibration=Calibration(iter_time_s=iter_time),
                         vocab=model_cfg.vocab_size)
    e = plan.engine
    return {
        "slo": slo.to_json(),
        "engine": {"clusters": e.clusters, "max_lanes": e.max_lanes,
                   "num_pages": e.cache.num_pages, "chunk": e.chunk,
                   "kv_dtype": e.cache.kv_dtype, "spec_k": e.spec_k},
        "cost_bytes": plan.cost,
        "candidates_evaluated": plan.evaluated,
        "predicted": {k: plan.predicted[k] for k in
                      ("completed", "ttft_p95_s", "tpot_p95_s",
                       "throughput_rps", "iterations")},
        "slo_met": slo.met_by(plan.predicted),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default="BENCH_serve.json",
                    help="bench JSON to replay and update in place")
    ap.add_argument("--out", default=None,
                    help="write the updated bench here "
                         "(default: --bench, in place)")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        bench = json.load(f)
    arch = bench["arch"]
    if arch.endswith("-smoke"):
        arch = arch[:-len("-smoke")]
    model_cfg = get_config(arch).smoke()
    vocab = model_cfg.vocab_size

    sec = Section(args.tolerance)
    spec, _engine, _rep = replay_latency(bench, sec, vocab)
    replay_speculation(bench, sec, vocab)
    replay_quantized(bench, sec, vocab, model_cfg)
    replay_hierarchical(bench, sec, vocab)
    replay_cluster_sweep(bench, sec, vocab)
    section = sec.finish()
    section["capacity_demo"] = capacity_demo(bench, spec, model_cfg)

    bench["planner_accuracy"] = section
    out = args.out or args.bench
    with open(out, "w") as f:
        json.dump(bench, f, indent=2)

    print(f"# planner accuracy (tolerance +-{args.tolerance:.0%})")
    for wname, w in section["workloads"].items():
        flag = "ok " if w["within_tolerance"] else "FAIL"
        worst = max((abs(m["rel_err"]) for m in w["metrics"].values()
                     if m["gated"]), default=0.0)
        print(f"{flag} {wname:>20s}: {len(w['metrics'])} metrics, "
              f"worst gated |rel err| = {worst:.4f}")
    demo = section["capacity_demo"]
    e = demo["engine"]
    print(f"plan_capacity: clusters={e['clusters']} lanes={e['max_lanes']} "
          f"pages={e['num_pages']} chunk={e['chunk']} kv={e['kv_dtype']} "
          f"spec_k={e['spec_k']}  (evaluated "
          f"{demo['candidates_evaluated']}, slo_met={demo['slo_met']})")
    print(f"max gated |rel err| = {section['max_gated_abs_rel_err']:.4f} "
          f"over {len(section['gated'])} gated metrics; "
          f"{section['workloads_within_tolerance']}/"
          f"{len(section['workloads'])} workloads within tolerance")
    if section["workloads_within_tolerance"] < len(section["workloads"]):
        print("planner accuracy outside tolerance", file=sys.stderr)
        sys.exit(1)
    print(f"wrote {out}")
    return section


if __name__ == "__main__":
    main()
