"""Roofline analysis from the dry-run artifacts.

Per (arch x shape x mesh) cell, derives the three terms (seconds):

  compute    = HLO_FLOPs_per_device / peak_flops        (197 TF/s bf16, v5e)
  memory     = bytes_per_device / HBM_bw                (819 GB/s)
  collective = collective_bytes_per_device / link_bw    (50 GB/s/link)

Sources and corrections (documented in EXPERIMENTS.md):
  * HLO_FLOPs: trip-count-corrected dot re-count (launch/hlo_stats.dot_flops)
    — ``cost_analysis()['flops']`` counts while bodies once, so scanned-layer
    training graphs would be ~L x undercounted;
  * collective bytes: per-device operand sums from the SPMD HLO, with the
    CPU-backend f32-legalization halved for >=1MiB f32 ops (TPU moves bf16);
  * memory bytes: the CPU backend's ``bytes accessed`` both over-counts
    (f32-widened tensors, no latency-hiding scheduler) and under-counts
    (loop bodies once), so the memory term uses an *analytic* per-device
    model: weight+optimizer traffic + activation/cache traffic; the raw
    cost_analysis number is reported alongside.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N per token (decode),
with N_active for MoE.  The reported ``roofline_fraction`` is
useful-model-FLOP-time / dominant-term — the score of how close the cell
sits to the hardware roofline.

``--kv-dtype int8`` models the quantized KV serving path
(``CacheConfig.kv_dtype="int8"``): decode-cache traffic shrinks to one
byte per element plus the amortized per-(page, K/V, head) float32 scale,
which roughly halves the memory term of decode shapes and shifts their
arithmetic intensity (reported per cell as ``arith_intensity`` =
HLO FLOPs / HBM bytes) correspondingly up the roofline.  Only paged
attention KV pools quantize — MLA latent, SSM and mLSTM state stay at
their native widths.

The analytic byte/FLOP terms themselves live in
:mod:`repro.core.roofline` (pure functions, no artifacts) so the
capacity planner (``repro.planner``) prices engine iterations from the
same model this table renders; this module keeps the artifact loading,
table assembly and CLI.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional


from repro.configs import SHAPES, get_config
from repro.core.roofline import (  # noqa: F401  (re-exported: the analytic
    KV_PAGE_SIZE, analytic_bytes, cache_bytes,  # model moved to the library;
    kv_elt_bytes, model_flops, param_counts,    # old import paths keep
)                                               # working)
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_LINK_BW

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

#: Backwards-compatible private alias (pre-refactor name).
_kv_elt_bytes = kv_elt_bytes


# ---------------------------------------------------------------------------
# table assembly
# ---------------------------------------------------------------------------

def load_cell(arch: str, shape: str, mesh: str,
              profile: str = "megatron") -> Optional[dict]:
    suffix = "" if profile == "megatron" else f"__{profile}"
    f = ARTIFACTS / f"{arch}__{shape}__{mesh}{suffix}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def analyze_cell(arch: str, shape_name: str, mesh: str = "single",
                 profile: str = "megatron",
                 kv_dtype: str = "bf16") -> Optional[dict]:
    rec = load_cell(arch, shape_name, mesh, profile)
    if rec is None or rec.get("status") != "ok":
        return rec
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dev = rec["devices"]
    # the serve profile keeps weights sharded over `model` only (replicated
    # across data): each device reads params/tp, not params/devices
    weight_div = 16 if profile == "serve" else dev

    hlo_flops_dev = rec.get("dot_flops") or rec["cost"].get("flops", 0.0)
    mf_global = model_flops(cfg, shape)
    mf_dev = mf_global / dev
    bytes_dev = analytic_bytes(cfg, shape, dev, kv_dtype) + \
        param_counts(cfg)["total"] * 2.0 * (1.0 / weight_div - 1.0 / dev)
    coll_dev = rec.get("collective_bytes_tpu", rec.get("collective_bytes", 0))

    t_comp = hlo_flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_model = mf_dev / PEAK_FLOPS_BF16
    frac = t_model / max(terms[dominant], 1e-30)
    # attainment: unavoidable work (useful FLOPs or the analytic byte
    # movement, whichever binds) over the actual bound — 1.0 means the cell
    # sits on its intrinsic roofline
    intrinsic = max(t_model, t_mem)
    attainment = intrinsic / max(max(terms.values()), 1e-30)

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh, "status": "ok",
        "profile": profile, "kv_dtype": kv_dtype,
        "devices": dev,
        "hlo_flops_dev": hlo_flops_dev,
        "model_flops_dev": mf_dev,
        "useful_ratio": mf_dev / max(hlo_flops_dev, 1e-30),
        "bytes_dev": bytes_dev,
        "arith_intensity": hlo_flops_dev / max(bytes_dev, 1e-30),
        "cost_bytes_dev": rec["cost"].get("bytes accessed", 0.0),
        "coll_bytes_dev": coll_dev,
        "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,
        "attainment": attainment,
        "compile_s": rec.get("compile_s"),
        "temp_gib": (rec["memory"]["temp_size_in_bytes"] or 0) / 2 ** 30,
        "args_gib": (rec["memory"]["argument_size_in_bytes"] or 0) / 2 ** 30,
    }


def full_table(mesh: str = "single", kv_dtype: str = "bf16") -> List[dict]:
    rows = []
    for arch in sorted({f.name.split("__")[0] for f in ARTIFACTS.glob("*.json")}):
        for shape in SHAPES:
            rec = load_cell(arch, shape, mesh)
            if rec is None:
                continue
            if rec.get("status") == "skipped":
                rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                             "status": "skipped", "reason": rec["reason"]})
            else:
                rows.append(analyze_cell(arch, shape, mesh,
                                         kv_dtype=kv_dtype))
    return rows


def render_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac | attainment "
           "| what would move the dominant term |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — | {r['reason'][:60]} |")
            continue
        hint = _improvement_hint(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
            f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['attainment']:.3f} | {hint} |")
    return "\n".join(lines)


def _improvement_hint(r: dict) -> str:
    if r["dominant"] == "collective":
        return ("reduce per-layer resharding: fewer TP gathers (wider FSDP), "
                "or EP-local MoE dispatch")
    if r["dominant"] == "memory":
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            if r.get("kv_dtype") == "int8":
                return "KV already int8: batch more requests / MLA-style compression"
            return "quantize KV cache (kv_dtype=int8) / MLA-style compression"
        return "fuse activations (flash kernel), larger remat leaves"
    if r["useful_ratio"] < 0.8:
        return "cut remat recompute (dots-saveable policy) / drop redundant fp32"
    return "near roofline: overlap remaining collectives"


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"),
                    help="KV-pool storage dtype for the decode-cache "
                         "byte model (int8 = quantized serving path)")
    args = ap.parse_args()
    rows = full_table(args.mesh, kv_dtype=args.kv_dtype)
    if args.csv:
        print("arch,shape,kv_dtype,t_compute,t_memory,t_collective,dominant,"
              "useful_ratio,arith_intensity,roofline_fraction")
        for r in rows:
            if r.get("status") == "ok":
                print(f"{r['arch']},{r['shape']},{r['kv_dtype']},"
                      f"{r['t_compute']:.4e},"
                      f"{r['t_memory']:.4e},{r['t_collective']:.4e},"
                      f"{r['dominant']},{r['useful_ratio']:.3f},"
                      f"{r['arith_intensity']:.3f},"
                      f"{r['roofline_fraction']:.4f}")
    else:
        print(render_markdown(rows))


if __name__ == "__main__":
    main()
