"""Benchmark driver: one section per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows per section (the scaffold
contract), then the full section outputs.
"""
from __future__ import annotations

import io
import time
import traceback
from contextlib import redirect_stdout


def _run(name, fn):
    buf = io.StringIO()
    t0 = time.perf_counter()
    status = "ok"
    try:
        with redirect_stdout(buf):
            fn()
    except Exception as e:  # noqa: BLE001
        status = f"error:{type(e).__name__}"
        buf.write(traceback.format_exc())
    dt = (time.perf_counter() - t0) * 1e6
    print(f"{name},{dt:.0f},{status}", flush=True)
    return name, buf.getvalue()


def main() -> None:
    sections = []
    from benchmarks import fig4_cluster_speedup, fig5_svm_offload, \
        fig6_event_tracing, tab2_resources, roofline

    print("name,us_per_call,derived")
    sections.append(_run("fig4_cluster_speedup",
                         lambda: fig4_cluster_speedup.main(throughput=True)))
    sections.append(_run("fig5_svm_offload", fig5_svm_offload.main))
    sections.append(_run("fig6_event_tracing", fig6_event_tracing.main))
    sections.append(_run("tab2_resources", tab2_resources.main))
    sections.append(_run("roofline_single_pod",
                         lambda: print(roofline.render_markdown(
                             roofline.full_table("single")))))

    for name, out in sections:
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        print(out)


if __name__ == '__main__':
    main()
