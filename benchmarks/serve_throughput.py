"""Serving hot-path benchmark: chunked prefill vs token-by-token admission.

Runs the same workload through the paged engine twice — ``chunk=1``
(reproducing the pre-chunked-prefill engine's iteration structure: one
prompt token per engine iteration) and ``chunk=N`` — and reports per run:

* generated tokens/s (wall clock over the whole workload)
* engine iterations per finished request
* host->device / device->host transfer events, trace-counted from the
  engine's ``TraceBuffer`` (``EventType.H2D`` / ``D2H``), per generated
  token

Emits ``BENCH_serve.json`` so the serving perf trajectory is tracked
PR-over-PR.

    PYTHONPATH=src python benchmarks/serve_throughput.py            # full
    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.tracing import EventType, TraceBuffer
from repro.models import model as M
from repro.runtime import PagedServer, Request


def _make_prompts(n: int, length: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=length).tolist() for _ in range(n)]


def run_engine(cfg, params, prompts, *, chunk, max_new, num_pages, page_size,
               max_lanes, max_pages_per_seq, use_kernel) -> dict:
    tracer = TraceBuffer(capacity=1 << 16)
    srv = PagedServer(cfg, params, num_pages=num_pages, page_size=page_size,
                      max_lanes=max_lanes, max_pages_per_seq=max_pages_per_seq,
                      chunk=chunk, use_kernel=use_kernel, tracer=tracer)
    reqs = [Request(rid=rid, prompt=list(p), max_new=max_new)
            for rid, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.step()                       # warmup iteration triggers jit compile
    warm_gen = sum(len(r.out) for r in reqs)
    t0 = time.perf_counter()
    done = srv.run()
    jax.block_until_ready(srv.last_tok)
    dt = time.perf_counter() - t0

    events = tracer.drain()
    h2d = int(sum(e[3] for e in events if e[2] == EventType.H2D))
    d2h = int(sum(e[3] for e in events if e[2] == EventType.D2H))
    gen = sum(len(r.out) for r in done)
    # tokens/s only counts tokens produced inside the timed window, so the
    # untimed warmup iteration (which for a chunked run is the expensive
    # full-prefill step and may itself emit tokens) doesn't bias the ratio
    gen_timed = gen - warm_gen
    assert len(done) == len(prompts), "workload did not drain"
    return {
        "chunk": chunk,
        "iterations": srv.iterations,
        "iters_per_request": srv.iterations / len(done),
        "generated_tokens": gen,
        "tokens_per_s": gen_timed / max(dt, 1e-9),
        "wall_s": dt,
        "h2d_events": h2d,
        "d2h_events": d2h,
        "h2d_per_generated_token": h2d / max(gen, 1),
        "d2h_per_generated_token": d2h / max(gen, 1),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-lanes", type=int, default=4)
    ap.add_argument("--kernel", action="store_true",
                    help="force the Pallas kernels (default: kernels on TPU, "
                         "XLA reference path elsewhere — engine structure and "
                         "transfer counts are identical either way)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny workload, seconds on CPU")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests, args.prompt_len, args.max_new = 3, 12, 4
        args.chunk, args.page_size, args.max_lanes = 8, 4, 2

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _make_prompts(args.requests, args.prompt_len, cfg.vocab_size)

    per_seq = -(-(args.prompt_len + args.max_new) // args.page_size) + 1
    num_pages = per_seq * args.max_lanes + 8
    use_kernel = args.kernel or jax.default_backend() == "tpu"
    common = dict(max_new=args.max_new, num_pages=num_pages,
                  page_size=args.page_size, max_lanes=args.max_lanes,
                  max_pages_per_seq=per_seq, use_kernel=use_kernel)

    baseline = run_engine(cfg, params, prompts, chunk=1, **common)
    chunked = run_engine(cfg, params, prompts, chunk=args.chunk, **common)

    result = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "use_kernel": use_kernel,
        "workload": {"requests": args.requests,
                     "prompt_len": args.prompt_len,
                     "max_new": args.max_new,
                     "page_size": args.page_size,
                     "max_lanes": args.max_lanes},
        "baseline_token_by_token": baseline,
        "chunked_prefill": chunked,
        "iters_per_request_reduction":
            baseline["iters_per_request"] / chunked["iters_per_request"],
        "tokens_per_s_speedup":
            chunked["tokens_per_s"] / max(baseline["tokens_per_s"], 1e-9),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)

    print(f"# serve_throughput ({cfg.name}, {jax.default_backend()}, "
          f"kernel={use_kernel})")
    for tag, r in (("token-by-token", baseline), ("chunked", chunked)):
        print(f"{tag:>16s}: chunk={r['chunk']:<4d} "
              f"iters/req={r['iters_per_request']:6.1f}  "
              f"tok/s={r['tokens_per_s']:8.1f}  "
              f"h2d/tok={r['h2d_per_generated_token']:5.2f}  "
              f"d2h/tok={r['d2h_per_generated_token']:5.2f}")
    print(f"iters/request reduction: "
          f"{result['iters_per_request_reduction']:.2f}x   "
          f"tokens/s speedup: {result['tokens_per_s_speedup']:.2f}x")
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
