"""Serving hot-path benchmark: chunked prefill, shared-prefix KV caching,
preemptive scheduling, speculative decoding, sampled decoding, and the
multi-cluster sweep — all driven through the unified generation API
(``EngineConfig`` + ``GenerationRequest``/``SamplingParams`` +
``make_engine``).

Workloads, all emitted into ``BENCH_serve.json``:

* chunked prefill vs token-by-token admission (``chunk=1`` reproduces the
  pre-chunked-prefill engine's iteration structure) — tokens/s, engine
  iterations per request, trace-counted H2D/D2H transfer events per
  generated token;
* a shared-prefix workload (K distinct system prompts x M requests each)
  served with prefix caching off vs on — prefix-hit rate, pages saved,
  copy-on-writes, engine iterations, tokens/s;
* a forced-preemption probe: a tight pool where a high-priority arrival
  preempts the running low-priority lane (non-shared pages swap D2H to the
  host backing store and back) — completion, output correctness vs an
  uncontended run, and trace-counted swap events;
* a multi-cluster sweep (``--clusters 4`` -> configs {1, 2, 4}): the same
  workload served by the sharded engine across a ``("cluster", "head")``
  mesh — iters/request, per-cluster peak page occupancy, dispatch balance,
  with the 1-cluster configuration asserted token-for-token identical to
  the unsharded engine;
* a speculative-decoding workload (repeated-suffix prompts, one request
  per lane so drafting is never throttled) served with ``spec_k`` off vs
  on — engine iterations per generated token (the gated win), acceptance
  rate, wasted verify tokens, and token-for-token parity asserted;
* a sampled-decoding workload: the same prompts served greedy
  (temperature 0 — the gated iters/generated-token path) and at
  temperature/top-p with per-request seeds — seed-reproducibility is
  asserted (two identical sampled runs must match token-for-token), and a
  stop-token request demonstrates the ``finish_reason="stop"`` early
  exit;
* a seeded fault storm (the ``degradation`` section): the same engine
  under injected backing-store faults (transient I/O errors retried with
  backoff, planted payload corruption caught by checksum at swap-in), a
  tight deadline, a mid-stream cancel, a forced preemption and
  admission-time load shedding — goodput, completed-within-deadline
  fraction, recovery counters, survivor token parity vs the fault-free
  reference, and a zero unhandled-exception count, all CI-gated.
  Deadlines here are ``deadline_iters`` only: wall-clock ``deadline_s``
  would make the committed baseline nondeterministic.
* a hierarchical prefix-cache workload (the ``hierarchical_cache``
  section): a Zipf-weighted multi-tenant corpus ~4x the device pool,
  served device-only vs with host+disk spill tiers and async promotion
  on a virtual clock — tier hit rates, demotion/promotion counts,
  prefill tokens saved, output token parity, all CI-gated.

    PYTHONPATH=src python benchmarks/serve_throughput.py            # full
    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke    # CI-sized
    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke --clusters 4
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

# The cluster sweep needs virtual devices on CPU; XLA only reads the flag
# before the first jax import, so force it here when launched as a script
# with a sweep request.  (When imported as a module — e.g. by smoke_all —
# jax may already be up; the sweep then skips configs it lacks devices for.)
if "--clusters" in sys.argv and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax
import numpy as np

from repro.configs import get_config
from repro.core.analysis import (
    assert_faults_contained, layer1_decode, layer2_cluster_balance,
    layer2_fault_recovery, layer2_speculation,
)
from repro.core.tracing import EventType, TraceBuffer
from repro.models import model as M
from repro.runtime import (
    CacheConfig, EngineConfig, FaultInjector, FaultSpec, GenerationRequest,
    SamplingParams, VirtualClock, make_engine,
)

try:                                  # script launch: sibling module
    import load_gen
except ImportError:                   # package launch: benchmarks.load_gen
    from benchmarks import load_gen


def _make_prompts(n: int, length: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=length).tolist() for _ in range(n)]


def run_engine(cfg, params, prompts, *, chunk, max_new, num_pages, page_size,
               max_lanes, max_pages_per_seq, use_kernel,
               enable_prefix_cache=True, clusters=None, heads=1,
               keep_events=None, spec_k=0, sampling_for=None,
               kv_dtype="bf16") -> dict:
    """One engine run through ``make_engine``.  ``clusters=None`` -> the
    unsharded ``PagedServer``; an int -> ``ShardedPagedServer`` over a
    (clusters, heads) mesh, with per-cluster occupancy and dispatch
    balance added to the result.  ``spec_k > 0`` enables speculative
    decoding (n-gram drafter) and adds acceptance metrics.
    ``sampling_for`` maps a request index to its ``SamplingParams``
    (default: greedy with ``max_new``); ``kv_dtype`` selects the KV-pool
    storage dtype ("bf16" | "int8")."""
    tracer = TraceBuffer(capacity=1 << 16)
    engine_cfg = EngineConfig(
        cache=CacheConfig(num_pages=num_pages, page_size=page_size,
                          max_pages_per_seq=max_pages_per_seq,
                          enable_prefix_cache=enable_prefix_cache,
                          kv_dtype=kv_dtype),
        max_lanes=max_lanes, chunk=chunk, use_kernel=use_kernel,
        spec_k=spec_k, clusters=clusters or 1, heads=heads,
        sharded=clusters is not None)
    srv = make_engine(cfg, params, engine_cfg, tracer=tracer)
    if sampling_for is None:
        def sampling_for(rid):
            return SamplingParams(max_new=max_new)
    for rid, p in enumerate(prompts):
        srv.submit(GenerationRequest(rid=rid, prompt=tuple(p),
                                     sampling=sampling_for(rid)))
    srv.step()                       # warmup iteration triggers jit compile
    warm_gen = sum(len(s.out) for s in srv.lanes if s is not None) + \
        sum(len(r.tokens) for r in srv.finished)
    t0 = time.perf_counter()
    done = srv.run()
    jax.block_until_ready(srv.last_tok)
    dt = time.perf_counter() - t0

    events = tracer.drain()
    h2d = int(sum(e[3] for e in events if e[2] == EventType.H2D))
    d2h = int(sum(e[3] for e in events if e[2] == EventType.D2H))
    gen = sum(len(r.tokens) for r in done)
    # tokens/s only counts tokens produced inside the timed window, so the
    # untimed warmup iteration (which for a chunked run is the expensive
    # full-prefill step and may itself emit tokens) doesn't bias the ratio
    gen_timed = gen - warm_gen
    assert len(done) == len(prompts), "workload did not drain"
    if keep_events is not None:
        keep_events.extend(np.asarray(events).tolist())
    prompt_tokens = sum(len(p) for p in prompts)
    stats = srv.cache_stats()
    hit_tokens = stats.prefix_hit_tokens
    extra = {}
    if clusters is not None:
        bal = layer2_cluster_balance(layer1_decode(events),
                                     n_clusters=clusters)
        extra = dict(srv.cluster_report(),
                     dispatch_balance=bal["balance"],
                     all_gathers=bal["all_gathers"])
    if spec_k:
        sp = layer2_speculation(layer1_decode(events))
        extra.update(
            spec_k=spec_k,
            spec_iterations=srv.spec_iterations,
            spec_proposed=srv.spec_proposed,
            spec_accepted=srv.spec_accepted,
            spec_rejected=srv.spec_rejected,
            acceptance_rate=sp["acceptance_rate"],
            wasted_verify_tokens=sp["wasted_verify_tokens"],
        )
    reasons: dict = {}
    for r in done:
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    return {
        **extra,
        "chunk": chunk,
        "iterations": srv.iterations,
        "iters_per_request": srv.iterations / len(done),
        "iters_per_generated_token": srv.iterations / max(gen, 1),
        "generated_tokens": gen,
        "tokens_per_s": gen_timed / max(dt, 1e-9),
        "wall_s": dt,
        "h2d_events": h2d,
        "d2h_events": d2h,
        "h2d_per_generated_token": h2d / max(gen, 1),
        "d2h_per_generated_token": d2h / max(gen, 1),
        "prefill_tokens": srv.prefill_tokens,
        "kv_dtype": kv_dtype,
        "bytes_per_token": stats.bytes_per_token,
        "prefix_hit_tokens": hit_tokens,
        "prefix_hit_rate": hit_tokens / max(prompt_tokens, 1),
        "pages_saved": srv.pool.stats["prefix_hit_pages"],
        "cow_pages": srv.pool.stats["cow"],
        "finish_reasons": reasons,
        "outputs": {r.rid: list(r.tokens) for r in done},
    }


def _make_shared_prefix_prompts(k_prefixes, m_per_prefix, sys_len, user_len,
                                vocab, seed=1):
    """K distinct system prompts x M requests each (distinct user tails),
    interleaved round-robin so the cache is stressed across prefixes."""
    rng = np.random.default_rng(seed)
    systems = [rng.integers(1, vocab, size=sys_len).tolist()
               for _ in range(k_prefixes)]
    prompts = []
    for m in range(m_per_prefix):
        for s in systems:
            prompts.append(s + rng.integers(1, vocab,
                                            size=user_len).tolist())
    return prompts


def _make_repeated_suffix_prompts(n, pat_len, reps, tail_len, vocab, seed=3):
    """n prompts, each a short random pattern tiled ``reps`` times plus a
    distinct random tail — the workload speculative decoding exists for:
    greedy decode over periodic context settles into short cycles the
    n-gram drafter predicts almost for free."""
    rng = np.random.default_rng(seed)
    prompts = []
    for _ in range(n):
        pat = rng.integers(1, vocab, size=pat_len).tolist()
        tail = rng.integers(1, vocab, size=tail_len).tolist()
        prompts.append(pat * reps + tail)
    return prompts


def run_spec_workload(cfg, params, *, spec_k, max_new, page_size, max_lanes,
                      use_kernel, pat_len=4, reps=3, tail_len=2,
                      chunk=8) -> dict:
    """Repeated-suffix workload served spec-off vs spec-on.

    One request per lane so the queue stays empty and drafting is never
    throttled; identical engine configuration otherwise, so the only
    difference is the draft-verify-rollback path.  Outputs must match
    token-for-token (greedy parity), and engine iterations per generated
    token is the headline win the CI gate locks in."""
    prompts = _make_repeated_suffix_prompts(max_lanes, pat_len, reps,
                                            tail_len, cfg.vocab_size)
    plen = pat_len * reps + tail_len
    per_seq = -(-(plen + max_new) // page_size) + 1
    common = dict(chunk=chunk, max_new=max_new,
                  num_pages=per_seq * max_lanes + 8, page_size=page_size,
                  max_lanes=max_lanes, max_pages_per_seq=per_seq,
                  use_kernel=use_kernel)
    off = run_engine(cfg, params, prompts, spec_k=0, **common)
    on = run_engine(cfg, params, prompts, spec_k=spec_k, **common)
    outputs_match = off.pop("outputs") == on.pop("outputs")
    return {
        "workload": {"requests": max_lanes, "prompt_len": plen,
                     "pat_len": pat_len, "reps": reps, "tail_len": tail_len,
                     "max_new": max_new, "spec_k": spec_k},
        "spec_off": off,
        "spec_on": on,
        "outputs_match": outputs_match,
        "acceptance_rate": on["acceptance_rate"],
        "wasted_verify_tokens": on["wasted_verify_tokens"],
        "iters_per_token_reduction":
            off["iters_per_generated_token"] /
            max(on["iters_per_generated_token"], 1e-9),
    }


def run_quantized_kv(cfg, params, *, page_size, max_lanes, use_kernel,
                     max_new=8, requests=8, pat_len=4, reps=5, tail_len=2,
                     chunk=8) -> dict:
    """int8 KV pool vs the bf16 baseline on the repeated-suffix greedy
    workload (the serving shape speculation also uses).

    Three engine runs with identical configuration except the pool dtype:
    the bf16 reference, the int8 pool on the same path, and the int8 pool
    on the *other* attention path (kernel vs oracle) for in-kernel-dequant
    parity.  Quality is scored as **teacher-forced next-token agreement**
    — for every reference position j the int8 engine is fed
    ``prompt + ref_out[:j]`` and asked for ONE token, so each comparison
    sees the same context and a single early flip cannot cascade (the
    standard perplexity-style proxy; free-running output equality is also
    reported, but it measures divergence, not quality).  Like the bench's
    other token-parity properties, the score is deterministic for the
    fixed seeded workload; disagreements are argmax near-ties of the
    random-weight smoke model, so the workload leans on long periodic
    prompts whose greedy continuations are decisive.  Memory is scored
    from ``CacheStats.bytes_per_token``: int8 pays 1 byte + 4/page_size
    scale bytes per (layer, K/V, head, dim) where the baseline pays the
    param dtype's width."""
    prompts = _make_repeated_suffix_prompts(requests, pat_len, reps,
                                            tail_len, cfg.vocab_size)
    plen = pat_len * reps + tail_len
    per_seq = -(-(plen + max_new) // page_size) + 1
    common = dict(chunk=chunk, max_new=max_new,
                  num_pages=per_seq * max_lanes + 32, page_size=page_size,
                  max_lanes=max_lanes, max_pages_per_seq=per_seq,
                  use_kernel=use_kernel)
    base = run_engine(cfg, params, prompts, kv_dtype="bf16", **common)
    quant = run_engine(cfg, params, prompts, kv_dtype="int8", **common)
    other = run_engine(cfg, params, prompts, kv_dtype="int8",
                       **dict(common, use_kernel=not use_kernel))
    ref_outputs = base.pop("outputs")
    quant_outputs = quant.pop("outputs")
    free_match = quant_outputs == ref_outputs
    paths_match = other.pop("outputs") == quant_outputs
    # teacher-forced sweep: every (prompt, position) pair is one
    # single-token request against a fresh int8 engine (prefix caching
    # makes the incremental prefixes cheap)
    tf_prompts, tf_refs = [], []
    for rid, p in enumerate(prompts):
        ref = ref_outputs[rid]
        for j in range(len(ref)):
            tf_prompts.append(list(p) + ref[:j])
            tf_refs.append(ref[j])
    tf_per_seq = -(-(plen + max_new + 1) // page_size) + 1
    tf = run_engine(cfg, params, tf_prompts, kv_dtype="int8",
                    **dict(common, max_new=1, max_pages_per_seq=tf_per_seq,
                           num_pages=tf_per_seq * max_lanes + 64))
    tf_out = tf.pop("outputs")
    agree = sum(int(tf_out[i][0] == tf_refs[i]) for i in range(len(tf_refs)))
    return {
        "workload": {"requests": requests, "prompt_len": plen,
                     "pat_len": pat_len, "reps": reps, "tail_len": tail_len,
                     "max_new": max_new,
                     "teacher_forced_positions": len(tf_refs)},
        "bf16": base,
        "int8": quant,
        "bytes_per_token_bf16": base["bytes_per_token"],
        "bytes_per_token_int8": quant["bytes_per_token"],
        "bytes_per_token_ratio":
            quant["bytes_per_token"] / max(base["bytes_per_token"], 1e-9),
        "page_pool_headroom":
            base["bytes_per_token"] / max(quant["bytes_per_token"], 1e-9),
        "token_agreement": agree / max(len(tf_refs), 1),
        "free_running_outputs_match": free_match,
        "kernel_ref_outputs_match": paths_match,
    }


def run_sampling_workload(cfg, params, *, max_new, page_size, max_lanes,
                          use_kernel, requests=4, prompt_len=10, chunk=8,
                          temperature=0.8, top_p=0.9) -> dict:
    """The same prompts served greedy vs sampled through ``SamplingParams``.

    The greedy run is the gated baseline (``iters_per_generated_token``
    must not regress — temperature 0 rides the exact argmax path the
    engine always had); the sampled run draws on device with per-request
    seeds and must be *reproducible*: a second identical run has to match
    token-for-token.  A final request carries a stop token harvested from
    the greedy output, demonstrating the ``finish_reason="stop"`` early
    exit."""
    prompts = _make_prompts(requests, prompt_len, cfg.vocab_size, seed=11)
    per_seq = -(-(prompt_len + max_new) // page_size) + 1
    common = dict(chunk=chunk, max_new=max_new,
                  num_pages=per_seq * max_lanes + 8, page_size=page_size,
                  max_lanes=max_lanes, max_pages_per_seq=per_seq,
                  use_kernel=use_kernel)

    def sampled_params(rid):
        return SamplingParams(temperature=temperature, top_p=top_p,
                              seed=100 + rid, max_new=max_new)

    greedy = run_engine(cfg, params, prompts, **common)
    sampled = run_engine(cfg, params, prompts, sampling_for=sampled_params,
                         **common)
    sampled_again = run_engine(cfg, params, prompts,
                               sampling_for=sampled_params, **common)
    reproducible = sampled["outputs"] == sampled_again.pop("outputs")
    diverged = sampled["outputs"] != greedy["outputs"]

    # stop-token early exit: stop on the first greedy continuation token
    # whose first occurrence is not at position 0 (so >= 1 token survives)
    g0 = greedy["outputs"][0]
    stop_tok = next((t for i, t in enumerate(g0)
                     if i > 0 and g0.index(t) == i), g0[-1])
    stop = run_engine(
        cfg, params, [prompts[0]],
        sampling_for=lambda rid: SamplingParams(
            max_new=max_new, stop_tokens=(stop_tok,)), **common)
    stop_out = stop.pop("outputs")[0]
    stop_early = (stop["finish_reasons"].get("stop") == 1
                  and stop_out == g0[:len(stop_out)]
                  and len(stop_out) <= len(g0))

    greedy.pop("outputs")
    sampled.pop("outputs")
    return {
        "workload": {"requests": requests, "prompt_len": prompt_len,
                     "max_new": max_new, "temperature": temperature,
                     "top_p": top_p},
        "greedy": greedy,
        "sampled": sampled,
        "sampled_reproducible": reproducible,
        "sampled_diverges_from_greedy": diverged,
        "stop_token_early_exit": stop_early,
        "stop_tokens_generated": len(stop_out),
    }


def run_preemption_probe(cfg, params, *, page_size, max_new, use_kernel,
                         prompt_len=8, chunk=4) -> dict:
    """Tight pool: a high-priority arrival must preempt the running
    low-priority lane (swap-out D2H, swap-in H2D) and both must finish
    with the same outputs as an uncontended run."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, size=prompt_len).tolist()
               for _ in range(2)]
    per_seq = int(np.ceil((prompt_len + max_new - 1) / page_size))

    def run(num_pages):
        tracer = TraceBuffer(capacity=1 << 16)
        srv = make_engine(cfg, params, EngineConfig(
            cache=CacheConfig(num_pages=num_pages, page_size=page_size,
                              max_pages_per_seq=per_seq + 1,
                              enable_prefix_cache=False),
            max_lanes=2, chunk=chunk, use_kernel=use_kernel),
            tracer=tracer)
        srv.submit(GenerationRequest(
            rid=0, prompt=tuple(prompts[0]), priority=0,
            sampling=SamplingParams(max_new=max_new)))
        srv.step()
        srv.step()
        srv.submit(GenerationRequest(
            rid=1, prompt=tuple(prompts[1]), priority=5,
            sampling=SamplingParams(max_new=max_new)))
        while srv.step():
            pass
        events = tracer.drain()
        # swap events carry (rid, pages) in (a0, a1)
        swap_out = int(sum(e[4] for e in events
                           if e[2] == EventType.SWAP_OUT))
        swap_in = int(sum(e[4] for e in events if e[2] == EventType.SWAP_IN))
        return ({r.rid: list(r.tokens) for r in srv.finished}, srv,
                swap_out, swap_in)

    ref_out, _, _, _ = run(4 * per_seq)          # uncontended reference
    out, srv, swap_out, swap_in = run(per_seq + per_seq // 2)
    return {
        "completed": len(out) == 2,
        "outputs_match_uncontended": out == ref_out,
        "preemptions": srv.preemptions,
        "swap_out_pages": swap_out,
        "swap_in_pages": swap_in,
        "swap_bytes_out": srv.backing.bytes_out,
        "swap_bytes_in": srv.backing.bytes_in,
    }


def run_fault_storm(cfg, params, *, page_size, max_lanes, use_kernel,
                    requests=8, prompt_len=10, max_new=6, chunk=4,
                    rate=0.4, seed=23) -> dict:
    """Seeded fault storm: the graceful-degradation workload.

    A fault-free reference run over a generous pool pins down the
    canonical greedy outputs.  The storm run then serves the same
    prompts through a deliberately hostile configuration:

    * a tight pool + mixed priorities + one forced mid-stream
      preemption, so pages actually travel through the backing store
      where the ``FaultInjector`` lives;
    * transient I/O faults at ``rate`` (seeded — the whole storm is
      deterministic) recovered by bounded retry, plus two *planted*
      corruption faults on the first swap-out, caught by checksum at
      swap-in and demoting exactly that request to ``"error"``;
    * one request with a deadline it cannot meet (``deadline_iters`` —
      never wall-clock ``deadline_s``, which would be nondeterministic),
      one cancelled from the streaming loop body, and a queue depth one
      short of the workload so the lowest-priority newest arrival is
      shed at admission.

    Everything the gate needs comes back: goodput (completed/submitted),
    completed-within-deadline fraction, retry/recovery counters, the
    layer-2 fault-recovery report, survivor token parity against the
    reference, fault containment, pool invariants, and the
    unhandled-exception count (must be zero — faults demote requests,
    they never escape the engine)."""
    prompts = _make_prompts(requests, prompt_len, cfg.vocab_size, seed=29)
    per_seq = -(-(prompt_len + max_new) // page_size) + 1
    ref = run_engine(cfg, params, prompts, chunk=chunk, max_new=max_new,
                     num_pages=per_seq * requests + 8, page_size=page_size,
                     max_lanes=max_lanes, max_pages_per_seq=per_seq,
                     use_kernel=use_kernel, enable_prefix_cache=False)
    ref_outputs = ref.pop("outputs")

    inj = FaultInjector(
        seed=seed, rate=rate, kinds=(FaultSpec("io"),),
        plan={0: FaultSpec("corrupt", op="put"),
              1: FaultSpec("corrupt", op="put")})
    tracer = TraceBuffer(capacity=1 << 16)
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(
            num_pages=per_seq * max_lanes + max(per_seq // 2, 1),
            page_size=page_size, max_pages_per_seq=per_seq,
            enable_prefix_cache=False),
        max_lanes=max_lanes, chunk=chunk, use_kernel=use_kernel,
        fault_injector=inj, swap_retries=3, retry_backoff_s=0.0,
        max_queue_depth=requests - 1, watchdog_iters=256), tracer=tracer)

    unhandled = 0
    unhandled_detail = []
    deltas = preempts = 0
    did_cancel = False
    t0 = time.perf_counter()
    try:
        for rid, p in enumerate(prompts):
            srv.submit(GenerationRequest(
                rid=rid, prompt=tuple(p), priority=rid % 3,
                sampling=SamplingParams(max_new=max_new),
                deadline_iters=3 if rid == 1 else 500))
        for _ in srv.generate():
            deltas += 1
            # all requests arrive up front, so the scheduler alone never
            # preempts (the highest-priority lanes are already running) —
            # force checkpoint/restore traffic through the faulty backing
            # store on a fixed cadence instead
            if preempts < 4 and deltas % 4 == 2:
                victim = next((r for r in srv.lanes if r is not None
                               and not r.done and r.rid not in (1, 2)),
                              None)
                if victim is not None:
                    srv.preempt(victim.rid)
                    preempts += 1
            if not did_cancel and deltas >= 5:
                did_cancel = srv.cancel(2)
    except Exception as e:        # noqa: BLE001 — the property under test
        unhandled += 1
        unhandled_detail.append(f"{type(e).__name__}: {e}")
    dt = time.perf_counter() - t0

    res = {r.rid: r for r in srv.finished}
    reasons: dict = {}
    for r in res.values():
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    survivors = {rid: list(r.tokens) for rid, r in res.items()
                 if r.finish_reason in ("stop", "length")}
    parity = all(toks == ref_outputs[rid]
                 for rid, toks in survivors.items())
    events = layer1_decode(tracer.drain())
    recovery = layer2_fault_recovery(events)
    invariants_ok = True
    try:
        srv.pool.check_invariants()
    except AssertionError as e:
        invariants_ok = False
        unhandled_detail.append(f"pool invariants: {e}")
    return {
        "workload": {"requests": requests, "prompt_len": prompt_len,
                     "max_new": max_new, "chunk": chunk,
                     "fault_rate": rate, "fault_seed": seed,
                     "tight_deadline_rid": 1, "cancel_rid": 2,
                     "max_queue_depth": requests - 1},
        "reference_tokens_per_s": ref["tokens_per_s"],
        "storm_wall_s": dt,
        "iterations": srv.iterations,
        "finish_reasons": reasons,
        "submitted": requests,
        "completed": len(survivors),
        "goodput": len(survivors) / requests,
        # of the requests the engine actually attempted (not shed at
        # admission, not cancelled by the client), the fraction that met
        # their deadline and completed
        "within_deadline_fraction":
            len(survivors) / max(requests - srv.shed_count -
                                 srv.cancelled, 1),
        "survivor_parity": parity,
        "unhandled_exceptions": unhandled,
        "unhandled_detail": unhandled_detail,
        "faults_injected": inj.report(),
        "fault_retries": srv.fault_retries,
        "recovered_faults": srv.recovered_faults,
        "timeouts": srv.timeouts,
        "cancelled": srv.cancelled,
        "errors": srv.errors,
        "shed": srv.shed_count,
        "degrades": srv.degrades,
        "recovery": {k: v for k, v in recovery.items() if k != "requests"},
        "faults_contained": assert_faults_contained(events),
        "pool_invariants_ok": invariants_ok,
        "backing_store_empty": len(srv.backing) == 0,
    }


def _make_tenant_prompts(tenants, visits, sys_len, tail_len, vocab, seed=17):
    """Long-tailed multi-tenant workload: each tenant owns a distinct
    page-aligned system prompt; visits are Zipf-weighted (a few hot
    tenants, a long tail of cold ones) with a unique per-visit user tail
    so only the system prefix is shareable."""
    rng = np.random.default_rng(seed)
    systems = [rng.integers(1, vocab, size=sys_len).tolist()
               for _ in range(tenants)]
    weights = 1.0 / np.arange(1, tenants + 1)
    weights /= weights.sum()
    order = rng.choice(tenants, size=visits, p=weights)
    prompts = [systems[int(t)] +
               rng.integers(1, vocab, size=tail_len).tolist()
               for t in order]
    return prompts, [int(t) for t in order]


def run_hierarchical_cache(cfg, params, *, page_size, use_kernel,
                           tenants=16, visits=24, max_new=4, tail_len=2,
                           chunk=4, max_lanes=2) -> dict:
    """Tiered prefix cache vs device-only over a prefix corpus ~4x the
    device pool.

    The tenant corpus cannot fit on device, so the device-only engine
    keeps evicting (dropping) cold tenants' prefix pages and re-prefilling
    them on the next visit.  The tiered engine demotes evicted pages to a
    host tier and, under host pressure, to a disk tier; a later visit
    hits the index, admits immediately, and the payload is promoted back
    H2D asynchronously on the engine clock.  Both runs ride a
    ``VirtualClock`` (promotion latency is modeled, not slept) and must
    produce token-identical outputs."""
    sys_len = 4 * page_size                   # 4 full pages per tenant
    prompts, order = _make_tenant_prompts(tenants, visits, sys_len,
                                          tail_len, cfg.vocab_size)
    corpus_pages = tenants * (sys_len // page_size)
    num_pages = corpus_pages // 4             # corpus is 4x the device pool
    per_seq = -(-(sys_len + tail_len + max_new) // page_size) + 1
    prompt_tokens = sum(len(p) for p in prompts)

    def run(tiered):
        tmp = tempfile.mkdtemp(prefix="bench_hier_disk_") if tiered else None
        srv = None
        try:
            engine_cfg = EngineConfig(
                cache=CacheConfig(
                    num_pages=num_pages, page_size=page_size,
                    max_pages_per_seq=per_seq,
                    host_tier_pages=corpus_pages // 4 if tiered else 0,
                    disk_tier_pages=2 * corpus_pages if tiered else 0,
                    disk_dir=tmp, prefetch_depth=2,
                    promote_latency_s=0.002 if tiered else 0.0),
                max_lanes=max_lanes, chunk=chunk, use_kernel=use_kernel,
                clock=VirtualClock())
            srv = make_engine(cfg, params, engine_cfg)
            for rid, p in enumerate(prompts):
                srv.submit(GenerationRequest(
                    rid=rid, prompt=tuple(p),
                    sampling=SamplingParams(max_new=max_new)))
            done = srv.run()
            assert len(done) == len(prompts), "workload did not drain"
            cs = srv.cache_stats()
            hits = (cs.hits_device_pages + cs.hits_host_pages +
                    cs.hits_disk_pages)
            lookups = hits + cs.miss_pages
            return {
                "iterations": srv.iterations,
                "virtual_duration_s": round(srv.clock.now(), 9),
                "prefill_tokens": srv.prefill_tokens,
                "prefix_hit_tokens": cs.prefix_hit_tokens,
                "prefix_hit_rate": hits / max(lookups, 1),
                "hits_device_pages": cs.hits_device_pages,
                "hits_host_pages": cs.hits_host_pages,
                "hits_disk_pages": cs.hits_disk_pages,
                "miss_pages": cs.miss_pages,
                "demoted_pages": cs.demoted_pages,
                "promoted_pages": cs.promoted_pages,
                "dropped_entries": cs.dropped_entries,
                "bytes_demoted": cs.bytes_demoted,
                "bytes_promoted": cs.bytes_promoted,
                "outputs": {r.rid: list(r.tokens) for r in done},
            }
        finally:
            if srv is not None:
                srv.close()
            if tmp is not None:
                shutil.rmtree(tmp, ignore_errors=True)

    device_only = run(tiered=False)
    tiered = run(tiered=True)
    token_parity = device_only.pop("outputs") == tiered.pop("outputs")
    return {
        "workload": {"tenants": tenants, "visits": visits,
                     "sys_len": sys_len, "tail_len": tail_len,
                     "max_new": max_new, "page_size": page_size,
                     "device_pages": num_pages,
                     "corpus_pages": corpus_pages,
                     "prompt_tokens": prompt_tokens},
        "corpus_to_pool_ratio": corpus_pages / num_pages,
        "device_only": device_only,
        "tiered": tiered,
        "token_parity": token_parity,
        "prefix_hit_rate": tiered["prefix_hit_rate"],
        "prefill_tokens_saved":
            device_only["prefill_tokens"] - tiered["prefill_tokens"],
    }


def run_latency_workload(cfg, params, *, smoke: bool) -> dict:
    """Live-traffic latency section: the seeded open-loop load generator
    (Poisson arrivals, uniform prompt/output lengths) replayed through
    the front door on a virtual clock.  The workload is run TWICE on
    fresh engines with the same seed and the serialized reports must be
    byte-identical (``replay_identical``) — on a virtual clock the
    latency distribution is a pure function of (seed, engine config),
    which is what makes the p95/p99 gates in ``check_bench`` meaningful
    on shared CI runners."""
    if smoke:
        knobs = dict(rate_rps=50.0, requests=8, prompt_min=4,
                     prompt_max=12, output_min=2, output_max=5,
                     page_size=4, max_lanes=2, chunk=4, token_budget=6)
    else:
        knobs = dict(rate_rps=100.0, requests=32, prompt_min=8,
                     prompt_max=24, output_min=4, output_max=12,
                     page_size=4, max_lanes=4, chunk=8, token_budget=12)
    knobs.update(seed=0, iter_time_s=0.01, slo_ttft_s=0.25,
                 slo_tpot_s=0.05, cfg=cfg, params=params)
    first = load_gen.run_load_gen(**knobs)
    replay = load_gen.run_load_gen(**knobs)
    identical = json.dumps(first, sort_keys=True) == \
        json.dumps(replay, sort_keys=True)
    return {**first, "replay_identical": identical}


def run_cluster_sweep(cfg, params, prompts, *, max_clusters, heads, common,
                      unsharded_outputs, trace_events=None) -> dict:
    """Serve the same workload on the sharded engine at 1..max_clusters
    clusters (per-cluster pool/lane budget held fixed, so capacity scales
    with C).  The 1-cluster configuration must match the unsharded engine
    token-for-token."""
    configs, skipped = {}, {}
    match_1 = None
    for C in (1, 2, 4, 8):
        if C > max_clusters:
            continue
        need = C * heads
        if need > len(jax.devices()):
            skipped[str(C)] = (f"needs {need} devices, "
                               f"{len(jax.devices())} visible")
            continue
        keep = trace_events.setdefault(f"clusters={C}", []) \
            if trace_events is not None else None
        r = run_engine(cfg, params, prompts, clusters=C, heads=heads,
                       keep_events=keep, **common)
        outputs = r.pop("outputs")
        if C == 1:
            match_1 = outputs == unsharded_outputs
        configs[str(C)] = r
    return {
        "heads": heads,
        "configs": configs,
        "skipped": skipped,
        "one_cluster_outputs_match_unsharded": match_1,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-lanes", type=int, default=4)
    ap.add_argument("--kernel", action="store_true",
                    help="force the Pallas kernels (default: kernels on TPU, "
                         "XLA reference path elsewhere — engine structure and "
                         "transfer counts are identical either way)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny workload, seconds on CPU")
    ap.add_argument("--clusters", type=int, default=1,
                    help="sweep the sharded engine over {1,2,4,8} clusters "
                         "up to this count (forces 8 virtual CPU devices "
                         "when launched as a script)")
    ap.add_argument("--heads", type=int, default=1,
                    help="tensor-parallel head shards per cluster "
                         "(must divide num_kv_heads)")
    ap.add_argument("--trace-out", default=None,
                    help="write the cluster sweep's drained trace events "
                         "to this JSON file (nightly CI artifact)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft depth for the speculative-decoding workload")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests, args.prompt_len, args.max_new = 3, 12, 4
        args.chunk, args.page_size, args.max_lanes = 8, 4, 2
        k_prefixes, m_per_prefix, sys_len, user_len = 2, 3, 8, 3
        spec_max_new, spec_reps = 12, 3
        sample_reqs, sample_max_new = 3, 6
        storm_reqs, storm_max_new = 8, 6
    else:
        k_prefixes, m_per_prefix, sys_len, user_len = 4, 8, 64, 16
        spec_max_new, spec_reps = 32, 6
        sample_reqs, sample_max_new = 8, 16
        storm_reqs, storm_max_new = 12, 8

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _make_prompts(args.requests, args.prompt_len, cfg.vocab_size)

    per_seq = -(-(args.prompt_len + args.max_new) // args.page_size) + 1
    num_pages = per_seq * args.max_lanes + 8
    use_kernel = args.kernel or jax.default_backend() == "tpu"
    common = dict(max_new=args.max_new, num_pages=num_pages,
                  page_size=args.page_size, max_lanes=args.max_lanes,
                  max_pages_per_seq=per_seq, use_kernel=use_kernel)

    baseline = run_engine(cfg, params, prompts, chunk=1, **common)
    chunked = run_engine(cfg, params, prompts, chunk=args.chunk, **common)
    chunked_outputs = chunked["outputs"]

    # shared-prefix workload: K system prompts x M requests, caching off/on
    sp_prompts = _make_shared_prefix_prompts(
        k_prefixes, m_per_prefix, sys_len, user_len, cfg.vocab_size)
    sp_len = sys_len + user_len
    sp_per_seq = -(-(sp_len + args.max_new) // args.page_size) + 1
    # chunk below the system-prompt length so skipped prefill also shows up
    # as fewer engine iterations, not only as fewer prefill tokens
    sp_chunk = min(args.chunk, max(sys_len // 4, 8))
    sp_common = dict(max_new=args.max_new,
                     num_pages=sp_per_seq * args.max_lanes + 8,
                     page_size=args.page_size, max_lanes=args.max_lanes,
                     max_pages_per_seq=sp_per_seq, use_kernel=use_kernel,
                     chunk=sp_chunk)
    no_share = run_engine(cfg, params, sp_prompts,
                          enable_prefix_cache=False, **sp_common)
    shared = run_engine(cfg, params, sp_prompts,
                        enable_prefix_cache=True, **sp_common)
    outputs_match = no_share.pop("outputs") == shared.pop("outputs")

    preemption = run_preemption_probe(cfg, params, page_size=args.page_size,
                                      max_new=args.max_new,
                                      use_kernel=use_kernel)

    speculation = run_spec_workload(cfg, params, spec_k=args.spec_k,
                                    max_new=spec_max_new, reps=spec_reps,
                                    page_size=args.page_size,
                                    max_lanes=args.max_lanes,
                                    use_kernel=use_kernel)

    quantized = run_quantized_kv(cfg, params, page_size=args.page_size,
                                 max_lanes=args.max_lanes,
                                 use_kernel=use_kernel)

    sampling = run_sampling_workload(cfg, params, max_new=sample_max_new,
                                     page_size=args.page_size,
                                     max_lanes=args.max_lanes,
                                     use_kernel=use_kernel,
                                     requests=sample_reqs)

    degradation = run_fault_storm(cfg, params, page_size=args.page_size,
                                  max_lanes=args.max_lanes,
                                  use_kernel=use_kernel,
                                  requests=storm_reqs,
                                  max_new=storm_max_new)

    hier = run_hierarchical_cache(cfg, params, page_size=args.page_size,
                                  use_kernel=use_kernel,
                                  visits=24 if args.smoke else 48)

    latency = run_latency_workload(cfg, params, smoke=args.smoke)

    trace_events = {} if args.trace_out else None
    sweep = run_cluster_sweep(
        cfg, params, prompts, max_clusters=args.clusters, heads=args.heads,
        common=dict(common, chunk=args.chunk),
        unsharded_outputs=chunked_outputs, trace_events=trace_events)

    baseline.pop("outputs", None)
    chunked.pop("outputs", None)
    result = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "use_kernel": use_kernel,
        "workload": {"requests": args.requests,
                     "prompt_len": args.prompt_len,
                     "max_new": args.max_new,
                     "page_size": args.page_size,
                     "max_lanes": args.max_lanes},
        "baseline_token_by_token": baseline,
        "chunked_prefill": chunked,
        "iters_per_request_reduction":
            baseline["iters_per_request"] / chunked["iters_per_request"],
        "tokens_per_s_speedup":
            chunked["tokens_per_s"] / max(baseline["tokens_per_s"], 1e-9),
        "shared_prefix": {
            "workload": {"k_prefixes": k_prefixes,
                         "m_per_prefix": m_per_prefix,
                         "sys_len": sys_len, "user_len": user_len},
            "baseline_no_sharing": no_share,
            "prefix_cached": shared,
            "outputs_match": outputs_match,
            "prefix_hit_rate": shared["prefix_hit_rate"],
            "pages_saved": shared["pages_saved"],
            "prefill_tokens_saved":
                no_share["prefill_tokens"] - shared["prefill_tokens"],
            "prefill_iters_reduction":
                no_share["iterations"] / max(shared["iterations"], 1),
            "tokens_per_s_speedup":
                shared["tokens_per_s"] / max(no_share["tokens_per_s"], 1e-9),
        },
        "preemption": preemption,
        "speculation": speculation,
        "quantized_kv": quantized,
        "sampling": sampling,
        "degradation": degradation,
        "hierarchical_cache": hier,
        "latency": latency,
        "cluster_sweep": sweep,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            json.dump({"schema": ["ts", "tracer", "etype", "a0", "a1"],
                       "event_types": {e.name: int(e) for e in EventType},
                       "events": trace_events}, f)

    print(f"# serve_throughput ({cfg.name}, {jax.default_backend()}, "
          f"kernel={use_kernel})")
    for tag, r in (("token-by-token", baseline), ("chunked", chunked),
                   ("no-sharing", no_share), ("prefix-cached", shared)):
        print(f"{tag:>16s}: chunk={r['chunk']:<4d} "
              f"iters/req={r['iters_per_request']:6.1f}  "
              f"tok/s={r['tokens_per_s']:8.1f}  "
              f"h2d/tok={r['h2d_per_generated_token']:5.2f}  "
              f"d2h/tok={r['d2h_per_generated_token']:5.2f}")
    print(f"iters/request reduction: "
          f"{result['iters_per_request_reduction']:.2f}x   "
          f"tokens/s speedup: {result['tokens_per_s_speedup']:.2f}x")
    sp = result["shared_prefix"]
    print(f"shared-prefix: hit-rate={sp['prefix_hit_rate']:.2f}  "
          f"pages saved={sp['pages_saved']}  "
          f"cow={shared['cow_pages']}  "
          f"prefill tokens saved={sp['prefill_tokens_saved']}  "
          f"iters reduction={sp['prefill_iters_reduction']:.2f}x  "
          f"outputs match={sp['outputs_match']}")
    pr = result["preemption"]
    print(f"preemption: completed={pr['completed']}  "
          f"outputs match={pr['outputs_match_uncontended']}  "
          f"swapped out/in={pr['swap_out_pages']}/{pr['swap_in_pages']} "
          f"pages")
    sd = result["speculation"]
    print(f"speculation (k={args.spec_k}): "
          f"iters/token={sd['spec_off']['iters_per_generated_token']:.3f}"
          f"->{sd['spec_on']['iters_per_generated_token']:.3f} "
          f"({sd['iters_per_token_reduction']:.2f}x)  "
          f"acceptance={sd['acceptance_rate']:.2f}  "
          f"wasted verify tokens={sd['wasted_verify_tokens']}  "
          f"outputs match={sd['outputs_match']}")
    qk = result["quantized_kv"]
    print(f"quantized kv (int8): bytes/tok="
          f"{qk['bytes_per_token_bf16']:.0f}->"
          f"{qk['bytes_per_token_int8']:.0f} "
          f"(ratio={qk['bytes_per_token_ratio']:.3f}, "
          f"headroom={qk['page_pool_headroom']:.2f}x)  "
          f"token agreement={qk['token_agreement']:.4f} "
          f"({qk['workload']['teacher_forced_positions']} pos)  "
          f"kernel==ref={qk['kernel_ref_outputs_match']}  "
          f"free-running match={qk['free_running_outputs_match']}")
    sa = result["sampling"]
    print(f"sampling (T={sa['workload']['temperature']}, "
          f"top-p={sa['workload']['top_p']}): "
          f"greedy iters/token="
          f"{sa['greedy']['iters_per_generated_token']:.3f}  "
          f"sampled iters/token="
          f"{sa['sampled']['iters_per_generated_token']:.3f}  "
          f"reproducible={sa['sampled_reproducible']}  "
          f"stop-token early exit={sa['stop_token_early_exit']} "
          f"({sa['stop_tokens_generated']} tok)")
    dg = result["degradation"]
    print(f"fault storm (rate={dg['workload']['fault_rate']}, "
          f"seed={dg['workload']['fault_seed']}): "
          f"goodput={dg['goodput']:.2f}  "
          f"within-deadline={dg['within_deadline_fraction']:.2f}  "
          f"faults={dg['faults_injected']['injected']} "
          f"retries={dg['fault_retries']} "
          f"recovered={dg['recovered_faults']}  "
          f"timeouts={dg['timeouts']} cancelled={dg['cancelled']} "
          f"errors={dg['errors']} shed={dg['shed']}  "
          f"parity={dg['survivor_parity']} "
          f"contained={dg['faults_contained']} "
          f"unhandled={dg['unhandled_exceptions']}")
    hc = result["hierarchical_cache"]
    print(f"hierarchical cache (corpus={hc['workload']['corpus_pages']}p, "
          f"device={hc['workload']['device_pages']}p, "
          f"ratio={hc['corpus_to_pool_ratio']:.1f}x): "
          f"hit-rate={hc['device_only']['prefix_hit_rate']:.2f}"
          f"->{hc['tiered']['prefix_hit_rate']:.2f}  "
          f"hits dev/host/disk={hc['tiered']['hits_device_pages']}/"
          f"{hc['tiered']['hits_host_pages']}/"
          f"{hc['tiered']['hits_disk_pages']}  "
          f"demoted={hc['tiered']['demoted_pages']} "
          f"promoted={hc['tiered']['promoted_pages']}  "
          f"prefill tokens saved={hc['prefill_tokens_saved']}  "
          f"parity={hc['token_parity']}")
    lt = result["latency"]
    print(f"latency (rate={lt['workload']['rate_rps']} rps, "
          f"budget={lt['workload']['token_budget']}): "
          f"ttft p50/p95/p99={lt['ttft_p50_s']:.3f}/{lt['ttft_p95_s']:.3f}/"
          f"{lt['ttft_p99_s']:.3f}s  "
          f"tpot p50/p95/p99={lt['tpot_p50_s']:.3f}/{lt['tpot_p95_s']:.3f}/"
          f"{lt['tpot_p99_s']:.3f}s  "
          f"slo goodput={lt['slo_goodput']:.2f}  "
          f"replay identical={lt['replay_identical']}")
    for C, r in sweep["configs"].items():
        print(f"clusters={C:>2s} (x{sweep['heads']} heads): "
              f"iters/req={r['iters_per_request']:6.1f}  "
              f"tok/s={r['tokens_per_s']:8.1f}  "
              f"peak pages/cluster={r['peak_pages_per_cluster']}  "
              f"balance={r['dispatch_balance']:.2f}")
    for C, why in sweep["skipped"].items():
        print(f"clusters={C:>2s}: skipped ({why})")
    assert sp["outputs_match"], "prefix caching changed outputs"
    assert pr["completed"] and pr["outputs_match_uncontended"], \
        "preemption run incorrect"
    assert sd["outputs_match"], "speculative decoding changed outputs"
    assert sd["spec_on"]["iters_per_generated_token"] < \
        sd["spec_off"]["iters_per_generated_token"], \
        "speculation did not reduce engine iterations per token"
    assert qk["bytes_per_token_ratio"] <= 0.6, \
        "int8 KV pool did not halve the per-token cache footprint"
    assert qk["token_agreement"] >= 0.98, \
        "int8 KV teacher-forced token agreement fell below 0.98"
    assert qk["kernel_ref_outputs_match"], \
        "int8 kernel and oracle attention paths diverged"
    assert sa["sampled_reproducible"], \
        "seeded sampled decoding was not reproducible"
    assert sa["stop_token_early_exit"], "stop token did not end the request"
    assert dg["unhandled_exceptions"] == 0, \
        f"fault storm escaped the engine: {dg['unhandled_detail']}"
    assert dg["survivor_parity"], \
        "fault-storm survivors diverged from the fault-free reference"
    assert dg["faults_contained"], \
        "a faulted request never reached REQUEST_FINISH"
    assert dg["pool_invariants_ok"] and dg["backing_store_empty"], \
        "fault storm leaked pool or backing-store state"
    assert hc["token_parity"], \
        "tiered prefix cache changed outputs vs device-only"
    assert hc["tiered"]["prefix_hit_rate"] > \
        hc["device_only"]["prefix_hit_rate"], \
        "tiered cache did not beat device-only hit rate"
    assert hc["corpus_to_pool_ratio"] >= 4, \
        "hierarchical-cache corpus must be >= 4x the device pool"
    assert lt["replay_identical"], \
        "same-seed latency replays diverged (virtual clock leaked wall time)"
    assert lt["completed"] == lt["requests"], \
        "latency workload did not drain"
    assert sweep["one_cluster_outputs_match_unsharded"] is not False, \
        "1-cluster sharded engine diverged from the unsharded engine"
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
