"""Tab.2 analogue: per-configuration resource utilization.

The FPGA resource table (LUT/FF/DSP/BRAM, clusters vs top-level) maps to the
AOT compile's per-device memory accounting: model state (params + optimizer
+ cache = the 'clusters') vs runtime overhead (temporaries, code = the 'top
level & host interface').  The paper's finding — clusters dominate (>80-90%)
— is checked against the same split.

Also prints the PMCA configuration space (Tab.1) sizes via the config graph.
"""
from __future__ import annotations


from repro.configs import SHAPES, get_config
from repro.configs.hero_pmca import pmca_config_space, JUNO_ADP, ZC706
from benchmarks.roofline import param_counts, cache_bytes, load_cell


def main():
    print("# Tab.1 analogue: PMCA config space (graph-flattened)")
    g = pmca_config_space()
    print(f"config axes: {len(g.axes)}; flattened cells: {len(g)}")
    print(f"juno_adp preset: {JUNO_ADP}")
    print(f"zc706 preset: {ZC706}")

    print("\n# Tab.2 analogue: model state vs runtime overhead per device")
    print("arch,shape,model_state_gib,runtime_overhead_gib,model_state_pct")
    for arch in ("yi-6b", "qwen3-32b", "deepseek-v2-236b", "gemma2-2b"):
        cfg = get_config(arch)
        for shape_name in ("train_4k", "decode_32k"):
            rec = load_cell(arch, shape_name, "single")
            if not rec or rec.get("status") != "ok":
                continue
            dev = rec["devices"]
            n = param_counts(cfg)["total"]
            shape = SHAPES[shape_name]
            if shape.kind == "train":
                state = n * (2 + 12) / dev  # bf16 params + fp32 m/v(+master)
            else:
                state = n * 2 / dev + cache_bytes(cfg, shape) / dev
            overhead = rec["memory"]["temp_size_in_bytes"] or 0
            pct = 100 * state / max(state + overhead, 1)
            print(f"{arch},{shape_name},{state/2**30:.2f},"
                  f"{overhead/2**30:.2f},{pct:.1f}")
    print("\nNOTE: runtime overhead ('temp') from the CPU-backend buffer "
          "assignment over-estimates the TPU target (f32 legalization + no "
          "memory-aware scheduling); see EXPERIMENTS.md §Dry-run caveats.")


if __name__ == "__main__":
    main()
