"""Quickstart: train a tiny model, checkpoint it, decode from it.

    PYTHONPATH=src python examples/quickstart.py [--arch yi-6b] [--steps 20]
"""
import argparse
import tempfile

import jax.numpy as jnp

from repro.configs import get_config, smoke_shape
from repro.data import MarkovChainData
from repro.models import model as M
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    shape = smoke_shape("train")
    data = MarkovChainData(cfg, shape, seed=0)
    ckpt = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    trainer = Trainer(cfg, shape, data,
                      TrainerConfig(total_steps=args.steps, ckpt_every=10,
                                    ckpt_dir=ckpt, log_every=5))
    res = trainer.run()
    for m in res["metrics"]:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  {m['step_s']*1e3:.0f} ms")

    # greedy-decode a few tokens from the trained model
    params = res["state"]["params"]
    T = 16
    cache = M.init_cache(cfg, 1, T)
    tok = jnp.array([[1]], jnp.int32)
    out = []
    for t in range(8):
        logits, cache = M.decode_forward(cfg, params, cache, tok,
                                         jnp.array([t], jnp.int32))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy continuation:", out)
    print(f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
