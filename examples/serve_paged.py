"""Serve a small model with batched requests through the paged engine:
continuous batching + RAB translation + paged-attention kernel + tracing.

    PYTHONPATH=src python examples/serve_paged.py [--requests 8] [--kernel]
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.analysis import layer1_decode, layer2_tlb_transactions, \
    render_timeline
from repro.models import model as M
from repro.runtime import PagedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4,
                    help="prompt tokens consumed per engine iteration "
                         "(chunked prefill)")
    ap.add_argument("--kernel", action="store_true",
                    help="use the Pallas paged-attention kernels "
                         "(interpret mode on CPU; slower but exercises them)")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = PagedServer(cfg, params, num_pages=64, page_size=4, max_lanes=4,
                      max_pages_per_seq=16, chunk=args.chunk,
                      use_kernel=args.kernel)
    for rid in range(args.requests):
        srv.submit(Request(rid=rid, prompt=[1 + rid, 7, 3, 11], max_new=6))
    done = srv.run()

    print(f"# served {len(done)} requests (lanes=4, pages=64x4, "
          f"chunk={args.chunk}) in {srv.iterations} engine iterations "
          f"(h2d={srv.h2d_events}, d2h={srv.d2h_events})")
    for r in done:
        print(f"req {r.rid}: prompt {r.prompt} -> {r.out}")
    print("\n# RAB:", srv.rab.stats)
    events = layer1_decode(srv.tracer.drain())
    print(f"\n# {len(events)} events; TLB transactions (first 10):")
    for tx in layer2_tlb_transactions(events)[:10]:
        print(tx)
    print("\n# timeline (truncated)")
    print(render_timeline(events, max_rows=12)[:2000])


if __name__ == "__main__":
    main()
