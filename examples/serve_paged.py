"""Serve a small model through the paged engine's unified generation API:
continuous batching + RAB translation + shared-prefix KV caching +
priority preemption + per-request sampling + live token streaming.

Requests share a common system prompt, so later admissions hit the prefix
cache and skip most of their prefill; one request decodes with
temperature/top-p sampling (on device, seed-reproducible) while the rest
stay greedy; a late high-priority request lands in a deliberately tight
pool and preempts a running lane (its pages swap to the host backing
store and back).  Everything is observed LIVE through
``engine.generate()`` — the stream of ``TokenDelta``s (tokens, prefix
hits, preemptions) is printed as it happens, and its per-request
concatenation is asserted identical to the final ``GenerationResult``s.

    PYTHONPATH=src python examples/serve_paged.py [--requests 8] [--kernel]
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.analysis import layer1_decode, layer2_tlb_transactions, \
    layer2_request_lifecycles, render_timeline
from repro.models import model as M
from repro.runtime import (
    CacheConfig, EngineConfig, GenerationRequest, SamplingParams,
    make_engine,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4,
                    help="prompt tokens consumed per engine iteration "
                         "(chunked prefill)")
    ap.add_argument("--kernel", action="store_true",
                    help="use the Pallas paged-attention kernels "
                         "(interpret mode on CPU; slower but exercises them)")
    ap.add_argument("--no-prefix-cache", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=24, page_size=4,
                          max_pages_per_seq=16,
                          enable_prefix_cache=not args.no_prefix_cache),
        max_lanes=2, chunk=args.chunk, use_kernel=args.kernel))
    system = [9, 9, 8, 2, 5, 5, 1, 3]          # the shared "system prompt"
    requests = []
    for rid in range(args.requests):
        # one sampled lane in the greedy crowd: rid 1 decodes at
        # temperature 0.7 with nucleus truncation, reproducible from seed
        sampling = SamplingParams(temperature=0.7, top_p=0.9, seed=11,
                                  max_new=6) if rid == 1 else \
            SamplingParams(max_new=6)
        requests.append(GenerationRequest(rid=rid, prompt=system + [20 + rid],
                                          sampling=sampling))

    streamed: dict = {}
    stream = srv.generate(requests)
    for i, delta in enumerate(stream):
        streamed.setdefault(delta.rid, []).extend(delta.tokens)
        tag = f" [{delta.event}]" if delta.event != "token" else ""
        fin = f" -> {delta.finish_reason}" if delta.finish_reason else ""
        print(f"delta {i:3d}: req {delta.rid} +{list(delta.tokens)}"
              f"{tag}{fin}")
        if i == 8:
            # a late VIP request into a busy pool: the scheduler preempts a
            # lane; submissions can land mid-stream
            srv.submit(GenerationRequest(
                rid=99, prompt=[4, 2] * 8, priority=5,
                sampling=SamplingParams(max_new=6)))
            print("delta   —: submitted VIP req 99 mid-stream")
    done = srv.finished

    # the streamed deltas ARE the results — token-for-token
    assert {r.rid: list(r.tokens) for r in done} == streamed, \
        "delta concatenation diverged from final results"

    print(f"\n# served {len(done)} requests (lanes=2, pages=24x4, "
          f"chunk={args.chunk}) in {srv.iterations} engine iterations "
          f"(h2d={srv.h2d_events}, d2h={srv.d2h_events}, "
          f"preemptions={srv.preemptions})")
    for r in sorted(done, key=lambda r: r.rid):
        tag = f" [prefix hit {r.prefix_hit_tokens} tok]" \
            if r.prefix_hit_tokens else ""
        tag += f" [preempted x{r.preemptions}]" if r.preemptions else ""
        print(f"req {r.rid}: prompt {list(r.prompt)} -> {list(r.tokens)} "
              f"[{r.finish_reason}]{tag}")
    print("\n# RAB:", srv.rab.stats)
    print("# pool:", srv.pool.stats)
    print(f"# backing store: {srv.backing.bytes_out} B out, "
          f"{srv.backing.bytes_in} B in")
    events = layer1_decode(srv.tracer.drain())
    print(f"\n# {len(events)} events; TLB transactions (first 10):")
    for tx in layer2_tlb_transactions(events)[:10]:
        print(tx)
    print("\n# request lifecycles (admit/preempt/swap_in/finish):")
    for rid, spans in sorted(layer2_request_lifecycles(events).items()):
        print(f"req {rid}: " + " -> ".join(s["kind"] for s in spans))
    print("\n# timeline (truncated)")
    print(render_timeline(events, max_rows=12)[:2000])


if __name__ == "__main__":
    main()
