"""Offload semantics demo (paper §2.2/Fig.5): the same kernel via
copy-based SM vs zero-copy SVM, with the traced offload protocol.

    PYTHONPATH=src python examples/svm_offload_demo.py
"""
import jax
import numpy as np

from repro.core import OffloadTarget, TraceBuffer
from repro.core.analysis import layer1_decode
from repro.kernels.cluster_matmul import cluster_matmul


def main():
    tgt = OffloadTarget(tracer=TraceBuffer())
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 256)).astype(np.float32)
    b = rng.standard_normal((256, 256)).astype(np.float32)

    def kern(a, b):
        return cluster_matmul(a, b, interpret=True)

    out_c, rep_c = tgt.run_copy_based(kern, a, b)
    print(f"copy-based : offload {rep_c.offload_s*1e3:7.2f} ms  "
          f"kernel {rep_c.kernel_s*1e3:7.2f} ms  "
          f"writeback {rep_c.writeback_s*1e3:6.2f} ms  "
          f"({rep_c.bytes_to/2**20:.1f} MiB staged)")

    ha, hb = tgt.svm.share(jax.device_put(a)), tgt.svm.share(jax.device_put(b))
    out_h, rep_z = tgt.run_zero_copy(kern, ha, hb)
    print(f"zero-copy  : offload {rep_z.offload_s*1e3:7.2f} ms  "
          f"kernel {rep_z.kernel_s*1e3:7.2f} ms  (pointer pass only)")
    print(f"total reduction: "
          f"{100*(1 - rep_z.total_s/rep_c.total_s):.1f}%")

    np.testing.assert_allclose(out_c, np.asarray(tgt.svm.deref(out_h)),
                               rtol=1e-4, atol=1e-4)
    print("results identical across offload modes ✓")
    print(f"{len(layer1_decode(tgt.tracer.drain()))} protocol events traced")


if __name__ == "__main__":
    main()
