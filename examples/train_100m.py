"""End-to-end driver: train a ~100M-parameter llama-family model on the
deterministic Markov corpus with the full production loop — prefetching,
async checkpointing, fault-tolerant restart, straggler watchdog.

Full run (a few hundred steps, ~100M params):
    PYTHONPATH=src python examples/train_100m.py --steps 300
CI-scale run:
    PYTHONPATH=src python examples/train_100m.py --smoke
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import MarkovChainData
from repro.runtime import Trainer, TrainerConfig


def model_100m():
    """~100M params: 10L, d=640, ff=2560, 10 heads (kv 5), vocab 50304."""
    return dataclasses.replace(
        get_config("yi-6b"),
        name="llama-100m",
        num_layers=10, d_model=640, num_heads=10, num_kv_heads=5,
        head_dim=64, d_ff=2560, vocab_size=50304, loss_chunk=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.smoke:
        cfg = model_100m()
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                                  num_heads=4, num_kv_heads=2, head_dim=32,
                                  d_ff=512, vocab_size=1024)
        args.steps, args.batch, args.seq = 30, 4, 64
    else:
        cfg = model_100m()

    n_params_est = (cfg.num_layers *
                    (2 * cfg.d_model * cfg.num_heads * cfg.resolved_head_dim +
                     2 * cfg.d_model * cfg.num_kv_heads * cfg.resolved_head_dim
                     + 3 * cfg.d_model * cfg.d_ff) +
                    2 * cfg.vocab_size * cfg.d_model)
    print(f"model ~{n_params_est/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    shape = ShapeSpec("train_cfg", args.seq, args.batch, "train")
    data = MarkovChainData(cfg, shape, seed=0)
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="train100m_ckpt_")
    trainer = Trainer(cfg, shape, data,
                      TrainerConfig(total_steps=args.steps,
                                    ckpt_every=max(args.steps // 5, 10),
                                    ckpt_dir=ckpt,
                                    log_every=max(args.steps // 20, 1)))
    res = trainer.run_with_recovery()
    first, last = res["metrics"][0], res["metrics"][-1]
    print(f"\nloss {first['loss']:.4f} -> {last['loss']:.4f} over "
          f"{res['final_step']} steps "
          f"({len(res['stragglers'])} straggler flags, "
          f"{res['restarts']} restarts)")
    for m in res["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['step_s']*1e3:.0f} ms/step")
    assert last["loss"] < first["loss"], "loss must decrease"
    print(f"checkpoints committed under {ckpt}")


if __name__ == "__main__":
    main()
