"""CI bench-regression gate: fresh BENCH_serve.json vs the committed one.

HERO validates every change "through fully automated hardware and software
builds and executed tests" (§1); this is the serving-side analogue for the
engine's *scheduling efficiency* metrics, which are deterministic for a
fixed workload (unlike wall-clock tokens/s on shared CI runners):

* ``chunked_prefill.iters_per_request`` — engine iterations per request
  (chunked-prefill admission efficiency);
* ``chunked_prefill.h2d_per_generated_token`` — host->device transfer
  events per generated token (device-residency of the hot path);
* ``speculation.spec_on.iters_per_generated_token`` — engine iterations
  per generated token with speculative decoding (lower is better);
* ``speculation.acceptance_rate`` — drafted tokens the verify step
  confirmed (HIGHER is better — the gate is direction-aware);
* ``sampling.greedy.iters_per_generated_token`` — the temperature-0 path
  of the sampled-decoding workload: the unified-API sampler must keep the
  greedy hot path's iteration structure intact (lower is better);
* ``degradation.goodput`` — completed/submitted under the seeded fault
  storm (HIGHER is better);
* ``degradation.within_deadline_fraction`` — of the requests the engine
  attempted, the fraction that completed within deadline (HIGHER is
  better);
* ``latency.ttft_p95_s`` / ``latency.ttft_p99_s`` — tail time-to-first-
  token under the live-traffic load generator, in virtual seconds
  (lower is better; deterministic because the front door runs on a
  ``VirtualClock``);
* ``latency.tpot_p95_s`` / ``latency.tpot_p99_s`` — tail time-per-
  output-token under the same workload (lower is better);
* ``latency.slo_goodput`` — fraction of all offered requests that
  completed within both latency SLOs (HIGHER is better).

Relative rule: a gated metric may not regress by more than
``--max-regress`` (default 10%) against the committed baseline.  On top
of the relative gates, two absolute speculation gates lock the win in
regardless of what the baseline says:

* ``speculation.acceptance_rate`` must be >= ``--spec-accept-floor``;
* ``speculation.spec_on.iters_per_generated_token`` must be strictly
  below ``speculation.spec_off.iters_per_generated_token`` — if drafting
  ever stops beating plain decode, the gate fails even if both numbers
  match the baseline.

The degradation section additionally carries absolute gates (fault
tolerance is a property, not just a trend — a missing ``degradation``
section fails outright, it is not NEW-tolerated):

* ``degradation.goodput`` >= ``--goodput-floor``;
* ``degradation.within_deadline_fraction`` >= ``--deadline-floor``;
* ``degradation.unhandled_exceptions`` == 0 — a fault that escapes the
  engine instead of demoting one request is an automatic failure.

The latency section carries the same treatment (a missing ``latency``
section fails outright — the live-traffic probe going silent is the
regression):

* ``latency.slo_goodput`` >= ``--slo-goodput-floor``;
* ``latency.replay_identical`` must be true — if two same-seed runs of
  the load generator diverge, the virtual clock leaked wall time and
  every latency gate above is noise.

The ``hierarchical_cache`` section is gated the same way (a missing
section fails outright):

* ``hierarchical_cache.tiered.prefix_hit_rate`` must be strictly above
  ``hierarchical_cache.device_only.prefix_hit_rate`` — the host/disk
  spill tiers must actually buy hits the device pool alone cannot hold;
* ``hierarchical_cache.corpus_to_pool_ratio`` >= ``--corpus-ratio-floor``
  (default 4) — the workload must genuinely overflow the device pool;
* ``hierarchical_cache.token_parity`` must be true — pages restored
  through the tiers must decode token-identically to device-only.

The ``quantized_kv`` section is gated absolutely too (a missing section
fails outright — the int8 path going unmeasured is the regression):

* ``quantized_kv.bytes_per_token_ratio`` <= ``--kv-ratio-ceiling``
  (default 0.6) — the int8 pool must keep roughly half the bf16
  footprint, scales included;
* ``quantized_kv.token_agreement`` >= ``--token-agreement-floor``
  (default 0.98) — teacher-forced next-token agreement vs the bf16
  engine, the bench's perplexity proxy;
* ``quantized_kv.kernel_ref_outputs_match`` must be true — the in-kernel
  dequant and the oracle must produce identical tokens.

The ``planner_accuracy`` section (written by
``benchmarks/plan_accuracy.py``) is gated absolutely as well (a missing
section fails outright — the capacity planner's engine replica going
unvalidated is the regression):

* every metric in ``planner_accuracy.gated`` must have
  ``|rel_err| <= --planner-err-ceiling`` (default 0.25) — the simulator's
  prediction of each bench workload stays within tolerance of the
  measured engine;
* at least ``--planner-min-workloads`` (default 3) workloads must be
  represented among the gated metrics;
* ``planner_accuracy.capacity_demo.slo_met`` must be true — the config
  ``plan_capacity`` recommends must meet the SLO it was asked for in its
  own predicted report.

Additionally ``planner_accuracy.max_gated_abs_rel_err`` joins the
relative gates (lower is better), so planner accuracy may not silently
erode even inside the ceiling.

Robustness contract (tested by ``tests/test_check_bench.py``):

* workload descriptor mismatch -> exit 2 (the comparison is meaningless);
* malformed/unreadable JSON -> exit 2 with the offending file named;
* a MISSING/unreadable baseline with ``--allow-missing-baseline`` ->
  warn, skip the relative and workload-descriptor checks, and run the
  absolute gates on the fresh result alone (exit 0/1) — the bootstrap
  path for a branch that has no committed baseline yet.  Without the
  flag a missing baseline stays exit 2; an unreadable FRESH result is
  exit 2 regardless;
* a gated metric missing from the FRESH result -> exit 1 (the benchmark
  stopped reporting something the gate guards);
* a gated metric missing from the BASELINE -> reported as NEW and skipped
  (metrics can be introduced without a same-commit baseline chicken/egg).

    python scripts/check_bench.py --baseline BENCH_baseline.json \
        --fresh BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys

#: (json path, human name, direction); direction is which way is BETTER
GATED = [
    (("chunked_prefill", "iters_per_request"),
     "engine iters/request", "lower"),
    (("chunked_prefill", "h2d_per_generated_token"),
     "H2D events/token", "lower"),
    (("speculation", "spec_on", "iters_per_generated_token"),
     "spec iters/generated token", "lower"),
    (("speculation", "acceptance_rate"),
     "spec acceptance rate", "higher"),
    (("sampling", "greedy", "iters_per_generated_token"),
     "greedy-path iters/generated token", "lower"),
    (("degradation", "goodput"),
     "fault-storm goodput", "higher"),
    (("degradation", "within_deadline_fraction"),
     "fault-storm within-deadline fraction", "higher"),
    (("latency", "ttft_p95_s"), "TTFT p95 (virtual s)", "lower"),
    (("latency", "ttft_p99_s"), "TTFT p99 (virtual s)", "lower"),
    (("latency", "tpot_p95_s"), "TPOT p95 (virtual s)", "lower"),
    (("latency", "tpot_p99_s"), "TPOT p99 (virtual s)", "lower"),
    (("latency", "slo_goodput"), "latency SLO goodput", "higher"),
    (("hierarchical_cache", "tiered", "prefix_hit_rate"),
     "tiered prefix-cache hit rate", "higher"),
    (("quantized_kv", "bytes_per_token_ratio"),
     "int8 KV bytes/token ratio", "lower"),
    (("quantized_kv", "token_agreement"),
     "int8 KV token agreement", "higher"),
    (("planner_accuracy", "max_gated_abs_rel_err"),
     "planner max gated |rel err|", "lower"),
]

SPEC_ACCEPT_FLOOR = 0.25
GOODPUT_FLOOR = 0.4
DEADLINE_FLOOR = 0.5
SLO_GOODPUT_FLOOR = 0.5
CORPUS_RATIO_FLOOR = 4.0
KV_RATIO_CEILING = 0.6
TOKEN_AGREEMENT_FLOOR = 0.98
PLANNER_ERR_CEILING = 0.25
PLANNER_MIN_WORKLOADS = 3


def _dig(d, path):
    for k in path:
        d = d[k]
    return d


def _load(path: str, role: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL cannot read {role} result {path!r}: {e}")
        return None


def check_relative(base: dict, fresh: dict, max_regress: float) -> bool:
    """Direction-aware relative gates.  Returns True iff all pass."""
    failed = False
    for path, name, direction in GATED:
        try:
            x = float(_dig(fresh, path))
        except (KeyError, TypeError) as e:
            print(f"FAIL {name}: missing key {e} in fresh result")
            failed = True
            continue
        try:
            b = float(_dig(base, path))
        except (KeyError, TypeError):
            print(f"NEW  {name}: fresh={x:.4f} (not in baseline; "
                  f"gated from the next baseline update on)")
            continue
        if b:
            ratio = x / b
        else:
            ratio = 1.0 if x == b else float("inf")
        regressed = ratio > 1.0 + max_regress if direction == "lower" \
            else ratio < 1.0 - max_regress
        verdict = "FAIL" if regressed else "OK  "
        failed |= regressed
        print(f"{verdict} {name}: baseline={b:.4f} fresh={x:.4f} "
              f"({ratio - 1.0:+.1%} vs baseline, {direction} is better)")
    return not failed


def check_speculation_absolute(fresh: dict, accept_floor: float) -> bool:
    """Absolute speculation gates on the fresh result alone."""
    try:
        rate = float(_dig(fresh, ("speculation", "acceptance_rate")))
        on = float(_dig(fresh, ("speculation", "spec_on",
                                "iters_per_generated_token")))
        off = float(_dig(fresh, ("speculation", "spec_off",
                                 "iters_per_generated_token")))
    except (KeyError, TypeError) as e:
        print(f"FAIL speculation section incomplete in fresh result: {e}")
        return False
    ok = True
    if rate < accept_floor:
        print(f"FAIL spec acceptance rate {rate:.3f} below floor "
              f"{accept_floor:.3f}")
        ok = False
    else:
        print(f"OK   spec acceptance rate {rate:.3f} >= floor "
              f"{accept_floor:.3f}")
    if not on < off:
        print(f"FAIL spec-on iters/token {on:.4f} not strictly below "
              f"spec-off {off:.4f}")
        ok = False
    else:
        print(f"OK   spec-on iters/token {on:.4f} < spec-off {off:.4f} "
              f"({off / max(on, 1e-9):.2f}x)")
    return ok


def check_degradation_absolute(fresh: dict, goodput_floor: float,
                               deadline_floor: float) -> bool:
    """Absolute fault-tolerance gates on the fresh result alone.

    Unlike a NEW metric, a *missing* ``degradation`` section fails: the
    fault storm stopping silently is exactly the regression this gate
    exists to catch."""
    dg = fresh.get("degradation")
    if not isinstance(dg, dict):
        print("FAIL degradation section missing from fresh result")
        return False
    ok = True
    try:
        goodput = float(dg["goodput"])
        within = float(dg["within_deadline_fraction"])
        unhandled = int(dg["unhandled_exceptions"])
    except (KeyError, TypeError, ValueError) as e:
        print(f"FAIL degradation section incomplete in fresh result: {e}")
        return False
    if goodput < goodput_floor:
        print(f"FAIL fault-storm goodput {goodput:.3f} below floor "
              f"{goodput_floor:.3f}")
        ok = False
    else:
        print(f"OK   fault-storm goodput {goodput:.3f} >= floor "
              f"{goodput_floor:.3f}")
    if within < deadline_floor:
        print(f"FAIL within-deadline fraction {within:.3f} below floor "
              f"{deadline_floor:.3f}")
        ok = False
    else:
        print(f"OK   within-deadline fraction {within:.3f} >= floor "
              f"{deadline_floor:.3f}")
    if unhandled != 0:
        print(f"FAIL {unhandled} unhandled exception(s) escaped the "
              f"engine under fault injection: "
              f"{dg.get('unhandled_detail', [])}")
        ok = False
    else:
        print("OK   zero unhandled exceptions under fault injection")
    return ok


def check_latency_absolute(fresh: dict, slo_goodput_floor: float) -> bool:
    """Absolute live-traffic latency gates on the fresh result alone.

    A missing ``latency`` section fails (like ``degradation``): the
    load-generator probe going silent is the regression.  The replay
    check is the load-bearing one — every latency number is only
    gate-able because two same-seed virtual-clock runs are
    byte-identical, so a replay divergence poisons the whole section."""
    lt = fresh.get("latency")
    if not isinstance(lt, dict):
        print("FAIL latency section missing from fresh result")
        return False
    ok = True
    try:
        goodput = float(lt["slo_goodput"])
        identical = bool(lt["replay_identical"])
    except (KeyError, TypeError, ValueError) as e:
        print(f"FAIL latency section incomplete in fresh result: {e}")
        return False
    if goodput < slo_goodput_floor:
        print(f"FAIL latency SLO goodput {goodput:.3f} below floor "
              f"{slo_goodput_floor:.3f}")
        ok = False
    else:
        print(f"OK   latency SLO goodput {goodput:.3f} >= floor "
              f"{slo_goodput_floor:.3f}")
    if not identical:
        print("FAIL same-seed latency replays diverged "
              "(virtual clock leaked wall time)")
        ok = False
    else:
        print("OK   same-seed latency replays byte-identical")
    return ok


def check_hierarchical_cache_absolute(
        fresh: dict, ratio_floor: float = CORPUS_RATIO_FLOOR) -> bool:
    """Absolute tiered prefix-cache gates on the fresh result alone.

    A missing ``hierarchical_cache`` section fails (like ``degradation``
    and ``latency``): the tiered-cache probe going silent is the
    regression.  The tiered engine must strictly beat the device-only
    hit rate on a corpus at least ``ratio_floor`` times the device pool,
    and tier restores must be token-exact (``token_parity``)."""
    hc = fresh.get("hierarchical_cache")
    if not isinstance(hc, dict):
        print("FAIL hierarchical_cache section missing from fresh result")
        return False
    ok = True
    try:
        tiered = float(_dig(hc, ("tiered", "prefix_hit_rate")))
        device = float(_dig(hc, ("device_only", "prefix_hit_rate")))
        ratio = float(hc["corpus_to_pool_ratio"])
        parity = hc["token_parity"]
    except (KeyError, TypeError, ValueError) as e:
        print(f"FAIL hierarchical_cache section incomplete in fresh "
              f"result: {e}")
        return False
    if not tiered > device:
        print(f"FAIL tiered hit rate {tiered:.3f} does not beat "
              f"device-only {device:.3f}")
        ok = False
    else:
        print(f"OK   tiered hit rate {tiered:.3f} > device-only "
              f"{device:.3f}")
    if ratio < ratio_floor:
        print(f"FAIL corpus/pool ratio {ratio:.2f} below floor "
              f"{ratio_floor:.2f} (workload too easy to gate on)")
        ok = False
    else:
        print(f"OK   corpus/pool ratio {ratio:.2f} >= floor "
              f"{ratio_floor:.2f}")
    if parity is not True:
        print("FAIL tiered outputs not token-identical to device-only "
              "(token_parity must be true)")
        ok = False
    else:
        print("OK   tiered outputs token-identical to device-only")
    return ok


def check_quantized_kv_absolute(
        fresh: dict, ratio_ceiling: float = KV_RATIO_CEILING,
        agreement_floor: float = TOKEN_AGREEMENT_FLOOR) -> bool:
    """Absolute int8-KV gates on the fresh result alone.

    A missing ``quantized_kv`` section fails (like the other
    property-style sections): the quantized path going unmeasured is the
    regression.  The memory win and the quality floor are both absolute
    — neither may silently erode behind a drifting baseline."""
    qk = fresh.get("quantized_kv")
    if not isinstance(qk, dict):
        print("FAIL quantized_kv section missing from fresh result")
        return False
    ok = True
    try:
        ratio = float(qk["bytes_per_token_ratio"])
        agreement = float(qk["token_agreement"])
        paths = qk["kernel_ref_outputs_match"]
    except (KeyError, TypeError, ValueError) as e:
        print(f"FAIL quantized_kv section incomplete in fresh result: {e}")
        return False
    if ratio > ratio_ceiling:
        print(f"FAIL int8 KV bytes/token ratio {ratio:.3f} above ceiling "
              f"{ratio_ceiling:.3f} (quantization stopped paying for "
              f"itself)")
        ok = False
    else:
        print(f"OK   int8 KV bytes/token ratio {ratio:.3f} <= ceiling "
              f"{ratio_ceiling:.3f}")
    if agreement < agreement_floor:
        print(f"FAIL int8 KV token agreement {agreement:.4f} below floor "
              f"{agreement_floor:.4f}")
        ok = False
    else:
        print(f"OK   int8 KV token agreement {agreement:.4f} >= floor "
              f"{agreement_floor:.4f}")
    if paths is not True:
        print("FAIL int8 kernel and oracle attention paths diverged "
              "(kernel_ref_outputs_match must be true)")
        ok = False
    else:
        print("OK   int8 kernel and oracle paths token-identical")
    return ok


def check_planner_accuracy_absolute(
        fresh: dict, err_ceiling: float = PLANNER_ERR_CEILING,
        min_workloads: int = PLANNER_MIN_WORKLOADS) -> bool:
    """Absolute planner-accuracy gates on the fresh result alone.

    A missing ``planner_accuracy`` section fails (like the other
    property-style sections): the capacity planner's engine replica
    going unvalidated is the regression.  Every gated metric (the flat
    ``gated`` map of ``workload.metric -> rel_err`` emitted by
    ``benchmarks/plan_accuracy.py``) must sit within ``err_ceiling`` of
    the measured engine, at least ``min_workloads`` distinct workloads
    must be represented, and the ``capacity_demo`` recommendation must
    meet its own SLO in its own predicted report."""
    pa = fresh.get("planner_accuracy")
    if not isinstance(pa, dict):
        print("FAIL planner_accuracy section missing from fresh result")
        return False
    ok = True
    try:
        gated = dict(pa["gated"])
        slo_met = _dig(pa, ("capacity_demo", "slo_met"))
    except (KeyError, TypeError, ValueError) as e:
        print(f"FAIL planner_accuracy section incomplete in fresh "
              f"result: {e}")
        return False
    if not gated:
        print("FAIL planner_accuracy.gated is empty — nothing validated")
        return False
    over = {k: v for k, v in gated.items()
            if not (isinstance(v, (int, float)) and abs(v) <= err_ceiling)}
    if over:
        worst = sorted(over.items(),
                       key=lambda kv: -abs(float(kv[1] or 0)))[:5]
        print(f"FAIL {len(over)}/{len(gated)} planner metrics outside "
              f"+-{err_ceiling:.0%}: "
              + ", ".join(f"{k}={v}" for k, v in worst))
        ok = False
    else:
        worst = max(abs(float(v)) for v in gated.values())
        print(f"OK   all {len(gated)} gated planner metrics within "
              f"+-{err_ceiling:.0%} (worst |rel err| = {worst:.4f})")
    workloads = {k.split(".", 1)[0] for k in gated}
    if len(workloads) < min_workloads:
        print(f"FAIL planner validated only {len(workloads)} workload(s) "
              f"({sorted(workloads)}), need >= {min_workloads}")
        ok = False
    else:
        print(f"OK   planner validated against {len(workloads)} bench "
              f"workloads: {sorted(workloads)}")
    if slo_met is not True:
        print("FAIL plan_capacity's recommended config does not meet its "
              "own SLO in its predicted report (slo_met must be true)")
        ok = False
    else:
        print("OK   plan_capacity recommendation meets its SLO "
              "in its predicted report")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_serve.json (the gate's reference)")
    ap.add_argument("--fresh", default="BENCH_serve.json",
                    help="freshly produced BENCH_serve.json")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="maximum tolerated relative regression")
    ap.add_argument("--spec-accept-floor", type=float,
                    default=SPEC_ACCEPT_FLOOR,
                    help="absolute floor on speculation.acceptance_rate")
    ap.add_argument("--goodput-floor", type=float, default=GOODPUT_FLOOR,
                    help="absolute floor on degradation.goodput")
    ap.add_argument("--deadline-floor", type=float, default=DEADLINE_FLOOR,
                    help="absolute floor on "
                         "degradation.within_deadline_fraction")
    ap.add_argument("--slo-goodput-floor", type=float,
                    default=SLO_GOODPUT_FLOOR,
                    help="absolute floor on latency.slo_goodput")
    ap.add_argument("--corpus-ratio-floor", type=float,
                    default=CORPUS_RATIO_FLOOR,
                    help="absolute floor on hierarchical_cache."
                         "corpus_to_pool_ratio")
    ap.add_argument("--kv-ratio-ceiling", type=float,
                    default=KV_RATIO_CEILING,
                    help="absolute ceiling on quantized_kv."
                         "bytes_per_token_ratio")
    ap.add_argument("--token-agreement-floor", type=float,
                    default=TOKEN_AGREEMENT_FLOOR,
                    help="absolute floor on quantized_kv.token_agreement")
    ap.add_argument("--planner-err-ceiling", type=float,
                    default=PLANNER_ERR_CEILING,
                    help="absolute ceiling on |rel_err| of every metric "
                         "in planner_accuracy.gated")
    ap.add_argument("--planner-min-workloads", type=int,
                    default=PLANNER_MIN_WORKLOADS,
                    help="minimum distinct workloads the planner must be "
                         "validated against")
    ap.add_argument("--allow-missing-baseline", action="store_true",
                    help="a missing/unreadable baseline becomes a warning: "
                         "relative gates are skipped and the absolute "
                         "gates run on the fresh result alone (the "
                         "bootstrap path for branches without a committed "
                         "baseline)")
    args = ap.parse_args(argv)

    base = _load(args.baseline, "baseline")
    fresh = _load(args.fresh, "fresh")
    if fresh is None or not isinstance(fresh, dict):
        print("bench gate ERROR (unreadable or non-object fresh input)")
        return 2
    if base is None or not isinstance(base, dict):
        if not args.allow_missing_baseline:
            print("bench gate ERROR (unreadable or non-object baseline; "
                  "pass --allow-missing-baseline to run the absolute "
                  "gates without one)")
            return 2
        print(f"WARN baseline {args.baseline!r} missing or unreadable — "
              f"skipping relative gates, running absolute gates only")
        base = None

    ok = True
    if base is not None:
        if base.get("workload") != fresh.get("workload"):
            print(f"FAIL workload mismatch — the gate compares nothing "
                  f"useful\n"
                  f"  baseline: {base.get('workload')}\n"
                  f"  fresh:    {fresh.get('workload')}")
            return 2
        ok = check_relative(base, fresh, args.max_regress)
    ok &= check_speculation_absolute(fresh, args.spec_accept_floor)
    ok &= check_degradation_absolute(fresh, args.goodput_floor,
                                     args.deadline_floor)
    ok &= check_latency_absolute(fresh, args.slo_goodput_floor)
    ok &= check_hierarchical_cache_absolute(fresh, args.corpus_ratio_floor)
    ok &= check_quantized_kv_absolute(fresh, args.kv_ratio_ceiling,
                                      args.token_agreement_floor)
    ok &= check_planner_accuracy_absolute(fresh, args.planner_err_ceiling,
                                          args.planner_min_workloads)
    if not ok:
        print(f"bench gate FAILED (>{args.max_regress:.0%} regression "
              f"or absolute speculation/degradation/latency/"
              f"hierarchical-cache/quantized-kv/planner-accuracy gate)")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
