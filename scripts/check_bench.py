"""CI bench-regression gate: fresh BENCH_serve.json vs the committed one.

HERO validates every change "through fully automated hardware and software
builds and executed tests" (§1); this is the serving-side analogue for the
engine's *scheduling efficiency* metrics, which are deterministic for a
fixed workload (unlike wall-clock tokens/s on shared CI runners):

* ``chunked_prefill.iters_per_request`` — engine iterations per request
  (chunked-prefill admission efficiency);
* ``chunked_prefill.h2d_per_generated_token`` — host->device transfer
  events per generated token (device-residency of the hot path).

The job fails when either regresses by more than ``--max-regress``
(default 10%).  Workload descriptors must match exactly — comparing
different workloads would make the gate meaningless, so a mismatch is
also a failure.

    python scripts/check_bench.py --baseline BENCH_baseline.json \
        --fresh BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys

#: (json path, human name); lower is better for every gated metric
GATED = [
    (("chunked_prefill", "iters_per_request"), "engine iters/request"),
    (("chunked_prefill", "h2d_per_generated_token"), "H2D events/token"),
]


def _dig(d, path):
    for k in path:
        d = d[k]
    return d


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_serve.json (the gate's reference)")
    ap.add_argument("--fresh", default="BENCH_serve.json",
                    help="freshly produced BENCH_serve.json")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="maximum tolerated relative regression")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    if base.get("workload") != fresh.get("workload"):
        print(f"FAIL workload mismatch — the gate compares nothing useful\n"
              f"  baseline: {base.get('workload')}\n"
              f"  fresh:    {fresh.get('workload')}")
        return 2

    failed = False
    for path, name in GATED:
        try:
            b = float(_dig(base, path))
        except KeyError as e:
            print(f"FAIL {name}: missing key {e} in baseline result")
            failed = True
            continue
        try:
            x = float(_dig(fresh, path))
        except KeyError as e:
            print(f"FAIL {name}: missing key {e} in fresh result")
            failed = True
            continue
        ratio = x / b if b else (1.0 if x == b else float("inf"))
        verdict = "OK  "
        if ratio > 1.0 + args.max_regress:
            verdict, failed = "FAIL", True
        print(f"{verdict} {name}: baseline={b:.4f} fresh={x:.4f} "
              f"({ratio - 1.0:+.1%} vs baseline)")
    if failed:
        print(f"bench gate FAILED (>{args.max_regress:.0%} regression)")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
