"""Fast dev loop: one train + prefill + decode step per smoke arch on CPU.

Failures are *aggregated*: every arch (and the serving benchmark) runs even
when an earlier step fails, a summary is printed at the end, and the exit
code is non-zero iff anything failed — so CI can run this script directly
and a single broken arch can't mask later ones (or sneak through a
reporting path that swallows the failure).
"""
import os
import sys
import traceback

# force virtual devices before the first jax import so the serving
# benchmark's multi-cluster sweep runs for real (single-device jit work is
# unaffected: it places on device 0)
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, smoke_shape
from repro.models import model as M
from repro.models import steps as ST

ARCHS = sys.argv[1:] or list_archs()

failures = []

for name in ARCHS:
    cfg = get_config(name).smoke()
    rng = jax.random.PRNGKey(0)
    try:
        params = M.init_params(cfg, rng)
        n = sum(x.size for x in jax.tree.leaves(params))
        # train
        tshape = smoke_shape("train")
        batch = ST.make_batch(cfg, tshape, rng)
        state = ST.init_train_state(cfg, ST.default_opt_cfg(cfg), rng)
        step = jax.jit(ST.make_train_step(cfg))
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        # prefill
        pshape = smoke_shape("prefill")
        pbatch = ST.make_batch(cfg, pshape, rng)
        logits = jax.jit(ST.make_prefill_step(cfg))(state["params"], pbatch)
        # decode
        dshape = smoke_shape("decode")
        T = max(cfg.cache_len(dshape), 1)
        cache = M.init_cache(cfg, dshape.global_batch, T)
        dbatch = ST.make_batch(cfg, dshape, rng)
        dlogits, cache = jax.jit(ST.make_decode_step(cfg))(
            state["params"], cache, dbatch)
        ok_nan = (jnp.isfinite(loss) and bool(jnp.isfinite(logits).all())
                  and bool(jnp.isfinite(dlogits).all()))
        print(f"OK   {name:20s} params={n:>9d} loss={loss:8.4f} "
              f"prefill={logits.shape} decode={dlogits.shape} finite={ok_nan}")
        assert ok_nan
    except Exception as e:
        print(f"FAIL {name}: {e}")
        traceback.print_exc()
        failures.append(name)

# the streaming serving example end-to-end: exercises the unified
# generation API (EngineConfig / SamplingParams / generate() deltas) the
# way a user would — it asserts internally that the streamed deltas
# concatenate to the final results
try:
    import subprocess
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, os.path.join(repo, "examples", "serve_paged.py"),
         "--requests", "4"],
        capture_output=True, text=True, env=env, cwd=repo, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "served 5 requests" in r.stdout, r.stdout
    print("OK   examples/serve_paged.py (streaming API demo)")
except Exception as e:
    print(f"FAIL serve_paged example: {e}")
    traceback.print_exc()
    failures.append("serve_paged_example")

# serving hot path: chunked prefill vs token-by-token, the shared-prefix
# KV-cache workload (hit rate must be real), the preemption probe, and the
# sharded-engine cluster sweep (1-cluster parity is asserted inside main)
try:
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import serve_throughput
    result = serve_throughput.main(["--smoke", "--clusters", "4"])
    sp = result["shared_prefix"]
    assert sp["prefix_hit_rate"] > 0, "no prefix-cache hits in smoke run"
    assert sp["prefix_cached"]["iterations"] < \
        sp["baseline_no_sharing"]["iterations"], \
        "prefix caching did not reduce engine iterations"
    assert result["preemption"]["swap_out_pages"] > 0, \
        "preemption probe swapped nothing"
    sweep = result["cluster_sweep"]
    assert sweep["one_cluster_outputs_match_unsharded"], \
        "sharded engine diverged at 1 cluster"
    sd = result["speculation"]
    assert sd["outputs_match"], "speculative decoding changed outputs"
    assert sd["iters_per_token_reduction"] > 1.0, \
        "speculation did not reduce engine iterations per token"
    sa = result["sampling"]
    assert sa["sampled_reproducible"], "seeded sampling not reproducible"
    assert sa["stop_token_early_exit"], "stop token did not end a request"
    print(f"OK   shared-prefix hit-rate="
          f"{sp['prefix_hit_rate']:.2f} pages_saved={sp['pages_saved']} "
          f"preemption swaps={result['preemption']['swap_out_pages']} "
          f"spec acceptance={sd['acceptance_rate']:.2f} "
          f"sampling reproducible={sa['sampled_reproducible']} "
          f"cluster configs={sorted(sweep['configs'])}")
except Exception as e:
    print(f"FAIL serve_throughput: {e}")
    traceback.print_exc()
    failures.append("serve_throughput")

# analytical capacity planner: replay the bench just produced above
# through the discrete-event simulator and assert the predictions land
# inside the accuracy gate (plan_accuracy sys.exits non-zero otherwise);
# the annotated copy goes to a scratch file so this script mutates
# nothing beyond what serve_throughput already wrote
try:
    import tempfile
    from benchmarks import plan_accuracy
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
        scratch = tf.name
    section = plan_accuracy.main(["--bench", "BENCH_serve.json",
                                  "--out", scratch])
    assert section["capacity_demo"]["slo_met"], \
        "plan_capacity recommendation missed its own SLO"
    print(f"OK   planner accuracy: max gated |rel err| = "
          f"{section['max_gated_abs_rel_err']:.4f} over "
          f"{len(section['gated'])} metrics")
except (Exception, SystemExit) as e:
    print(f"FAIL plan_accuracy: {e}")
    traceback.print_exc()
    failures.append("plan_accuracy")

if failures:
    print(f"SMOKE FAILURES ({len(failures)}): " + ", ".join(failures))
    sys.exit(1)
print("ALL SMOKE OK")
