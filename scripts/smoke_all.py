"""Fast dev loop: one train + prefill + decode step per smoke arch on CPU."""
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, smoke_shape
from repro.models import model as M
from repro.models import steps as ST

ARCHS = sys.argv[1:] or list_archs()

for name in ARCHS:
    cfg = get_config(name).smoke()
    rng = jax.random.PRNGKey(0)
    try:
        params = M.init_params(cfg, rng)
        n = sum(x.size for x in jax.tree.leaves(params))
        # train
        tshape = smoke_shape("train")
        batch = ST.make_batch(cfg, tshape, rng)
        state = ST.init_train_state(cfg, ST.default_opt_cfg(cfg), rng)
        step = jax.jit(ST.make_train_step(cfg))
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        # prefill
        pshape = smoke_shape("prefill")
        pbatch = ST.make_batch(cfg, pshape, rng)
        logits = jax.jit(ST.make_prefill_step(cfg))(state["params"], pbatch)
        # decode
        dshape = smoke_shape("decode")
        T = max(cfg.cache_len(dshape), 1)
        cache = M.init_cache(cfg, dshape.global_batch, T)
        dbatch = ST.make_batch(cfg, dshape, rng)
        dlogits, cache = jax.jit(ST.make_decode_step(cfg))(
            state["params"], cache, dbatch)
        ok_nan = (jnp.isfinite(loss) and bool(jnp.isfinite(logits).all())
                  and bool(jnp.isfinite(dlogits).all()))
        print(f"OK   {name:20s} params={n:>9d} loss={loss:8.4f} "
              f"prefill={logits.shape} decode={dlogits.shape} finite={ok_nan}")
        assert ok_nan
    except Exception as e:
        print(f"FAIL {name}: {e}")
        traceback.print_exc()
        sys.exit(1)

# serving hot path: chunked prefill vs token-by-token, the shared-prefix
# KV-cache workload (hit rate must be real), and the preemption probe
try:
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import serve_throughput
    result = serve_throughput.main(["--smoke"])
    sp = result["shared_prefix"]
    assert sp["prefix_hit_rate"] > 0, "no prefix-cache hits in smoke run"
    assert sp["prefix_cached"]["iterations"] < \
        sp["baseline_no_sharing"]["iterations"], \
        "prefix caching did not reduce engine iterations"
    assert result["preemption"]["swap_out_pages"] > 0, \
        "preemption probe swapped nothing"
    print(f"OK   shared-prefix hit-rate="
          f"{sp['prefix_hit_rate']:.2f} pages_saved={sp['pages_saved']} "
          f"preemption swaps={result['preemption']['swap_out_pages']}")
except Exception as e:
    print(f"FAIL serve_throughput: {e}")
    traceback.print_exc()
    sys.exit(1)
print("ALL SMOKE OK")
