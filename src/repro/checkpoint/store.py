"""Sharded, mesh-agnostic checkpointing with atomic commit + async save.

Layout:  <root>/step_<N>/
            metadata.json        tree paths, shapes, dtypes
            <leafpath>.npy       one file per leaf (host-local shard on a
                                 real fleet; full arrays single-process)
            COMMITTED            atomic marker (written last, rename-safe)

Restore is *elastic*: arrays are re-device_put with whatever shardings the
new mesh prescribes — checkpoints carry only logical tensors, so a run
saved on a (4,) mesh restores onto (2,2) or (2,16,16) unchanged (the
standard checkpoint-reshard-restart path used after node failures).
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy cannot round-trip ml_dtypes through .npy headers; store such arrays
# as a same-width integer view and reconstruct from the recorded dtype.
_VIEW_SAVE = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}
_VIEW_LOAD = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
    "float8_e5m2": ml_dtypes.float8_e5m2,
}


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    out: List[Tuple[str, Any]] = []

    def visit(node, prefix):
        if isinstance(node, dict):
            for k in sorted(node):
                visit(node[k], prefix + [str(k)])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                visit(v, prefix + [str(i)])
        else:
            out.append(("/".join(prefix), node))

    visit(tree, [])
    return out


def _unflatten_like(like: Any, values: Dict[str, Any]) -> Any:
    def visit(node, prefix):
        if isinstance(node, dict):
            return {k: visit(node[k], prefix + [str(k)]) for k in node}
        if isinstance(node, (list, tuple)):
            t = [visit(v, prefix + [str(i)]) for i, v in enumerate(node)]
            return type(node)(t)
        return values["/".join(prefix)]

    return visit(like, [])


def save_checkpoint(root: str, step: int, state: Any) -> str:
    """Atomic synchronous save.  Returns the committed directory."""
    root_p = Path(root)
    root_p.mkdir(parents=True, exist_ok=True)
    final = root_p / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=root))
    try:
        leaves = _flatten_with_paths(state)
        meta = {"step": step, "leaves": {}}
        for path, leaf in leaves:
            arr = np.asarray(leaf)
            fn = path.replace("/", "__") + ".npy"
            logical = str(arr.dtype)
            if logical in _VIEW_SAVE:
                np.save(tmp / fn, arr.view(_VIEW_SAVE[logical]))
            else:
                np.save(tmp / fn, arr)
            meta["leaves"][path] = {"file": fn, "shape": list(arr.shape),
                                    "dtype": logical}
        (tmp / "metadata.json").write_text(json.dumps(meta))
        (tmp / "COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return str(final)


def latest_step(root: str) -> Optional[int]:
    p = Path(root)
    if not p.exists():
        return None
    steps = []
    for d in p.iterdir():
        if d.name.startswith("step_") and (d / "COMMITTED").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(root: str, like: Any, step: Optional[int] = None,
                       shardings: Optional[Any] = None) -> Tuple[Any, int]:
    """Load a committed checkpoint; reshard onto `shardings` if given."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = Path(root) / f"step_{step:08d}"
    meta = json.loads((d / "metadata.json").read_text())
    values: Dict[str, Any] = {}
    shard_leaves = dict(_flatten_with_paths(shardings)) if shardings is not None \
        else {}
    for path, info in meta["leaves"].items():
        arr = np.load(d / info["file"])
        if info["dtype"] in _VIEW_LOAD:
            arr = arr.view(_VIEW_LOAD[info["dtype"]])
        sh = shard_leaves.get(path)
        values[path] = jax.device_put(arr, sh) if sh is not None else \
            jax.device_put(arr)
    return _unflatten_like(like, values), step


class AsyncCheckpointer:
    """One-slot async save queue (next save waits for the previous)."""

    def __init__(self, root: str):
        self.root = root
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None

    def save(self, step: int, state: Any) -> None:
        # snapshot to host *synchronously* (cheap bytes, correctness first),
        # write files in the background
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()
        self._pending = self._pool.submit(save_checkpoint, self.root, step,
                                          host_state)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
