from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, smoke_shape
from repro.configs.registry import get_config, get_shape, list_archs, all_cells

__all__ = [
    "ArchConfig", "ShapeSpec", "SHAPES", "smoke_shape",
    "get_config", "get_shape", "list_archs", "all_cells",
]
