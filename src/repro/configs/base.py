"""Architecture + input-shape configuration system.

Every assigned architecture is an ``ArchConfig`` instance in its own module
(one file per arch, ``src/repro/configs/<id>.py``) registered in
``registry.py``.  The *same* dataclass covers dense / MoE / SSM / hybrid /
enc-dec / VLM families; family-specific fields default to "off".

The four assigned input shapes are global (same names for every arch); a
shape is *realized* per-arch via :func:`ArchConfig.realize_shape`, which also
decides applicability (e.g. ``long_500k`` only for sub-quadratic archs).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical name set for every arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (seq_len, global_batch) input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # -- attention ----------------------------------------------------------
    head_dim: int = 0                # 0 -> d_model // num_heads
    attention_kind: str = "gqa"      # gqa | mla | none
    use_qk_norm: bool = False
    attn_softcap: float = 0.0        # 0 disables (gemma2: 50.0)
    final_softcap: float = 0.0       # 0 disables (gemma2: 30.0)
    sliding_window: int = 0          # 0 disables
    local_global_period: int = 0     # gemma2: 2 -> alternate [local, global]
    rope_theta: float = 10_000.0
    use_rope: bool = True

    # -- block / mlp --------------------------------------------------------
    block_kind: str = "transformer"  # transformer | mlstm | hymba | encdec
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu | none
    norm_eps: float = 1e-6
    post_block_norm: bool = False    # gemma2-style post norms
    tie_embeddings: bool = False
    embedding_scale: bool = False    # gemma2 scales embeds by sqrt(d)

    # -- MoE -----------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden
    moe_first_dense_layers: int = 0  # deepseek-v2: 1
    moe_capacity_factor: float = 1.25

    # -- MLA (deepseek-v2) ---------------------------------------------------
    mla_kv_lora_rank: int = 0        # 512
    mla_q_lora_rank: int = 0         # 1536
    mla_qk_nope_dim: int = 128
    mla_qk_rope_dim: int = 64
    mla_v_head_dim: int = 128

    # -- SSM / recurrent -----------------------------------------------------
    ssm_state: int = 0               # mamba state size (hymba: 16)
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0             # xlstm: one sLSTM per this many layers

    # -- enc-dec / frontends -------------------------------------------------
    encoder_layers: int = 0          # whisper: 24
    cross_attention: bool = False
    frontend: str = ""               # "" | "patch" (vlm) | "audio" (whisper)
    frontend_seq: int = 0            # stub-embedding sequence length
    max_positions: int = 0           # learned-position table size (whisper)

    # -- numerics / training -------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    loss_chunk: int = 512            # chunked cross-entropy (vocab-heavy archs)
    remat_policy: str = "full"       # none | dots | full
    scan_layers: bool = True         # lax.scan over homogeneous layer stacks
                                     # (compile time ~L x smaller; HLO cost
                                     # accounting corrects by trip count)

    # ------------------------------------------------------------------ api
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        if self.block_kind == "mlstm":
            return True
        if self.block_kind == "hymba":
            return True  # SWA + SSM
        return False

    @property
    def has_decoder(self) -> bool:
        """Encoder-only archs would return False; none assigned."""
        return True

    def shape_applicable(self, shape: ShapeSpec) -> Tuple[bool, str]:
        """(applicable, reason-if-not) for an assigned shape."""
        if shape.name == "long_500k" and not self.is_subquadratic:
            return False, ("pure full-attention arch: 500k-context decode is "
                           "skipped per assignment (sub-quadratic archs only)")
        if shape.is_decode and not self.has_decoder:
            return False, "encoder-only arch has no decode step"
        return True, ""

    # Per-arch overrides for the serve cache (sliding-window archs bound it).
    def cache_len(self, shape: ShapeSpec) -> int:
        if self.block_kind == "mlstm":
            return 0  # O(1) recurrent state, no KV cache
        if self.block_kind == "hymba":
            return min(self.sliding_window or 2048, shape.seq_len)
        return shape.seq_len

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        n_layers = 2
        if self.slstm_every:
            n_layers = max(2, min(self.slstm_every, 4))
        if self.local_global_period:
            n_layers = 2 * self.local_global_period
        kv = min(self.num_kv_heads, 2)
        heads = max(kv, min(self.num_heads, 4))
        # keep the heads:kv ratio GQA-like when possible
        if heads % kv:
            heads = kv
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            encoder_layers=min(self.encoder_layers, 2),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            moe_num_experts=min(self.moe_num_experts, 4) if self.moe_num_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_shared_experts=min(self.moe_shared_experts, 1),
            moe_d_ff=32 if self.moe_d_ff else 0,
            mla_kv_lora_rank=32 if self.mla_kv_lora_rank else 0,
            mla_q_lora_rank=48 if self.mla_q_lora_rank else 0,
            mla_qk_nope_dim=16 if self.mla_kv_lora_rank else 128,
            mla_qk_rope_dim=8 if self.mla_kv_lora_rank else 64,
            mla_v_head_dim=16 if self.mla_kv_lora_rank else 128,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            frontend_seq=min(self.frontend_seq, 8) if self.frontend_seq else 0,
            max_positions=min(self.max_positions, 64) if self.max_positions else 0,
            loss_chunk=64,
        )


def smoke_shape(kind: str = "train") -> ShapeSpec:
    if kind == "train":
        return ShapeSpec("smoke_train", 32, 4, "train")
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", 32, 2, "prefill")
    return ShapeSpec("smoke_decode", 32, 2, "decode")


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
    from repro.models import model as model_lib  # lazy; avoids cycle
    import jax
    specs = model_lib.param_specs(cfg)
    return sum(int(x.size) for x in jax.tree.leaves(specs))
