"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6 [arXiv:2405.04434].

60L d_model=5120 128H d_ff=1536(per expert) vocab=102400.  MLA: kv_lora 512,
q_lora 1536, qk_nope 128, qk_rope 64, v_head 128.  First layer uses a dense
FFN (d_ff 12288); remaining layers are MoE with 2 shared + 160 routed
experts, top-6 routing.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,    # MLA: per-assignment notation; cache is compressed
    d_ff=12288,          # dense-FFN width (layer 0)
    vocab_size=102400,
    attention_kind="mla",
    mla_kv_lora_rank=512,
    mla_q_lora_rank=1536,
    mla_qk_nope_dim=128,
    mla_qk_rope_dim=64,
    mla_v_head_dim=128,
    moe_num_experts=160,
    moe_top_k=6,
    moe_shared_experts=2,
    moe_d_ff=1536,
    moe_first_dense_layers=1,
    remat_policy="full",  # 236B: memory over recompute
)
