"""gemma2-2b — local+global alternating attention, logit softcaps [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.  Alternating
sliding-window(4096) / global layers, attn softcap 50, final softcap 30,
GeGLU MLP, pre+post RMSNorm, tied embeddings scaled by sqrt(d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    mlp_kind="geglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_period=2,
    post_block_norm=True,
    tie_embeddings=True,
    embedding_scale=True,
)
