"""HERO's own PMCA configuration space (paper Tab.1) as a config graph.

The paper's configurability table is reproduced verbatim as axes; the
flattened cells drive the Tab.2-analogue resource sweep.  Bold/underlined
values in the paper (Juno ADP / ZC706 implementations) are exposed as the
two named presets.
"""
from repro.core.buildflow import ConfigGraph


def pmca_config_space() -> ConfigGraph:
    g = (ConfigGraph()
         .axis("clusters", [1, 2, 4, 8])
         .axis("interconnect", ["bus", "noc"])
         .axis("pes_per_cluster", [2, 4, 8])
         .axis("fpu", ["private", "shared", "off"])
         .axis("l1_spm_kib", [32, 64, 128, 256])
         .axis("l2_spm_kib", [32, 64, 128, 256])
         .axis("icache_kib", [2, 4, 8])
         .axis("rab_l1_tlb", [4, 8, 16, 32, 64])
         .axis("rab_l2_tlb", [0, 256, 512, 1024, 2048])
         .axis("rab_l2_assoc", [16, 32, 64])
         .axis("rab_l2_banks", [1, 2, 4, 8])
         .constraint(lambda c: c["rab_l2_tlb"] == 0 or
                     c["rab_l2_tlb"] % (c["rab_l2_assoc"] * c["rab_l2_banks"])
                     == 0 or c["rab_l2_tlb"] % c["rab_l2_assoc"] == 0))
    return g


JUNO_ADP = {  # bold values in Tab.1 (8 clusters, Juno)
    "clusters": 8, "interconnect": "bus", "pes_per_cluster": 8,
    "fpu": "off", "l1_spm_kib": 256, "l2_spm_kib": 256, "icache_kib": 4,
    "rab_l1_tlb": 32, "rab_l2_tlb": 1024, "rab_l2_assoc": 32,
    "rab_l2_banks": 4, "clock_mhz": 31.0,
}

ZC706 = {  # underlined values (1 cluster, ZC706)
    "clusters": 1, "interconnect": "bus", "pes_per_cluster": 8,
    "fpu": "off", "l1_spm_kib": 256, "l2_spm_kib": 256, "icache_kib": 4,
    "rab_l1_tlb": 32, "rab_l2_tlb": 1024, "rab_l2_assoc": 16,
    "rab_l2_banks": 4, "clock_mhz": 57.0,
}
