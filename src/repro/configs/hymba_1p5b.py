"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each block runs an attention branch and a Mamba (selective-SSM) branch in
parallel on the same input and mean-fuses their (normed) outputs, followed
by an FFN.  Attention uses sliding window 2048 (hymba uses SWA on most
layers; meta-tokens are omitted — noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    block_kind="hymba",
    sliding_window=2048,
    ssm_state=16,
    ssm_conv_width=4,
    ssm_expand=2,
)
