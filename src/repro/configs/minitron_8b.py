"""minitron-8b — pruned nemotron [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.  Nemotron uses
squared-relu MLP; we keep the gated family default (swiglu) for the pruned
variant per the HF config's silu activation... minitron-8b-base uses
relu^2 -> modeled as plain gelu MLP (ungated) to match its 2-matrix FFN.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    mlp_kind="gelu",  # ungated 2-matrix FFN (nemotron relu^2 family)
)
