"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=1024(per expert) vocab=50304,
MoE 64e top-8, qk-norm per the OLMoE config.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=50304,
    head_dim=128,
    use_qk_norm=True,
    moe_num_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
)
