"""pixtral-12b — pixtral-ViT + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.  The vision frontend
is a STUB per assignment: ``input_specs()`` provides precomputed patch
embeddings of shape (batch, frontend_seq, d_model) merged at the head of the
token sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    frontend="patch",
    frontend_seq=256,   # 16x16 patch grid stub
)
