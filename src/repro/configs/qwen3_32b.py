"""qwen3-32b — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, head_dim=128,
per-head RMSNorm on q and k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    use_qk_norm=True,
    rope_theta=1_000_000.0,
)
