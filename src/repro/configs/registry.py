"""Architecture registry: ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec

_MODULES = {
    "xlstm-350m": "repro.configs.xlstm_350m",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "yi-6b": "repro.configs.yi_6b",
    "minitron-8b": "repro.configs.minitron_8b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "whisper-medium": "repro.configs.whisper_medium",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def all_cells():
    """Every (arch, shape) cell with its applicability verdict."""
    out = []
    for a in list_archs():
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = cfg.shape_applicable(s)
            out.append((cfg, s, ok, why))
    return out
