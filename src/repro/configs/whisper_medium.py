"""whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.  24 encoder + 24 decoder
layers (whisper-medium).  The conv/mel frontend is a STUB per assignment:
``input_specs()`` provides precomputed frame embeddings (batch, 1500, d).
GELU MLPs, LayerNorm, no RoPE (learned/sinusoidal positions).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    block_kind="encdec",
    mlp_kind="gelu",
    use_rope=False,
    cross_attention=True,
    frontend="audio",
    frontend_seq=1500,
    max_positions=32768,
)
