"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
carry their own up/down projections, there is no separate FFN.  One sLSTM
block per 8 layers (xLSTM[7:1] ratio), rest mLSTM.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    attention_kind="none",
    block_kind="mlstm",
    mlp_kind="none",
    use_rope=False,
    slstm_every=8,
)
