"""HERO's core contributions (C1-C5) adapted to the TPU/JAX target.

offload  - C1: OpenMP-target-style offload runtime (copy vs zero-copy)
svm      - C1/C2: shared handle space between host and accelerator
rab      - C2: two-level software TLB + miss protocol + paged KV pool
cluster  - C3: cluster = submesh abstraction over the model axis
tracing  - C4: non-intrusive in-step event tracing, freeze-and-drain
analysis - C4: three-layer event analysis with definable assertions
buildflow- C5: graph-based config matrix flattening
"""
from repro.core.rab import RAB, RABConfig, PagedKVPool, RABMiss
from repro.core.svm import SVMSpace, AddressCollision
from repro.core.offload import OffloadTarget, OffloadReport, HostBackingStore
from repro.core.tracing import TraceBuffer, EventType, HOST_TRACER_ID
from repro.core.cluster import (
    ClusterConfig, make_cluster_mesh, cluster_parallel_matmul,
    interconnect_model,
)
from repro.core.buildflow import ConfigGraph, hero_test_matrix

__all__ = [
    "RAB", "RABConfig", "PagedKVPool", "RABMiss",
    "SVMSpace", "AddressCollision",
    "OffloadTarget", "OffloadReport", "HostBackingStore",
    "TraceBuffer", "EventType", "HOST_TRACER_ID",
    "ClusterConfig", "make_cluster_mesh", "cluster_parallel_matmul",
    "interconnect_model",
    "ConfigGraph", "hero_test_matrix",
]
