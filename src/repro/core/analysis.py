"""Three-layer event analysis (HERO §2.3.1).

Layer 1 (generic): binary event rows -> time-sorted ``Event`` records with
platform metadata.
Layer 2 (platform): event-type specific decoding (memory accesses per core,
TLB protocol transitions, offload phases).
Layer 3 (application): user-defined analyses + *definable assertions*
(HERO §3.4b verifies hit-under-miss with exactly such assertions).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.core.tracing import EventType


@dataclasses.dataclass(frozen=True)
class Event:
    ts: int
    tracer: int
    etype: EventType
    a0: int
    a1: int

    @property
    def core(self) -> int:       # platform decode: a0 is the requester/core
        return self.a0

    @property
    def vpage(self) -> int:      # platform decode: a1 is the address/page
        return self.a1


def layer1_decode(rows: np.ndarray, platform: Optional[Dict] = None
                  ) -> List[Event]:
    """Generic: rows (N,5) -> time-sorted Events (per tracer domain)."""
    events = [Event(int(r[0]), int(r[1]), EventType(int(r[2])),
                    int(r[3]), int(r[4])) for r in rows]
    return sorted(events, key=lambda e: (e.tracer, e.ts))


def layer2_per_core(events: Iterable[Event]) -> Dict[int, List[Event]]:
    """Platform: split protocol events by core (HERO Fig.6 view)."""
    out: Dict[int, List[Event]] = defaultdict(list)
    for e in events:
        out[e.core].append(e)
    return dict(out)


def layer2_tlb_transactions(events: Iterable[Event]) -> List[Dict]:
    """Platform: stitch TLB protocol transitions into transactions."""
    open_miss: Dict[int, Dict] = {}
    done: List[Dict] = []
    for e in events:
        if e.etype in (EventType.TLB_L1_HIT, EventType.TLB_L2_HIT):
            done.append({"core": e.core, "vpage": e.vpage, "ts": e.ts,
                         "kind": "hit_l1" if e.etype == EventType.TLB_L1_HIT
                                 else "hit_l2", "latency": 0})
        elif e.etype == EventType.TLB_MISS:
            open_miss[e.core] = {"core": e.core, "vpage": e.vpage,
                                 "ts": e.ts, "kind": "miss"}
        elif e.etype == EventType.CORE_WAKE and e.core in open_miss:
            tx = open_miss.pop(e.core)
            tx["latency"] = e.ts - tx["ts"]
            done.append(tx)
    done.extend(open_miss.values())
    return done


def layer2_request_lifecycles(events: Iterable[Event]) -> Dict[int, List[Dict]]:
    """Platform: per-request scheduler lifecycle — admit / preempt (with
    swap-out page counts) / re-admit / finish — stitched from the serving
    event stream.  a0 is the request id for all scheduler events."""
    out: Dict[int, List[Dict]] = defaultdict(list)
    for e in events:
        if e.etype == EventType.REQUEST_ADMIT:
            out[e.a0].append({"kind": "admit", "ts": e.ts, "lane": e.a1})
        elif e.etype == EventType.REQUEST_PREEMPT:
            out[e.a0].append({"kind": "preempt", "ts": e.ts,
                              "swapped_pages": e.a1})
        elif e.etype == EventType.SWAP_IN:
            out[e.a0].append({"kind": "swap_in", "ts": e.ts, "pages": e.a1})
        elif e.etype == EventType.REQUEST_FINISH:
            out[e.a0].append({"kind": "finish", "ts": e.ts, "tokens": e.a1})
    return dict(out)


def layer2_latency(events: Iterable[Event]) -> Dict:
    """Platform: request latency structure from the serving event stream.

    ``REQUEST_ARRIVE`` (rid, queue depth) marks a request entering the
    engine queue; ``REQUEST_ADMIT`` (rid, lane) its first/each placement;
    ``REQUEST_FINISH`` (rid, tokens) its exit.  Timestamps are the host
    tracer's logical clock (event counts, not seconds — the engine's
    *wall* latency lives on the injected Clock and is reported by
    ``runtime.frontdoor.latency_report``), so what this view exposes is
    the *ordering* structure: how much scheduler activity elapsed between
    arrival, first admission and finish.  Returns per-request
    ``queue_delay`` (arrive -> first admit), ``service`` (first admit ->
    finish) and ``e2e`` plus aggregate means/maxima."""
    per: Dict[int, Dict] = {}
    for e in events:
        if e.etype == EventType.REQUEST_ARRIVE:
            per.setdefault(e.a0, {"arrive_ts": e.ts, "admit_ts": None,
                                  "finish_ts": None, "admissions": 0,
                                  "queue_depth": e.a1, "tokens": 0})
        elif e.etype == EventType.REQUEST_ADMIT and e.a0 in per:
            r = per[e.a0]
            r["admissions"] += 1
            if r["admit_ts"] is None:
                r["admit_ts"] = e.ts
        elif e.etype == EventType.REQUEST_FINISH and e.a0 in per:
            per[e.a0]["finish_ts"] = e.ts
            per[e.a0]["tokens"] = e.a1
    rows = []
    for rid, r in sorted(per.items()):
        queue_delay = (r["admit_ts"] - r["arrive_ts"]
                       if r["admit_ts"] is not None else None)
        service = (r["finish_ts"] - r["admit_ts"]
                   if r["admit_ts"] is not None
                   and r["finish_ts"] is not None else None)
        e2e = (r["finish_ts"] - r["arrive_ts"]
               if r["finish_ts"] is not None else None)
        rows.append((rid, dict(r, queue_delay=queue_delay,
                               service=service, e2e=e2e)))
    qd = [v["queue_delay"] for _, v in rows if v["queue_delay"] is not None]
    sv = [v["service"] for _, v in rows if v["service"] is not None]
    return {
        "requests": dict(rows),
        "arrived": len(rows),
        "finished": sum(1 for _, v in rows if v["finish_ts"] is not None),
        "mean_queue_delay": sum(qd) / len(qd) if qd else 0.0,
        "max_queue_delay": max(qd) if qd else 0,
        "mean_service": sum(sv) / len(sv) if sv else 0.0,
        "max_service": max(sv) if sv else 0,
    }


def layer2_calibration(events: Iterable[Event],
                       iter_time_s: Optional[float] = None) -> Dict:
    """Planner calibration: per-iteration service structure from a trace.

    ``layer2_latency`` reports queue/service spans in *logical event
    counts*, which depend on how chatty the tracer was.  The capacity
    planner needs those spans in *engine iterations* — the unit its
    simulator steps in and the unit ``iter_time_s`` prices.  The engine
    emits exactly one ``D2H`` token-pull event per iteration, so D2H
    events serve as iteration ticks: this walks the stream once,
    counting D2H ticks, and stamps each request's arrive / first-admit /
    finish with the tick count at that point.  Within an iteration the
    tick fires after admission and before finishes, so ``service_iters``
    (first admit -> finish) counts the iterations the request was
    actually active, inclusive, and ``queue_delay_iters`` (arrive ->
    first admit) the full iterations it waited.  Caveat: preemption
    swap-outs and tier demotions also pull pages D2H, so calibrate from
    a trace without swap traffic (the smoke bench) or treat the result
    as an upper bound on the tick count.  When ``iter_time_s`` is
    given, also returns the seconds conversions (``mean_service_s``
    etc.) — exactly the :class:`repro.planner.costs.Calibration`
    input."""
    per: Dict[int, Dict] = {}
    it = 0
    for e in events:
        if e.etype == EventType.D2H:
            it += 1
        elif e.etype == EventType.REQUEST_ARRIVE:
            per.setdefault(e.a0, {"arrive_iter": it, "admit_iter": None,
                                  "finish_iter": None})
        elif e.etype == EventType.REQUEST_ADMIT and e.a0 in per:
            if per[e.a0]["admit_iter"] is None:
                per[e.a0]["admit_iter"] = it
        elif e.etype == EventType.REQUEST_FINISH and e.a0 in per:
            per[e.a0]["finish_iter"] = it
    rows: Dict[int, Dict] = {}
    for rid, r in sorted(per.items()):
        queue = (r["admit_iter"] - r["arrive_iter"]
                 if r["admit_iter"] is not None else None)
        service = (r["finish_iter"] - r["admit_iter"]
                   if r["admit_iter"] is not None
                   and r["finish_iter"] is not None else None)
        rows[rid] = dict(r, queue_delay_iters=queue, service_iters=service)
    qd = [v["queue_delay_iters"] for v in rows.values()
          if v["queue_delay_iters"] is not None]
    sv = [v["service_iters"] for v in rows.values()
          if v["service_iters"] is not None]
    out = {
        "requests": rows,
        "iterations": it,
        "arrived": len(rows),
        "finished": sum(1 for v in rows.values()
                        if v["finish_iter"] is not None),
        "mean_queue_delay_iters": sum(qd) / len(qd) if qd else 0.0,
        "max_queue_delay_iters": max(qd) if qd else 0,
        "mean_service_iters": sum(sv) / len(sv) if sv else 0.0,
        "max_service_iters": max(sv) if sv else 0,
    }
    if iter_time_s is not None:
        out["iter_time_s"] = iter_time_s
        out["mean_queue_delay_s"] = out["mean_queue_delay_iters"] * iter_time_s
        out["mean_service_s"] = out["mean_service_iters"] * iter_time_s
        out["duration_s"] = it * iter_time_s
    return out


def layer2_cluster_balance(events: Iterable[Event],
                           n_clusters: Optional[int] = None) -> Dict:
    """Platform: per-cluster placement balance for the sharded engine.

    CLUSTER_DISPATCH carries (rid, cluster); ALL_GATHER carries
    (iteration, active clusters).  Returns per-cluster dispatch counts and
    request sets plus a min/max balance ratio (1.0 = perfectly balanced,
    0.0 = some cluster never used while another was).  Pass ``n_clusters``
    so clusters that never dispatched count as zero — without it only
    clusters present in the event stream are visible."""
    per: Dict[int, Dict] = {}
    gathers = 0
    for e in events:
        if e.etype == EventType.CLUSTER_DISPATCH:
            c = per.setdefault(e.a1, {"dispatches": 0, "requests": set()})
            c["dispatches"] += 1
            c["requests"].add(e.a0)
        elif e.etype == EventType.ALL_GATHER:
            gathers += 1
    for c in range(n_clusters or 0):
        per.setdefault(c, {"dispatches": 0, "requests": set()})
    counts = [c["dispatches"] for c in per.values()]
    balance = (min(counts) / max(counts)) if counts and max(counts) else 1.0
    return {
        "clusters": {k: {"dispatches": v["dispatches"],
                         "requests": sorted(v["requests"])}
                     for k, v in sorted(per.items())},
        "all_gathers": gathers,
        "balance": balance,
    }


def layer2_speculation(events: Iterable[Event]) -> Dict:
    """Platform: speculative-decoding efficiency from the event stream.

    SPEC_PROPOSE / SPEC_ACCEPT / SPEC_ROLLBACK all carry (rid, tokens).
    Returns per-request and aggregate proposed/accepted/rolled-back token
    counts, the acceptance rate, and ``wasted_verify_tokens`` — positions
    the verify step scored and then rolled back (the price paid for the
    iterations saved)."""
    per: Dict[int, Dict[str, int]] = {}

    def row(rid: int) -> Dict[str, int]:
        return per.setdefault(rid, {"proposed": 0, "accepted": 0,
                                    "rolled_back": 0, "verify_rounds": 0})

    for e in events:
        if e.etype == EventType.SPEC_PROPOSE:
            r = row(e.a0)
            r["proposed"] += e.a1
            r["verify_rounds"] += 1
        elif e.etype == EventType.SPEC_ACCEPT:
            row(e.a0)["accepted"] += e.a1
        elif e.etype == EventType.SPEC_ROLLBACK:
            row(e.a0)["rolled_back"] += e.a1
    proposed = sum(r["proposed"] for r in per.values())
    accepted = sum(r["accepted"] for r in per.values())
    rolled = sum(r["rolled_back"] for r in per.values())
    return {
        "requests": dict(sorted(per.items())),
        "proposed": proposed,
        "accepted": accepted,
        "rolled_back": rolled,
        "acceptance_rate": accepted / proposed if proposed else 0.0,
        "wasted_verify_tokens": rolled,
    }


def assert_spec_conserves(events: List[Event]) -> bool:
    """Per request: accepted + rolled_back == proposed (every drafted
    token is either confirmed or undone — none vanish, none double)."""
    for r in layer2_speculation(events)["requests"].values():
        if r["accepted"] + r["rolled_back"] != r["proposed"]:
            return False
    return True


def layer2_fault_recovery(events: Iterable[Event]) -> Dict:
    """Platform: stitch the fault/recovery story from the event stream.

    ``FAULT_INJECT`` carries (rid, kind code 1=io/2=corrupt/3=stall, +8
    when persistent); ``REQUEST_TIMEOUT`` (rid, iteration);
    ``REQUEST_SHED`` (rid, queue depth); ``DEGRADE`` (subject, cause:
    1=drafter disabled, 2=watchdog abort, 3=straggler iteration).
    Returns aggregate fault counts by kind, timeout/shed/degrade tallies
    and the per-request fault exposure — including whether each faulted
    request still reached ``REQUEST_FINISH`` (the containment property
    :func:`assert_faults_contained` gates on)."""
    kinds = {1: "io", 2: "corrupt", 3: "stall"}
    causes = {1: "drafter", 2: "watchdog", 3: "straggler"}
    per: Dict[int, Dict] = {}

    def row(rid: int) -> Dict:
        return per.setdefault(rid, {"faults": 0, "kinds": [],
                                    "persistent": 0, "finished": False})

    out = {
        "faults": 0,
        "by_kind": {k: 0 for k in kinds.values()},
        "persistent_faults": 0,
        "timeouts": 0,
        "sheds": 0,
        "degrades": {c: 0 for c in causes.values()},
    }
    for e in events:
        if e.etype == EventType.FAULT_INJECT:
            kind = kinds.get(e.a1 & 7, "io")
            r = row(e.a0)
            r["faults"] += 1
            if kind not in r["kinds"]:
                r["kinds"].append(kind)
            out["faults"] += 1
            out["by_kind"][kind] += 1
            if e.a1 & 8:
                out["persistent_faults"] += 1
                r["persistent"] += 1
        elif e.etype == EventType.REQUEST_TIMEOUT:
            out["timeouts"] += 1
            row(e.a0)["timed_out"] = True
        elif e.etype == EventType.REQUEST_SHED:
            out["sheds"] += 1
        elif e.etype == EventType.DEGRADE:
            out["degrades"][causes.get(e.a1, "watchdog")] += 1
        elif e.etype == EventType.REQUEST_FINISH and e.a0 in per:
            per[e.a0]["finished"] = True
    out["requests"] = dict(sorted(per.items()))
    return out


def assert_faults_contained(events: List[Event]) -> bool:
    """Fault containment (layer-3, HERO §3.4b style): every request that
    ever saw an injected fault, deadline timeout or shed decision still
    reaches a ``REQUEST_FINISH`` event — faults demote or recover
    individual requests, they never lose one (and never kill the engine,
    which could not have kept emitting finishes)."""
    touched = set()
    finished = set()
    for e in events:
        if e.etype in (EventType.FAULT_INJECT, EventType.REQUEST_TIMEOUT,
                       EventType.REQUEST_SHED):
            touched.add(e.a0)
        elif e.etype == EventType.REQUEST_FINISH:
            finished.add(e.a0)
    return touched <= finished


def layer2_tier_residency(events: Iterable[Event]) -> Dict:
    """Platform: hierarchical prefix-cache tier story from the event
    stream.

    ``PAGE_DEMOTE`` / ``PAGE_PROMOTE`` carry ``(entry_id,
    src_tier * 4 + dst_tier)`` with tiers 0=device, 1=host, 2=disk,
    3=dropped.  Returns each entry's transition chain plus aggregate move
    counts by (src, dst), admission-hit tallies per serving tier
    (promotions back to device, split by where the payload came from) and
    the set of entries that ended dropped."""
    tiers = {0: "device", 1: "host", 2: "disk", 3: "dropped"}
    chains: Dict[int, List[Dict]] = defaultdict(list)
    moves: Dict[str, int] = defaultdict(int)
    promoted_from: Dict[str, int] = defaultdict(int)
    for e in events:
        if e.etype not in (EventType.PAGE_DEMOTE, EventType.PAGE_PROMOTE):
            continue
        src, dst = tiers[(e.a1 >> 2) & 3], tiers[e.a1 & 3]
        chains[e.a0].append({"ts": e.ts, "src": src, "dst": dst,
                             "kind": ("demote"
                                      if e.etype == EventType.PAGE_DEMOTE
                                      else "promote")})
        moves[f"{src}->{dst}"] += 1
        if e.etype == EventType.PAGE_PROMOTE:
            promoted_from[src] += 1
    residency: Dict[int, str] = {
        eid: chain[-1]["dst"] for eid, chain in chains.items()}
    return {
        "entries": dict(sorted(chains.items())),
        "moves": dict(sorted(moves.items())),
        "promoted_from": dict(sorted(promoted_from.items())),
        "residency": dict(sorted(residency.items())),
        "dropped": sorted(e for e, t in residency.items()
                          if t == "dropped"),
    }


def assert_tier_conservation(events: List[Event]) -> bool:
    """No indexed page is lost or duplicated across tiers: every entry's
    demote/promote chain is *contiguous* — each move departs from the tier
    the previous move arrived at.  An entry's first move must leave the
    device tier (entries are born on-device by registration), and after
    being dropped any tier may re-source it (a fresh on-device
    re-registration of the same prefix restarts the chain)."""
    where: Dict[int, int] = {}
    for e in events:
        if e.etype not in (EventType.PAGE_DEMOTE, EventType.PAGE_PROMOTE):
            continue
        src, dst = (e.a1 >> 2) & 3, e.a1 & 3
        cur = where.get(e.a0, 0)          # entries start on-device
        if cur != src and cur != 3:       # dropped -> re-registered: reset
            return False
        where[e.a0] = dst
    return True


def assert_swaps_balanced(events: List[Event]) -> bool:
    """Every page swapped out for a request that eventually finished was
    swapped back in first (no request completes on lost KV state)."""
    out_pages: Dict[int, int] = defaultdict(int)
    for e in events:
        if e.etype == EventType.SWAP_OUT:
            out_pages[e.a0] += e.a1
        elif e.etype == EventType.SWAP_IN:
            out_pages[e.a0] -= e.a1
        elif e.etype == EventType.REQUEST_FINISH:
            if out_pages.get(e.a0, 0) != 0:
                return False
    return True


@dataclasses.dataclass
class Assertion:
    """Layer-3 definable assertion over the event stream (HERO §3.4b)."""

    name: str
    predicate: Callable[[List[Event]], bool]
    description: str = ""

    def check(self, events: List[Event]) -> bool:
        return bool(self.predicate(events))


def assert_hit_under_miss(events: List[Event]) -> bool:
    """While a miss is outstanding on core A, hits by other cores must
    still complete (HERO §3.4b's exact property)."""
    outstanding = set()
    ok = True
    for e in events:
        if e.etype == EventType.TLB_MISS:
            outstanding.add(e.core)
        elif e.etype == EventType.CORE_WAKE:
            outstanding.discard(e.core)
        elif e.etype in (EventType.TLB_L1_HIT, EventType.TLB_L2_HIT):
            if e.core in outstanding:
                ok = False  # a sleeping core cannot issue translations
    return ok


def assert_wake_follows_handle(events: List[Event]) -> bool:
    handled = set()
    for e in events:
        if e.etype == EventType.MISS_HANDLED:
            handled.add((e.core, e.vpage))
        elif e.etype == EventType.CORE_WAKE:
            if (e.core, e.vpage) not in handled:
                return False
    return True


def layer3_run(events: List[Event], assertions: Iterable[Assertion]
               ) -> Dict[str, bool]:
    return {a.name: a.check(events) for a in assertions}


def render_timeline(events: List[Event], max_rows: int = 40) -> str:
    """Fig.6-style compressed per-core textual timeline."""
    lines = []
    for core, evs in sorted(layer2_per_core(events).items()):
        cells = []
        last_ts = None
        for e in evs[:max_rows]:
            if last_ts is not None and e.ts - last_ts > 1:
                cells.append("..")
            cells.append(f"{e.etype.name}@{e.ts}(p{e.vpage})")
            last_ts = e.ts
        lines.append(f"core {core:3d}: " + " ".join(cells))
    return "\n".join(lines)
