"""Automated build-and-test flow (HERO §2.3.2).

HERO specifies platform-application-parameter combinations in a *graph-based
notation* which the integration server flattens into the concrete test
matrix ("listing all combinations manually would be redundant, error-prone
work").  This module is that notation: axes + compatibility edges -> flat
cells.  It drives the smoke-test matrix, the dry-run matrix, and the bench
matrix; 'bitstream build' maps to AOT ``lower().compile()``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, Iterable, List


@dataclasses.dataclass
class Axis:
    name: str
    values: List[Any]


class ConfigGraph:
    """Axes + constraints -> flattened combination cells."""

    def __init__(self):
        self.axes: Dict[str, Axis] = {}
        self.constraints: List[Callable[[Dict[str, Any]], bool]] = []
        self.annotators: List[Callable[[Dict[str, Any]], Dict[str, Any]]] = []

    def axis(self, name: str, values: Iterable[Any]) -> "ConfigGraph":
        self.axes[name] = Axis(name, list(values))
        return self

    def constraint(self, fn: Callable[[Dict[str, Any]], bool]) -> "ConfigGraph":
        """Edge predicate: cell kept only if fn(cell) is truthy."""
        self.constraints.append(fn)
        return self

    def annotate(self, fn: Callable[[Dict[str, Any]], Dict[str, Any]]
                 ) -> "ConfigGraph":
        """Attach derived fields (e.g. run arguments) to surviving cells."""
        self.annotators.append(fn)
        return self

    def cells(self) -> List[Dict[str, Any]]:
        names = list(self.axes)
        out: List[Dict[str, Any]] = []
        for combo in itertools.product(*(self.axes[n].values for n in names)):
            cell = dict(zip(names, combo))
            if all(c(cell) for c in self.constraints):
                for a in self.annotators:
                    cell.update(a(cell) or {})
                out.append(cell)
        return out

    def __len__(self) -> int:
        return len(self.cells())


def hero_test_matrix() -> ConfigGraph:
    """The project's own §2.3.2 matrix: archs x shapes x meshes."""
    from repro.configs import SHAPES, get_config, list_archs

    g = ConfigGraph()
    g.axis("arch", list_archs())
    g.axis("shape", list(SHAPES))
    g.axis("mesh", ["single", "multi"])
    g.constraint(lambda c: get_config(c["arch"]).shape_applicable(
        SHAPES[c["shape"]])[0])
    g.annotate(lambda c: {"kind": SHAPES[c["shape"]].kind})
    return g
