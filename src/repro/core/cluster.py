"""Cluster abstraction (HERO §2.1/§3.2): the PMCA as clusters of PEs.

HERO's PMCA is 1..8 clusters of 2..8 RISC-V PEs behind a bus-or-NoC
system interconnect; §3.2 parallelizes matmul row-wise over clusters and
finds the bus binding at 8 clusters.  TPU adaptation: a *cluster* is a
slice of the ``model`` mesh axis; the system interconnect is ICI; the
per-cluster compute is the SPM-tiled matmul (``kernels/cluster_matmul``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    n_clusters: int = 8            # Tab.1: 1,2,4,8
    pes_per_cluster: int = 8       # Tab.1: 2,4,8
    interconnect: str = "bus"      # Tab.1: bus | noc
    l1_spm_kib: int = 256
    clock_mhz: float = 31.0        # Juno ADP implementation (§3.1)

    @property
    def total_pes(self) -> int:
        return self.n_clusters * self.pes_per_cluster

    def nominal_gips(self) -> float:
        """§1: 64 cores @ >30 MHz -> >1.9 GIPS (1 instr/cycle/PE)."""
        return self.total_pes * self.clock_mhz * 1e6 / 1e9


def make_cluster_mesh(n_clusters: int) -> Mesh:
    """Mesh over the available (virtual) devices with a 'cluster' axis."""
    n = min(n_clusters, len(jax.devices()))
    return jax.make_mesh((n,), ("cluster",))


def cluster_parallel_matmul(mesh: Mesh, a: jax.Array, b: jax.Array,
                            per_cluster_fn: Optional[Callable] = None
                            ) -> jax.Array:
    """C = A @ B, A/C tiled row-wise over clusters (HERO §3.2's layout).

    Each cluster DMAs its row block of A and all of B into local memory,
    computes its row block of C, and writes it back — with `shard_map`, the
    per-cluster body is literally the single-cluster program.
    """
    from jax.experimental.shard_map import shard_map

    per_cluster_fn = per_cluster_fn or (lambda at, bt: at @ bt)

    def body(at, bt):
        return per_cluster_fn(at, bt)

    f = shard_map(body, mesh=mesh,
                  in_specs=(P("cluster", None), P(None, None)),
                  out_specs=P("cluster", None))
    return f(a, b)


def interconnect_model(cfg: ClusterConfig, total_bytes: int,
                       total_compute_s: float) -> dict:
    """Analytic bus-vs-NoC model reproducing Fig.4.

    DMA is double-buffered (overlapped with compute — the SPM/DMA model), so
    a cluster's runtime is max(compute, its transfer share).  On the *bus*
    all clusters' transfers serialize through one port; on the *NoC* they
    proceed in parallel.  With the paper's matmul intensity the bus only
    binds at 8 clusters (~2% below ideal), which calibrates the port
    bandwidth constant below.
    """
    n = cfg.n_clusters
    # bus port calibrated so serialized DMA = 1.02 x compute at 8 clusters
    bus_transfer_s = 1.02 * (total_compute_s / 8.0) * \
        (total_bytes / max(total_bytes, 1))
    if cfg.interconnect == "bus":
        transfer_s = bus_transfer_s                 # serialized, whole-job
    else:
        transfer_s = bus_transfer_s / n             # parallel links
    single = total_compute_s                        # 1 cluster, DMA hidden
    par = max(total_compute_s / n, transfer_s)
    return {
        "n_clusters": n,
        "interconnect": cfg.interconnect,
        "single_cluster_s": single,
        "parallel_s": par,
        "speedup": single / par,
        "ideal": n,
        "efficiency": (single / par) / n,
    }
