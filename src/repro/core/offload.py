"""OpenMP-4.5-style offload runtime (HERO §2.2), copy-based vs zero-copy.

HERO encapsulates accelerator kernels in ``omp target`` regions; the RTE
plugin implements two offload semantics:

  * copy-based shared memory: inputs are serialized into a physically
    contiguous, uncached staging area (pointer-rich structures must be
    flattened and their pointers rewritten), copied to the accelerator,
    outputs copied back;
  * zero-copy SVM: host passes virtual-address *pointers*; the PMCA
    translates through the RAB at run time.

The JAX adaptation maps a ``target`` region to a jitted function.  Copy mode
stages through host numpy (serialize -> contiguous buffer -> device_put ->
run -> device_get).  Zero-copy mode passes SVM handles to device-resident
buffers (no host staging, donation allowed).  ``OffloadReport`` splits total
time into offload vs kernel, reproducing the Fig.5 measurement.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.svm import SVMSpace
from repro.core.tracing import EventType, TraceBuffer


@dataclasses.dataclass
class OffloadReport:
    mode: str                 # "copy" | "zero_copy"
    offload_s: float          # host-side data preparation + transfers
    kernel_s: float           # device execution
    writeback_s: float        # copy-back (copy mode only)
    bytes_to: int = 0
    bytes_from: int = 0

    @property
    def total_s(self) -> float:
        return self.offload_s + self.kernel_s + self.writeback_s


def _nbytes(tree: Any) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


class BackingStoreError(RuntimeError):
    """Typed backing-store failure carrying the exact swap operation that
    broke: (rid, logical page, op).  ``transient`` distinguishes faults
    worth retrying (injected I/O hiccups) from persistent ones (missing
    page, double-park, checksum mismatch) which the engine must demote to
    a per-request ``"error"`` finish instead of retrying forever."""

    def __init__(self, rid: int, lpage: int, op: str, kind: str = "io",
                 *, transient: bool = False, detail: str = ""):
        msg = f"backing store {op} failed for rid={rid} lpage={lpage} " \
              f"[{kind}]"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)
        self.rid = rid
        self.lpage = lpage
        self.op = op
        self.kind = kind
        self.transient = transient


class HostBackingStore:
    """Host-DRAM backing store for reclaimed KV pages (swap space).

    When the serving scheduler preempts a sequence, its pages are dropped
    from the device pool (non-shared ones are thereby reclaimed): the
    payload crosses D2H into this store and crosses back H2D on
    re-admission.  This is HERO's SVM page
    reclamation (§2.2): because translation is software-managed, a physical
    page can be repurposed while its *content* survives in host memory, and
    the mapping is re-established later without the accelerator noticing
    anything but a RAB refill.

    The store only keeps host copies and byte counters; the engine owns the
    transfers themselves (and traces them as SWAP_OUT / SWAP_IN plus the
    underlying H2D / D2H events).

    Failure semantics: ``put``/``pop`` raise :class:`BackingStoreError`
    (never a bare ``KeyError`` or a silent overwrite), every parked payload
    is checksummed at park time and verified on restore (a mismatch is a
    persistent ``corrupt`` fault), and an optional ``fault_injector``
    (``runtime.faults.FaultInjector``) perturbs the swap path with seeded,
    deterministic I/O errors / corruption / stalls for chaos testing."""

    def __init__(self, fault_injector=None):
        self._pages: Dict[Tuple[int, int], np.ndarray] = {}
        self._sums: Dict[Tuple[int, int], int] = {}
        self.faults = fault_injector
        self.bytes_out = 0       # device -> host (swap-out)
        self.bytes_in = 0        # host -> device (swap-in)
        self.peak_pages = 0

    def put(self, seq: int, lpage: int, payload: np.ndarray):
        key = (seq, lpage)
        if key in self._pages:
            raise BackingStoreError(
                seq, lpage, "put", "overwrite",
                detail="page is already parked (double swap-out)")
        arr = np.ascontiguousarray(np.asarray(payload))
        spec = None
        if self.faults is not None:
            spec = self.faults.before("put", seq, lpage)   # may raise/stall
        self._sums[key] = zlib.crc32(arr.tobytes())
        if spec is not None and spec.kind == "corrupt":
            # silent bit-rot after the checksum was taken: the damage is
            # only discovered at swap-in, as a checksum mismatch
            arr = arr.copy()
            arr.view(np.uint8).reshape(-1)[0] ^= 0xFF
        self._pages[key] = arr
        self.bytes_out += arr.nbytes
        self.peak_pages = max(self.peak_pages, len(self._pages))

    def pop(self, seq: int, lpage: int) -> np.ndarray:
        key = (seq, lpage)
        if key not in self._pages:
            raise BackingStoreError(
                seq, lpage, "pop", "missing",
                detail="page was never parked (or already restored)")
        if self.faults is not None:
            self.faults.before("pop", seq, lpage)          # may raise/stall
        arr = self._pages.pop(key)
        crc = self._sums.pop(key)
        if zlib.crc32(arr.tobytes()) != crc:
            raise BackingStoreError(
                seq, lpage, "pop", "corrupt", transient=False,
                detail="checksum mismatch on restore")
        self.bytes_in += arr.nbytes
        return arr

    def repark(self, seq: int, lpage: int, payload: np.ndarray):
        """Undo a successful :meth:`pop` whose *batch* failed: the engine
        popped several pages for one swap-in, a later page faulted
        transiently, and the whole resume is being deferred — the
        already-popped payloads go back exactly as they were.  No fault
        injection (the op already succeeded once; re-parking is engine
        bookkeeping, not new I/O) and the ``bytes_in`` the pop counted is
        credited back, so a deferred attempt costs no phantom traffic."""
        key = (seq, lpage)
        if key in self._pages:
            raise BackingStoreError(
                seq, lpage, "repark", "overwrite",
                detail="page is already parked (repark without pop)")
        arr = np.ascontiguousarray(np.asarray(payload))
        self._sums[key] = zlib.crc32(arr.tobytes())
        self._pages[key] = arr
        self.bytes_in -= arr.nbytes
        self.peak_pages = max(self.peak_pages, len(self._pages))

    def discard(self, seq: int):
        """Drop every parked page of ``seq`` without counting swap-in
        traffic (the abort path: payload is released, never restored)."""
        for k in [k for k in self._pages if k[0] == seq]:
            del self._pages[k]
            self._sums.pop(k, None)

    def __len__(self) -> int:
        return len(self._pages)


class OffloadTarget:
    """The 'PMCA': a jit compilation target + the offload RTE around it."""

    def __init__(self, svm: Optional[SVMSpace] = None,
                 tracer: Optional[TraceBuffer] = None):
        self.svm = svm or SVMSpace()
        self.tracer = tracer
        self._compiled: Dict[int, Callable] = {}

    def _trace(self, etype: EventType, a: int = 0, b: int = 0):
        if self.tracer is not None:
            self.tracer.record_host(etype, a, b)

    # ------------------------------------------------------------------
    def target(self, fn: Callable, *, donate: Sequence[int] = ()) -> Callable:
        """Mark a kernel for offload (the `omp target` outline step)."""
        key = id(fn)
        if key not in self._compiled:
            self._compiled[key] = jax.jit(fn, donate_argnums=tuple(donate))
        return self._compiled[key]

    # ------------------------------------------------------------------
    def run_copy_based(self, fn: Callable, *host_args: Any
                       ) -> Tuple[Any, OffloadReport]:
        """Copy-based SM offload: serialize -> stage -> run -> copy back.

        ``host_args`` are host-side structures (numpy arrays or nested
        containers).  The serialization into one contiguous staging buffer
        models HERO's physically-contiguous uncached section, including the
        pointer-flattening cost for linked structures.
        """
        jfn = self.target(fn)
        self._trace(EventType.OFFLOAD_BEGIN, 0, 0)
        t0 = time.perf_counter()
        # serialize: flatten + force one contiguous copy of every leaf
        leaves, treedef = jax.tree.flatten(host_args)
        staged = [np.ascontiguousarray(np.asarray(x)) for x in leaves]
        blob_bytes = sum(x.nbytes for x in staged)
        # stage to device (the DMA across the host/PMCA boundary)
        dev = [jax.device_put(x) for x in staged]
        for d in dev:
            d.block_until_ready()
        t1 = time.perf_counter()
        self._trace(EventType.OFFLOAD_COPY_TO, blob_bytes % (1 << 31), 0)

        self._trace(EventType.OFFLOAD_KERNEL_BEGIN, 0, 0)
        out = jfn(*jax.tree.unflatten(treedef, dev))
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        self._trace(EventType.OFFLOAD_KERNEL_END, 0, 0)

        # copy back to host memory (uncached section -> host structures)
        host_out = jax.tree.map(lambda x: np.asarray(x), out)
        t3 = time.perf_counter()
        self._trace(EventType.OFFLOAD_COPY_FROM, _nbytes(host_out) % (1 << 31), 0)
        self._trace(EventType.OFFLOAD_END, 0, 0)
        rep = OffloadReport("copy", t1 - t0, t2 - t1, t3 - t2,
                            bytes_to=blob_bytes, bytes_from=_nbytes(host_out))
        return host_out, rep

    # ------------------------------------------------------------------
    def run_zero_copy(self, fn: Callable, *handles: int, donate: Sequence[int] = ()
                      ) -> Tuple[Any, OffloadReport]:
        """Zero-copy SVM offload: pass pointers, no staging.

        ``handles`` are SVM handles to device-resident buffers.  The kernel's
        outputs are published back into SVM and returned as handles too —
        the host never touches the payload (Fig.5's SVM bars).
        """
        jfn = self.target(fn, donate=donate)
        self._trace(EventType.OFFLOAD_BEGIN, 1, 0)
        t0 = time.perf_counter()
        args = [self.svm.deref(h) for h in handles]       # pointer deref only
        t1 = time.perf_counter()
        self._trace(EventType.OFFLOAD_KERNEL_BEGIN, 0, 0)
        out = jfn(*args)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        self._trace(EventType.OFFLOAD_KERNEL_END, 0, 0)
        out_handles = jax.tree.map(self.svm.share, out)
        self._trace(EventType.OFFLOAD_END, 0, 0)
        rep = OffloadReport("zero_copy", t1 - t0, t2 - t1, 0.0)
        return out_handles, rep
