"""OpenMP-4.5-style offload runtime (HERO §2.2), copy-based vs zero-copy.

HERO encapsulates accelerator kernels in ``omp target`` regions; the RTE
plugin implements two offload semantics:

  * copy-based shared memory: inputs are serialized into a physically
    contiguous, uncached staging area (pointer-rich structures must be
    flattened and their pointers rewritten), copied to the accelerator,
    outputs copied back;
  * zero-copy SVM: host passes virtual-address *pointers*; the PMCA
    translates through the RAB at run time.

The JAX adaptation maps a ``target`` region to a jitted function.  Copy mode
stages through host numpy (serialize -> contiguous buffer -> device_put ->
run -> device_get).  Zero-copy mode passes SVM handles to device-resident
buffers (no host staging, donation allowed).  ``OffloadReport`` splits total
time into offload vs kernel, reproducing the Fig.5 measurement.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import shutil
import tempfile
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.svm import SVMSpace
from repro.core.tracing import EventType, TraceBuffer


@dataclasses.dataclass
class OffloadReport:
    mode: str                 # "copy" | "zero_copy"
    offload_s: float          # host-side data preparation + transfers
    kernel_s: float           # device execution
    writeback_s: float        # copy-back (copy mode only)
    bytes_to: int = 0
    bytes_from: int = 0

    @property
    def total_s(self) -> float:
        return self.offload_s + self.kernel_s + self.writeback_s


def _nbytes(tree: Any) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


class BackingStoreError(RuntimeError):
    """Typed backing-store failure carrying the exact swap operation that
    broke: (rid, logical page, op).  ``transient`` distinguishes faults
    worth retrying (injected I/O hiccups) from persistent ones (missing
    page, double-park, checksum mismatch) which the engine must demote to
    a per-request ``"error"`` finish instead of retrying forever."""

    def __init__(self, rid: int, lpage: int, op: str, kind: str = "io",
                 *, transient: bool = False, detail: str = ""):
        msg = f"backing store {op} failed for rid={rid} lpage={lpage} " \
              f"[{kind}]"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)
        self.rid = rid
        self.lpage = lpage
        self.op = op
        self.kind = kind
        self.transient = transient


# Tier codes shared by the trace events (PAGE_DEMOTE / PAGE_PROMOTE pack
# ``src * 4 + dst`` into arg1) and ``core.analysis.layer2_tier_residency``.
TIER_DEVICE = 0
TIER_HOST = 1
TIER_DISK = 2
TIER_DROPPED = 3
TIER_NAMES = {TIER_DEVICE: "device", TIER_HOST: "host",
              TIER_DISK: "disk", TIER_DROPPED: "dropped"}
TIER_CODES = {v: k for k, v in TIER_NAMES.items()}


class BackingTier:
    """One level of the host-side backing hierarchy.

    A tier is a flat ``key -> payload`` map with a page-count capacity
    (``0`` = unbounded).  :class:`HostBackingStore` composes tiers into a
    spill chain and owns all policy — LRU ordering, checksums, cascade on
    overflow, fault injection — so a tier only needs dumb storage.  This is
    the HERO SVM ladder: scratchpad (device pool) -> host DRAM
    (:class:`HostTier`) -> storage (:class:`DiskTier`), each level slower
    and larger than the one above it."""

    name = "tier"

    def __init__(self, capacity_pages: int = 0):
        self.capacity_pages = capacity_pages

    def store(self, key: Tuple, payload: np.ndarray) -> None:
        raise NotImplementedError

    def load(self, key: Tuple) -> np.ndarray:
        raise NotImplementedError

    def delete(self, key: Tuple) -> None:
        raise NotImplementedError

    def __contains__(self, key: Tuple) -> bool:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def close(self) -> None:
        pass


class HostTier(BackingTier):
    """Host-DRAM tier: plain in-memory dict."""

    name = "host"

    def __init__(self, capacity_pages: int = 0):
        super().__init__(capacity_pages)
        self._data: Dict[Tuple, np.ndarray] = {}

    def store(self, key, payload):
        self._data[key] = payload

    def load(self, key):
        return self._data[key]

    def delete(self, key):
        del self._data[key]

    def __contains__(self, key):
        return key in self._data

    def __len__(self):
        return len(self._data)

    def close(self):
        self._data.clear()


class DiskTier(BackingTier):
    """Disk tier: one ``.npy`` file per parked page.

    If ``directory`` is ``None`` the tier creates (and on :meth:`close`
    removes) its own temp directory; a caller-provided directory is left in
    place, with only the tier's own files deleted — so benchmarks can own
    the lifetime in a ``finally`` block."""

    name = "disk"

    def __init__(self, capacity_pages: int = 0,
                 directory: Optional[str] = None):
        super().__init__(capacity_pages)
        self._owns_dir = directory is None
        self._dir = directory
        self._files: Dict[Tuple, str] = {}
        # page payloads are written as raw bytes (np.save would degrade
        # extension dtypes like bfloat16 to void); dtype+shape ride here
        self._meta: Dict[Tuple, Tuple] = {}
        self._serial = 0

    def _ensure_dir(self) -> str:
        if self._dir is None:
            self._dir = tempfile.mkdtemp(prefix="repro-disk-tier-")
        else:
            os.makedirs(self._dir, exist_ok=True)
        return self._dir

    def store(self, key, payload):
        path = os.path.join(self._ensure_dir(), f"page{self._serial}.bin")
        self._serial += 1
        arr = np.ascontiguousarray(payload)
        with open(path, "wb") as f:
            f.write(arr.view(np.uint8).reshape(-1).tobytes())
        self._files[key] = path
        self._meta[key] = (arr.dtype, arr.shape)

    def load(self, key):
        dtype, shape = self._meta[key]
        with open(self._files[key], "rb") as f:
            flat = np.frombuffer(f.read(), dtype=np.uint8)
        return flat.view(dtype).reshape(shape)

    def delete(self, key):
        path = self._files.pop(key)
        self._meta.pop(key, None)
        try:
            os.remove(path)
        except OSError:
            pass

    def __contains__(self, key):
        return key in self._files

    def __len__(self):
        return len(self._files)

    def close(self):
        for key in list(self._files):
            self.delete(key)
        if self._owns_dir and self._dir is not None:
            shutil.rmtree(self._dir, ignore_errors=True)
            self._dir = None


# Unified key space inside the store: preemption swap traffic and prefix
# cache spill traffic share the tier chain (and therefore the capacity
# pressure), but are distinguishable so only cache entries may ever be
# dropped off the bottom.
_SWAP = "swap"
_CACHE = "cache"


class HostBackingStore:
    """Tiered host-side backing store for reclaimed KV pages.

    When the serving scheduler preempts a sequence, its pages are dropped
    from the device pool (non-shared ones are thereby reclaimed): the
    payload crosses D2H into this store and crosses back H2D on
    re-admission.  This is HERO's SVM page
    reclamation (§2.2): because translation is software-managed, a physical
    page can be repurposed while its *content* survives in host memory, and
    the mapping is re-established later without the accelerator noticing
    anything but a RAB refill.

    Since PR 8 the store is a spill *chain* of :class:`BackingTier` levels
    (host DRAM, then optionally disk) shared by two traffic classes:

      * **swap** payloads (``put``/``pop``/``repark``/``discard``) — a
        preempted request's private pages.  Never dropped: under pressure
        they demote down-tier, and the bottom tier may exceed its capacity
        rather than lose one.
      * **cache** payloads (``park_cache``/``fetch_cache``/``drop_cache``)
        — prefix-index entries evicted from the device pool.  Evictable:
        when the bottom tier overflows, the least-recently-used cache entry
        is dropped (and counted).

    The store only keeps host copies and byte counters; the engine owns the
    transfers themselves (and traces them as SWAP_OUT / SWAP_IN /
    PAGE_DEMOTE / PAGE_PROMOTE plus the underlying H2D / D2H events).
    Inter-tier cache moves are queued in ``drain_cache_moves()`` order so
    the engine can trace every transition (the tier-conservation assert in
    ``core.analysis`` checks no entry is lost or duplicated).

    Failure semantics: ``put``/``pop``/``fetch_cache`` raise
    :class:`BackingStoreError` (never a bare ``KeyError`` or a silent
    overwrite), every parked payload is checksummed at park time and
    verified on restore *whatever tier it comes back from* (a mismatch is a
    persistent ``corrupt`` fault), and an optional ``fault_injector``
    (``runtime.faults.FaultInjector``) perturbs the swap and
    cache-restore paths with seeded, deterministic I/O errors / corruption
    / stalls for chaos testing."""

    def __init__(self, fault_injector=None, *, host_pages: int = 0,
                 disk_tier: Optional[BackingTier] = None):
        self.tiers: List[BackingTier] = [HostTier(host_pages)]
        if disk_tier is not None:
            self.tiers.append(disk_tier)
        # key -> tier index, in LRU order (oldest first)
        self._where: "collections.OrderedDict[Tuple, int]" = \
            collections.OrderedDict()
        self._sums: Dict[Tuple, int] = {}
        self.faults = fault_injector
        self.bytes_out = 0       # device -> host (swap-out)
        self.bytes_in = 0        # host -> device (swap-in)
        self.peak_pages = 0
        # cache-tier accounting (CacheStats feeds on these)
        self.cache_bytes_demoted = 0
        self.cache_bytes_promoted = 0
        self.cache_hits = {"host": 0, "disk": 0}
        self.cache_dropped = 0
        self._moves: List[Tuple[int, int, int]] = []  # (entry, src, dst)

    # ------------------------------------------------------------ plumbing --
    def _tier_code(self, idx: int) -> int:
        return TIER_CODES[self.tiers[idx].name]

    def _insert(self, key: Tuple, arr: np.ndarray):
        self.tiers[0].store(key, arr)
        self._where[key] = 0
        self._where.move_to_end(key)
        self._balance()

    def _move_down(self, key: Tuple, src: int):
        arr = self.tiers[src].load(key)
        self.tiers[src].delete(key)
        self.tiers[src + 1].store(key, arr)
        self._where[key] = src + 1
        if key[0] == _CACHE:
            self.cache_bytes_demoted += arr.nbytes
            self._moves.append((key[1], self._tier_code(src),
                                self._tier_code(src + 1)))

    def _drop(self, key: Tuple, src: int):
        self.tiers[src].delete(key)
        del self._where[key]
        del self._sums[key]
        if key[0] == _CACHE:
            self.cache_dropped += 1
            self._moves.append((key[1], self._tier_code(src), TIER_DROPPED))

    def _balance(self):
        """Cascade LRU overflow down the tier chain; drop LRU *cache*
        entries off the bottom (swap payloads may overflow the last tier
        rather than be lost)."""
        for i, tier in enumerate(self.tiers):
            if tier.capacity_pages <= 0:
                continue
            last = i == len(self.tiers) - 1
            while len(tier) > tier.capacity_pages:
                victim = None
                for key, where in self._where.items():   # oldest first
                    if where != i:
                        continue
                    if last and key[0] != _CACHE:
                        continue                         # swap: never drop
                    victim = key
                    break
                if victim is None:
                    break
                if last:
                    self._drop(victim, i)
                else:
                    self._move_down(victim, i)

    def _fetch(self, key: Tuple, rid: int, lpage: int) -> np.ndarray:
        idx = self._where.pop(key)
        arr = self.tiers[idx].load(key)
        self.tiers[idx].delete(key)
        crc = self._sums.pop(key)
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != crc:
            raise BackingStoreError(
                rid, lpage, "pop", "corrupt", transient=False,
                detail=f"checksum mismatch on restore from "
                       f"{self.tiers[idx].name}")
        return arr

    def drain_cache_moves(self) -> List[Tuple[int, int, int]]:
        """Inter-tier cache transitions (entry_id, src, dst) since the last
        drain, in order — the engine traces them as PAGE_DEMOTE events."""
        moves, self._moves = self._moves, []
        return moves

    # ---------------------------------------------------------- swap class --
    def put(self, seq: int, lpage: int, payload: np.ndarray):
        key = (_SWAP, seq, lpage)
        if key in self._where:
            raise BackingStoreError(
                seq, lpage, "put", "overwrite",
                detail="page is already parked (double swap-out)")
        arr = np.ascontiguousarray(np.asarray(payload))
        spec = None
        if self.faults is not None:
            spec = self.faults.before("put", seq, lpage)   # may raise/stall
        self._sums[key] = zlib.crc32(arr.tobytes())
        if spec is not None and spec.kind == "corrupt":
            # silent bit-rot after the checksum was taken: the damage is
            # only discovered at swap-in, as a checksum mismatch
            arr = arr.copy()
            arr.view(np.uint8).reshape(-1)[0] ^= 0xFF
        self._insert(key, arr)
        self.bytes_out += arr.nbytes
        self.peak_pages = max(self.peak_pages, len(self))

    def pop(self, seq: int, lpage: int) -> np.ndarray:
        key = (_SWAP, seq, lpage)
        if key not in self._where:
            raise BackingStoreError(
                seq, lpage, "pop", "missing",
                detail="page was never parked (or already restored)")
        if self.faults is not None:
            self.faults.before("pop", seq, lpage)          # may raise/stall
        arr = self._fetch(key, seq, lpage)
        self.bytes_in += arr.nbytes
        return arr

    def repark(self, seq: int, lpage: int, payload: np.ndarray):
        """Undo a successful :meth:`pop` whose *batch* failed: the engine
        popped several pages for one swap-in, a later page faulted
        transiently, and the whole resume is being deferred — the
        already-popped payloads go back exactly as they were.  No fault
        injection (the op already succeeded once; re-parking is engine
        bookkeeping, not new I/O) and the ``bytes_in`` the pop counted is
        credited back, so a deferred attempt costs no phantom traffic."""
        key = (_SWAP, seq, lpage)
        if key in self._where:
            raise BackingStoreError(
                seq, lpage, "repark", "overwrite",
                detail="page is already parked (repark without pop)")
        arr = np.ascontiguousarray(np.asarray(payload))
        self._sums[key] = zlib.crc32(arr.tobytes())
        self._insert(key, arr)
        self.bytes_in -= arr.nbytes
        self.peak_pages = max(self.peak_pages, len(self))

    def discard(self, seq: int):
        """Drop every parked page of ``seq`` without counting swap-in
        traffic (the abort path: payload is released, never restored) —
        across **all** tiers, so a cancelled request that was pushed down
        to disk under host pressure cannot strand files there."""
        for key in [k for k in self._where if k[0] == _SWAP and k[1] == seq]:
            idx = self._where.pop(key)
            self.tiers[idx].delete(key)
            self._sums.pop(key, None)

    def __len__(self) -> int:
        """Number of parked *swap* pages (cache entries are accounted via
        :meth:`cache_resident`)."""
        return sum(1 for k in self._where if k[0] == _SWAP)

    # --------------------------------------------------------- cache class --
    def park_cache(self, entry_id: int, payload: np.ndarray):
        """Park a demoted prefix-cache page (device -> host tier).  Engine
        bookkeeping like :meth:`repark` — no fault injection on the way
        down; the checksum taken here is verified whenever (and from
        whatever tier) the entry is promoted back."""
        key = (_CACHE, entry_id)
        if key in self._where:       # same entry re-demoted: replace
            idx = self._where.pop(key)
            self.tiers[idx].delete(key)
            self._sums.pop(key, None)
        arr = np.ascontiguousarray(np.asarray(payload))
        self._sums[key] = zlib.crc32(arr.tobytes())
        self._insert(key, arr)
        self.cache_bytes_demoted += arr.nbytes

    def fetch_cache(self, entry_id: int, rid: int) -> Tuple[np.ndarray, str]:
        """Fetch (and remove) a spilled cache entry for promotion on behalf
        of request ``rid``.  Returns ``(payload, tier_name)`` so the engine
        can trace which tier served the hit.  The fault injector sees this
        as a ``pop`` — tiered restores get the same chaos coverage as swap
        restores."""
        key = (_CACHE, entry_id)
        if key not in self._where:
            raise BackingStoreError(
                rid, entry_id, "pop", "missing",
                detail="cache entry is not parked (dropped or never spilled)")
        tier_name = self.tiers[self._where[key]].name
        if self.faults is not None:
            self.faults.before("pop", rid, entry_id)       # may raise/stall
        arr = self._fetch(key, rid, entry_id)
        self.cache_bytes_promoted += arr.nbytes
        self.cache_hits[tier_name] = self.cache_hits.get(tier_name, 0) + 1
        return arr, tier_name

    def drop_cache(self, entry_id: int):
        """Silently forget a spilled entry (fetch fault fallback, or the
        entry was re-registered on-device and the spill copy superseded)."""
        key = (_CACHE, entry_id)
        if key in self._where:
            self._drop(key, self._where[key])

    def cache_tier(self, entry_id: int) -> Optional[str]:
        idx = self._where.get((_CACHE, entry_id))
        return None if idx is None else self.tiers[idx].name

    def cache_resident(self) -> Dict[str, int]:
        """Cache entries resident per tier name."""
        out = {t.name: 0 for t in self.tiers}
        for key, idx in self._where.items():
            if key[0] == _CACHE:
                out[self.tiers[idx].name] += 1
        return out

    # ------------------------------------------------------------- hygiene --
    def check_invariants(self):
        """Every tracked key lives in exactly the tier the index says, has
        a checksum, and appears in no other tier."""
        for key, idx in self._where.items():
            assert key in self.tiers[idx], (key, idx)
            assert key in self._sums, key
            for j, tier in enumerate(self.tiers):
                if j != idx:
                    assert key not in tier, (key, idx, j)
        tracked = len(self._where)
        stored = sum(len(t) for t in self.tiers)
        assert tracked == stored, (tracked, stored)

    def close(self):
        """Release every tier (disk tiers delete their files; an owned temp
        directory is removed)."""
        self._where.clear()
        self._sums.clear()
        for tier in self.tiers:
            tier.close()


class OffloadTarget:
    """The 'PMCA': a jit compilation target + the offload RTE around it."""

    def __init__(self, svm: Optional[SVMSpace] = None,
                 tracer: Optional[TraceBuffer] = None):
        self.svm = svm or SVMSpace()
        self.tracer = tracer
        self._compiled: Dict[int, Callable] = {}

    def _trace(self, etype: EventType, a: int = 0, b: int = 0):
        if self.tracer is not None:
            self.tracer.record_host(etype, a, b)

    # ------------------------------------------------------------------
    def target(self, fn: Callable, *, donate: Sequence[int] = ()) -> Callable:
        """Mark a kernel for offload (the `omp target` outline step)."""
        key = id(fn)
        if key not in self._compiled:
            self._compiled[key] = jax.jit(fn, donate_argnums=tuple(donate))
        return self._compiled[key]

    # ------------------------------------------------------------------
    def run_copy_based(self, fn: Callable, *host_args: Any
                       ) -> Tuple[Any, OffloadReport]:
        """Copy-based SM offload: serialize -> stage -> run -> copy back.

        ``host_args`` are host-side structures (numpy arrays or nested
        containers).  The serialization into one contiguous staging buffer
        models HERO's physically-contiguous uncached section, including the
        pointer-flattening cost for linked structures.
        """
        jfn = self.target(fn)
        self._trace(EventType.OFFLOAD_BEGIN, 0, 0)
        t0 = time.perf_counter()
        # serialize: flatten + force one contiguous copy of every leaf
        leaves, treedef = jax.tree.flatten(host_args)
        staged = [np.ascontiguousarray(np.asarray(x)) for x in leaves]
        blob_bytes = sum(x.nbytes for x in staged)
        # stage to device (the DMA across the host/PMCA boundary)
        dev = [jax.device_put(x) for x in staged]
        for d in dev:
            d.block_until_ready()
        t1 = time.perf_counter()
        self._trace(EventType.OFFLOAD_COPY_TO, blob_bytes % (1 << 31), 0)

        self._trace(EventType.OFFLOAD_KERNEL_BEGIN, 0, 0)
        out = jfn(*jax.tree.unflatten(treedef, dev))
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        self._trace(EventType.OFFLOAD_KERNEL_END, 0, 0)

        # copy back to host memory (uncached section -> host structures)
        host_out = jax.tree.map(lambda x: np.asarray(x), out)
        t3 = time.perf_counter()
        self._trace(EventType.OFFLOAD_COPY_FROM, _nbytes(host_out) % (1 << 31), 0)
        self._trace(EventType.OFFLOAD_END, 0, 0)
        rep = OffloadReport("copy", t1 - t0, t2 - t1, t3 - t2,
                            bytes_to=blob_bytes, bytes_from=_nbytes(host_out))
        return host_out, rep

    # ------------------------------------------------------------------
    def run_zero_copy(self, fn: Callable, *handles: int, donate: Sequence[int] = ()
                      ) -> Tuple[Any, OffloadReport]:
        """Zero-copy SVM offload: pass pointers, no staging.

        ``handles`` are SVM handles to device-resident buffers.  The kernel's
        outputs are published back into SVM and returned as handles too —
        the host never touches the payload (Fig.5's SVM bars).
        """
        jfn = self.target(fn, donate=donate)
        self._trace(EventType.OFFLOAD_BEGIN, 1, 0)
        t0 = time.perf_counter()
        args = [self.svm.deref(h) for h in handles]       # pointer deref only
        t1 = time.perf_counter()
        self._trace(EventType.OFFLOAD_KERNEL_BEGIN, 0, 0)
        out = jfn(*args)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        self._trace(EventType.OFFLOAD_KERNEL_END, 0, 0)
        out_handles = jax.tree.map(self.svm.share, out)
        self._trace(EventType.OFFLOAD_END, 0, 0)
        rep = OffloadReport("zero_copy", t1 - t0, t2 - t1, 0.0)
        return out_handles, rep
