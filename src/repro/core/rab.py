"""RAB — Remapping Address Block (HERO's software-managed accelerator MMU),
adapted to TPU serving as the *paged KV-cache translation layer*.

HERO's RAB translates PMCA virtual addresses to physical DRAM addresses via
a tiny, software-managed two-level TLB: a single-cycle fully-associative L1
and a multi-cycle set-associative, banked L2.  Misses are queued; the core
that missed sleeps; a handler walks the page table, configures a replacement
entry, and wakes the core (Vogel et al. [28-30]).

The TPU adaptation: the "virtual address space" is the *logical token-page
space* of a serving request (SVM between the host scheduler and the model),
and "physical addresses" are slots in the paged KV pool consumed by
``kernels/paged_attention``.  The translation table the kernel reads (the
block table) is exactly HERO's RAB table; the miss path is on-demand page
allocation during decode; hit-under-miss, replacement, and the wake protocol
are preserved and observable through the event tracer (§3.4 reproduction).

This is a host-side state machine (the RAB is managed *in software* in HERO
too); the device-side consumer is the block-table array it maintains.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.tracing import EventType, TraceBuffer


@dataclasses.dataclass(frozen=True)
class RABConfig:
    l1_entries: int = 32          # Tab.1: 4..64
    l2_entries: int = 1024        # Tab.1: 0..2048
    l2_assoc: int = 32            # Tab.1: 16/32/64
    l2_banks: int = 4             # Tab.1: 1/2/4/8
    l1_lookup_cycles: int = 1     # single-cycle hit (§3.4a)
    l2_cycles_per_way: int = 1    # multi-cycle search (§3.4b)
    miss_handler_cycles: int = 50  # page-table walk cost model

    def __post_init__(self):
        assert self.l2_entries % max(self.l2_banks, 1) == 0
        sets = self.l2_entries // max(self.l2_assoc, 1)
        assert sets >= 1


class RABMiss(Exception):
    """Raised on a translation miss when no handler runs inline."""

    def __init__(self, vpage: int, requester: int):
        super().__init__(f"RAB miss vpage={vpage} requester={requester}")
        self.vpage = vpage
        self.requester = requester


class RAB:
    """Two-level software TLB with miss queue + wake list."""

    def __init__(self, cfg: RABConfig, tracer: Optional[TraceBuffer] = None):
        self.cfg = cfg
        self.l1: "OrderedDict[int, int]" = OrderedDict()   # vpage -> ppage, LRU
        n_sets = max(1, cfg.l2_entries // max(cfg.l2_assoc, 1))
        self.l2: List["OrderedDict[int, int]"] = [OrderedDict()
                                                  for _ in range(n_sets)]
        self.miss_queue: deque = deque()
        self.sleeping: Dict[int, int] = {}                 # requester -> vpage
        self.tracer = tracer
        self.stats = {"l1_hits": 0, "l2_hits": 0, "misses": 0,
                      "evictions_l1": 0, "evictions_l2": 0, "wakes": 0,
                      "cycles": 0}

    # ------------------------------------------------------------------ util
    def _trace(self, etype: EventType, a: int = 0, b: int = 0):
        if self.tracer is not None:
            self.tracer.record_host(etype, a, b)

    def _l2_set(self, vpage: int) -> "OrderedDict[int, int]":
        return self.l2[vpage % len(self.l2)]

    # ----------------------------------------------------------------- logic
    def lookup(self, vpage: int, requester: int = 0) -> Tuple[Optional[int], int]:
        """Translate vpage.  Returns (ppage | None, cycles).

        None means miss: the request was queued and the requester 'sleeps'
        (HERO: the core is clock-gated until the VMM handler wakes it).
        """
        cyc = self.cfg.l1_lookup_cycles
        if vpage in self.l1:
            self.l1.move_to_end(vpage)
            self.stats["l1_hits"] += 1
            self.stats["cycles"] += cyc
            self._trace(EventType.TLB_L1_HIT, requester, vpage)
            return self.l1[vpage], cyc

        s = self._l2_set(vpage)
        # multi-cycle associative search (§3.4b: L2 searched while L1 serves
        # other cores — hit-under-miss is possible because state is per-set)
        cyc += self.cfg.l2_cycles_per_way * max(1, min(len(s), self.cfg.l2_assoc))
        if vpage in s:
            ppage = s.pop(vpage)
            self.stats["l2_hits"] += 1
            self.stats["cycles"] += cyc
            self._promote_l1(vpage, ppage)
            self._trace(EventType.TLB_L2_HIT, requester, vpage)
            return ppage, cyc

        self.stats["misses"] += 1
        self.stats["cycles"] += cyc
        self.miss_queue.append((vpage, requester))
        self.sleeping[requester] = vpage
        self._trace(EventType.TLB_MISS, requester, vpage)
        self._trace(EventType.CORE_SLEEP, requester, vpage)
        return None, cyc

    def _promote_l1(self, vpage: int, ppage: int):
        if len(self.l1) >= self.cfg.l1_entries:
            old_v, old_p = self.l1.popitem(last=False)     # LRU
            self.stats["evictions_l1"] += 1
            self._insert_l2(old_v, old_p)
        self.l1[vpage] = ppage

    def _insert_l2(self, vpage: int, ppage: int):
        s = self._l2_set(vpage)
        if len(s) >= self.cfg.l2_assoc:
            s.popitem(last=False)
            self.stats["evictions_l2"] += 1
        s[vpage] = ppage

    def handle_misses(self, page_table: Dict[int, int]) -> List[int]:
        """VMM handler: walk `page_table`, configure entries, wake cores.

        Returns the requesters woken.  (HERO §2.2.4: handler dequeues the
        miss, walks the host page table, selects a replacement entry,
        configures it, and wakes the sleeping core.)
        """
        woken = []
        while self.miss_queue:
            vpage, requester = self.miss_queue.popleft()
            if vpage not in page_table:
                raise KeyError(f"page fault: vpage {vpage} unmapped")
            self.stats["cycles"] += self.cfg.miss_handler_cycles
            self._trace(EventType.MISS_HANDLED, requester, vpage)
            self._promote_l1(vpage, page_table[vpage])
            if self.sleeping.get(requester) == vpage:
                del self.sleeping[requester]
                self.stats["wakes"] += 1
                self._trace(EventType.CORE_WAKE, requester, vpage)
                woken.append(requester)
        return woken

    def invalidate(self, vpage: Optional[int] = None):
        if vpage is None:
            self.l1.clear()
            for s in self.l2:
                s.clear()
        else:
            self.l1.pop(vpage, None)
            self._l2_set(vpage).pop(vpage, None)

    def resident(self) -> Dict[int, int]:
        out = dict(self.l1)
        for s in self.l2:
            out.update(s)
        return out


# ===========================================================================
# Paged KV pool (the "physical memory" behind the RAB)
# ===========================================================================

class PagedKVPool:
    """Fixed pool of KV pages + per-sequence logical page tables, with
    shared-prefix caching and copy-on-write.

    The device-side consumable is ``block_table(seq_ids)``: an int32 array
    (B, max_pages) of physical page indices (the RAB table image the
    paged_attention kernel reads).  -1 marks unmapped logical pages.

    Page *sharing* reproduces HERO's central SVM property (§2.2, §3.4):
    because translation is software-managed, a physical page can be mapped
    into several logical address spaces at once and remapped or reclaimed
    without touching the data path.  Concretely:

    * every physical page carries a refcount (number of (seq, lpage)
      mappings pointing at it);
    * pages whose content is a pure prompt prefix are registered in a
      prefix index keyed by the exact token prefix they hold (a chain of
      token blocks; the key for logical page *i* is the token tuple up to
      the end of that page), so a later request with the same prefix maps
      the already-filled pages instead of re-prefilling them;
    * appending into a shared page triggers *copy-on-write* through the
      ordinary allocation path: a fresh page is mapped for the writer, the
      old refcount is decremented, and the engine is told to copy the page
      payload device-side (``drain_cow``);
    * a released page that is still prefix-indexed parks on a *cached-free*
      LRU list instead of the free list — reusable as a prefix hit until
      capacity pressure evicts it;
    * with ``spill_enabled`` (PR 8, the HERO SVM ladder), capacity pressure
      does not *lose* the entry: the key moves to a ``spilled`` side index
      and the page id + key are queued on ``pending_demote`` for the engine
      to park the payload in a host/disk backing tier.  An admission-time
      ``match_prefix_tiered`` hit on a spilled entry re-enters the device
      index via :meth:`adopt_spilled` (the engine promotes the payload
      back).  Every entry carries a stable ``key_ids`` id so demote /
      promote trace events chain per entry across its whole lifetime.
    """

    def __init__(self, num_pages: int, page_size: int, max_pages_per_seq: int,
                 rab: Optional[RAB] = None):
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.free = list(range(num_pages - 1, -1, -1))
        self.page_table: Dict[Tuple[int, int], int] = {}   # (seq, lpage) -> p
        self.seq_len: Dict[int, int] = {}
        self.reserved: Dict[int, int] = {}                 # seq -> pages held
        self.refcount: Dict[int, int] = {}                 # ppage -> mappings
        self.page_key: Dict[int, Tuple[int, ...]] = {}     # ppage -> prefix
        self.prefix_index: Dict[Tuple[int, ...], int] = {}  # prefix -> ppage
        self.cached_free: "OrderedDict[int, None]" = OrderedDict()  # LRU
        self.pending_cow: List[Tuple[int, int, int, int]] = []
        self.rab = rab
        # --- tiered spill state (engine drives the payload movement) ---
        self.spill_enabled = False
        self.spilled: Dict[Tuple[int, ...], int] = {}      # key -> entry id
        self.key_ids: Dict[Tuple[int, ...], int] = {}      # key -> stable id
        self._next_key_id = 0
        self.key_id_step = 1       # sharded pools interleave id namespaces
        self.pending_demote: List[Tuple[int, Tuple[int, ...]]] = []
        self.pending_spill_drop: List[Tuple[int, ...]] = []
        self.stats = {"prefix_hit_pages": 0, "prefix_hit_tokens": 0,
                      "cow": 0, "cache_evictions": 0, "swapped_out": 0,
                      "swapped_in": 0, "spec_trimmed_pages": 0,
                      "cache_demoted": 0, "cache_promoted": 0}

    # ------------------------------------------------------------ capacity --
    def available(self) -> int:
        """Pages obtainable right now (free + evictable cached) minus
        admission-time reservations."""
        return len(self.free) + len(self.cached_free) \
            - sum(self.reserved.values())

    def free_pages(self) -> int:
        """Pages not referenced by any live mapping (free + cached-free)."""
        return len(self.free) + len(self.cached_free)

    def can_alloc(self, n: int = 1) -> bool:
        return self.available() >= n

    def reserve(self, seq: int, n: int):
        """Hold ``n`` pages for ``seq`` so lazy mid-stream allocation can
        never fail after admission (chunked prefill allocates many pages per
        engine iteration; without the reservation, a later admit could eat
        pages this sequence still needs)."""
        if self.available() < n:
            raise MemoryError(f"cannot reserve {n} pages "
                              f"({self.available()} available)")
        self.reserved[seq] = self.reserved.get(seq, 0) + n

    def _take_page(self) -> int:
        """Pop a physical page: free list first, then evict the LRU
        cached-free page.  Without spill the evicted prefix-index entry is
        dropped; with spill the entry demotes — its key moves to the
        ``spilled`` index and ``(page, key)`` is queued so the engine parks
        the payload down-tier *before* anything overwrites the page."""
        if self.free:
            return self.free.pop()
        if self.cached_free:
            p, _ = self.cached_free.popitem(last=False)
            key = self.page_key.get(p)
            if self.spill_enabled and key is not None:
                del self.page_key[p]
                if self.prefix_index.get(key) == p:
                    del self.prefix_index[key]
                self.spilled[key] = self.key_ids[key]
                self.pending_demote.append((p, key))
                self.stats["cache_demoted"] += 1
            else:
                self._unregister(p)
            self.stats["cache_evictions"] += 1
            return p
        raise MemoryError("KV pool exhausted")

    def _draw_reservation(self, seq: int):
        """Charge one page to ``seq``: draw down its reservation, or — when
        none remains — take from the unreserved residue.  An unreserved
        allocation may not eat into pages other sequences reserved at
        admission; that would break the never-fail-after-admission
        guarantee ``reserve`` documents."""
        if self.reserved.get(seq, 0) > 0:
            self.reserved[seq] -= 1
        elif self.available() < 1:
            raise MemoryError("KV pool exhausted (remaining pages reserved)")

    # ---------------------------------------------------------- alloc/free --
    def alloc_page(self, seq: int, lpage: int) -> int:
        self._draw_reservation(seq)
        p = self._take_page()
        self.page_table[(seq, lpage)] = p
        self.refcount[p] = 1
        self._invalidate(seq, lpage)
        return p

    def share_page(self, seq: int, lpage: int, ppage: int):
        """Map an already-filled physical page into ``seq``'s table (a
        prefix-cache hit): RAB entry installed lazily on first translate,
        refcount bumped, no data movement."""
        assert (seq, lpage) not in self.page_table
        if ppage in self.cached_free:      # revive a parked page
            del self.cached_free[ppage]
        self.page_table[(seq, lpage)] = ppage
        self.refcount[ppage] = self.refcount.get(ppage, 0) + 1
        self.stats["prefix_hit_pages"] += 1
        self._invalidate(seq, lpage)

    def unmap_page(self, seq: int, lpage: int):
        """Drop one mapping; the page is freed (or parked on the cached-free
        list if still prefix-indexed) when its last reference goes."""
        p = self.page_table.pop((seq, lpage))
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            del self.refcount[p]
            if p in self.page_key:
                self.cached_free[p] = None
                self.cached_free.move_to_end(p)
            else:
                self.free.append(p)
        self._invalidate(seq, lpage)

    def append_token(self, seq: int) -> Tuple[int, int]:
        """Account one new token; allocates a page at page boundaries.

        Appending into a *shared* page (refcount > 1) copy-on-writes it:
        the writer gets a private page through the normal allocation path
        and the (src, dst) payload copy is queued on ``pending_cow`` for
        the engine to apply device-side.  Appending in place into a page
        that is prefix-indexed un-registers it (its content is about to
        diverge from the indexed prefix).

        Returns (lpage, slot_in_page)."""
        t = self.seq_len.get(seq, 0)
        lpage, slot = divmod(t, self.page_size)
        if slot == 0:
            self.alloc_page(seq, lpage)
        else:
            p = self.page_table[(seq, lpage)]
            if self.refcount[p] > 1:
                self._cow(seq, lpage, p)
            elif p in self.page_key:
                self._unregister(p)
        self.seq_len[seq] = t + 1
        return lpage, slot

    def _cow(self, seq: int, lpage: int, src: int) -> int:
        """Copy-on-write ``(seq, lpage)`` off shared page ``src``."""
        self._draw_reservation(seq)
        dst = self._take_page()
        self.refcount[src] -= 1
        self.refcount[dst] = 1
        self.page_table[(seq, lpage)] = dst
        self.pending_cow.append((seq, lpage, src, dst))
        self.stats["cow"] += 1
        self._invalidate(seq, lpage)
        return dst

    def drain_cow(self) -> List[Tuple[int, int, int, int]]:
        """Hand the queued (seq, lpage, src, dst) payload copies to the
        engine (which owns the device-side KV arrays) and clear the queue."""
        out, self.pending_cow = self.pending_cow, []
        return out

    def trim(self, seq: int, new_len: int) -> int:
        """Roll ``seq`` back to ``new_len`` tokens (speculative-decode
        rollback): pages wholly beyond the kept length are unmapped through
        the ordinary release path — a trimmed page that other sequences
        still share merely drops this mapping's refcount, and one that is
        prefix-indexed parks on the cached-free list — and every page this
        trim *frees back* is re-credited to ``seq``'s reservation, because
        the lifetime page budget reserved at admission still has to cover
        re-appending the rolled-back positions.  Returns pages unmapped.

        Only whole pages are unmapped; a kept page whose tail slots held
        rejected drafts keeps them in place — they sit beyond ``seq_len``,
        the attention kernels mask by length, and the next append
        overwrites them (same contract as the trash-page scatter)."""
        old = self.seq_len.get(seq, 0)
        assert 0 <= new_len <= old, (seq, new_len, old)
        if new_len == old:
            return 0
        keep = -(-new_len // self.page_size) if new_len else 0
        freed = 0
        for lp in range(keep, -(-old // self.page_size)):
            if (seq, lp) in self.page_table:
                self.unmap_page(seq, lp)
                freed += 1
        if new_len:
            self.seq_len[seq] = new_len
        else:
            self.seq_len.pop(seq, None)
        if freed:
            self.reserved[seq] = self.reserved.get(seq, 0) + freed
        self.stats["spec_trimmed_pages"] += freed
        return freed

    def release(self, seq: int):
        for (s, lp) in [k for k in self.page_table if k[0] == seq]:
            self.unmap_page(s, lp)
        self.seq_len.pop(seq, None)
        self.reserved.pop(seq, None)

    def seq_pages(self, seq: int) -> List[Tuple[int, int]]:
        """Sorted [(lpage, ppage)] currently mapped for ``seq``."""
        return sorted((lp, p) for (s, lp), p in self.page_table.items()
                      if s == seq)

    # ------------------------------------------------------- prefix cache --
    def prefix_key(self, tokens, lpage: int) -> Tuple[int, ...]:
        """Index key for logical page ``lpage`` of a prompt: the exact token
        prefix up to the end of that page (chained full blocks; the final
        partial block keys the whole prompt)."""
        return tuple(tokens[:min((lpage + 1) * self.page_size, len(tokens))])

    def register_page(self, seq: int, lpage: int, tokens):
        """Publish ``seq``'s page ``lpage`` (whose KV holds exactly the
        prompt prefix ``tokens[:end-of-page]``) in the prefix index.  A
        freshly prefilled on-device copy supersedes a spilled one: the key
        is re-registered here and queued on ``pending_spill_drop`` so the
        engine releases the stale down-tier payload (an entry is resident
        in exactly one tier)."""
        p = self.page_table[(seq, lpage)]
        key = self.prefix_key(tokens, lpage)
        if key in self.prefix_index or p in self.page_key:
            return
        if key not in self.key_ids:
            self.key_ids[key] = self._next_key_id
            self._next_key_id += self.key_id_step
        self.prefix_index[key] = p
        self.page_key[p] = key
        if key in self.spilled:
            del self.spilled[key]
            self.pending_spill_drop.append(key)

    def _unregister(self, p: int):
        key = self.page_key.pop(p, None)
        if key is not None and self.prefix_index.get(key) == p:
            del self.prefix_index[key]

    def match_prefix(self, tokens) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: ([physical pages], tokens
        covered).  Full pages chain block-by-block; a final partial page
        matches only on the exact whole-prompt key."""
        pages: List[int] = []
        n = 0
        while n < len(tokens):
            key = self.prefix_key(tokens, len(pages))
            p = self.prefix_index.get(key)
            if p is None:
                break
            pages.append(p)
            n = min(n + self.page_size, len(tokens))
        return pages, n

    def match_prefix_tiered(self, tokens
                            ) -> Tuple[List[Tuple[str, object]], int]:
        """Longest cached prefix of ``tokens`` across *all* tiers:
        ``([("device", ppage) | ("spilled", key)], tokens covered)``.
        Device-resident pages chain seamlessly with spilled entries — a
        prefix can be half on-device, half parked down-tier; the engine
        promotes the spilled half at admission."""
        entries: List[Tuple[str, object]] = []
        n = 0
        while n < len(tokens):
            key = self.prefix_key(tokens, len(entries))
            p = self.prefix_index.get(key)
            if p is not None:
                entries.append(("device", p))
            elif key in self.spilled:
                entries.append(("spilled", key))
            else:
                break
            n = min(n + self.page_size, len(tokens))
        return entries, n

    def adopt_spilled(self, seq: int, lpage: int, key: Tuple[int, ...]) -> int:
        """Promote a spilled entry back on-device for ``seq``: draw a fresh
        physical page through the ordinary (reservation-charged) allocation
        path, map it at ``lpage``, and re-register the key on it.  The
        engine owns uploading the fetched payload into the returned page."""
        assert key in self.spilled, key
        del self.spilled[key]
        p = self.alloc_page(seq, lpage)
        self.prefix_index[key] = p
        self.page_key[p] = key
        self.stats["cache_promoted"] += 1
        self.stats["prefix_hit_pages"] += 1
        return p

    def drop_spilled(self, key: Tuple[int, ...]):
        """Forget a spilled entry (its backing payload was lost or its
        fetch faulted unrecoverably) — the prefix is simply no longer
        cached."""
        self.spilled.pop(key, None)

    def drain_demotions(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """Hand queued (ppage, key) demotions to the engine — it must pull
        the page payloads D2H and park them *before* the step that reuses
        those pages scatters over them — and clear the queue."""
        out, self.pending_demote = self.pending_demote, []
        return out

    def drain_spill_drops(self) -> List[Tuple[int, ...]]:
        """Keys whose spilled payload was superseded by an on-device
        re-registration; the engine drops them from the backing store."""
        out, self.pending_spill_drop = self.pending_spill_drop, []
        return out

    # ---------------------------------------------------------- translate --
    def _invalidate(self, seq: int, lpage: int):
        if self.rab is not None:
            self.rab.invalidate(self._vpage(seq, lpage))

    # ---------------------------------------------------------- invariants --
    def check_invariants(self):
        """Assert the pool's conservation laws (used by the property suite):

        * refcount conservation: sum of refcounts == number of mappings;
        * free / cached-free / referenced partitions the physical pool
          exactly (no double-free, no leak);
        * a page reachable from two sequences has refcount > 1;
        * prefix index and page_key are a consistent bijection;
        * reservations never exceed obtainable pages.
        """
        mapped = list(self.page_table.values())
        assert sum(self.refcount.values()) == len(mapped), \
            "refcount conservation violated"
        per_page: Dict[int, int] = {}
        for p in mapped:
            per_page[p] = per_page.get(p, 0) + 1
        assert per_page == self.refcount, "refcount drifted from mappings"
        owners: Dict[int, set] = {}
        for (s, _lp), p in self.page_table.items():
            owners.setdefault(p, set()).add(s)
        for p, ss in owners.items():
            assert len(ss) <= self.refcount[p], \
                f"page {p} reachable from {len(ss)} seqs, refcount " \
                f"{self.refcount[p]}"
        pool = sorted(self.free) + sorted(self.cached_free) \
            + sorted(self.refcount)
        assert sorted(pool) == list(range(self.num_pages)), \
            f"free/cached/referenced does not partition the pool: {pool}"
        assert len(set(self.free)) == len(self.free), "double-free"
        assert not (set(self.cached_free) & set(self.refcount))
        for key, p in self.prefix_index.items():
            assert self.page_key.get(p) == key, "index/page_key mismatch"
        for p in self.page_key:
            assert p in self.refcount or p in self.cached_free, \
                f"indexed page {p} is on the raw free list"
        assert not (set(self.spilled) & set(self.prefix_index)), \
            "entry resident in two tiers (device-indexed AND spilled)"
        for key, eid in self.spilled.items():
            assert self.key_ids.get(key) == eid, \
                f"spilled entry {key} lost its stable id"
        assert self.available() >= 0, "reservations exceed capacity"
        for (s, lp) in self.page_table:
            n = self.seq_len.get(s, 0)
            assert n > 0 and lp < -(-n // self.page_size), \
                f"mapping ({s},{lp}) beyond seq_len {n}"

    def translate(self, seq: int, lpage: int) -> int:
        """RAB-mediated translation (miss -> handler walk -> retry)."""
        if self.rab is None:
            return self.page_table[(seq, lpage)]
        key = self._vpage(seq, lpage)
        ppage, _ = self.rab.lookup(key, requester=seq)
        if ppage is None:
            flat = {self._vpage(s, lp): p
                    for (s, lp), p in self.page_table.items()}
            self.rab.handle_misses(flat)
            ppage, _ = self.rab.lookup(key, requester=seq)
            assert ppage is not None
        return ppage

    def _vpage(self, seq: int, lpage: int) -> int:
        return seq * self.max_pages + lpage

    def block_table(self, seq_ids: List[int]) -> np.ndarray:
        """(B, max_pages) int32 physical page indices; -1 = unmapped."""
        bt = np.full((len(seq_ids), self.max_pages), -1, np.int32)
        for i, s in enumerate(seq_ids):
            n = self.seq_len.get(s, 0)
            for lp in range(-(-n // self.page_size) if n else 0):
                bt[i, lp] = self.translate(s, lp)
        return bt

    def lengths(self, seq_ids: List[int]) -> np.ndarray:
        return np.array([self.seq_len.get(s, 0) for s in seq_ids], np.int32)


# ===========================================================================
# Multi-cluster pool: C per-cluster pools, each behind its own RAB
# ===========================================================================

class ClusterPagedPool:
    """C independent ``PagedKVPool`` shards, one per PMCA cluster.

    HERO §2.2: every cluster sits behind its own RAB port into the shared
    SVM fabric.  The serving adaptation gives every cluster its own page
    shard (cluster-local free list, refcounts and prefix index) and its own
    ``RAB`` instance; a sequence lives entirely inside one cluster, so its
    block table holds *cluster-local* physical page ids and the owning
    cluster id rides alongside (``cluster_of``).  The global physical page
    namespace is ``cluster * (num_pages + 1) + local`` — the ``+ 1``
    accounts for each cluster's trash page in the fused device slab — and
    ``check_invariants`` proves the shards partition it (no page owned by
    two clusters).
    """

    def __init__(self, clusters: int, num_pages: int, page_size: int,
                 max_pages_per_seq: int, rab_cfg: Optional[RABConfig] = None,
                 tracer: Optional[TraceBuffer] = None):
        assert clusters >= 1
        self.clusters = clusters
        self.num_pages = num_pages            # per cluster
        self.page_size = page_size
        self.max_pages = max_pages_per_seq
        self.rabs = [RAB(rab_cfg or RABConfig(), tracer)
                     for _ in range(clusters)]
        self.pools = [PagedKVPool(num_pages, page_size, max_pages_per_seq,
                                  rab) for rab in self.rabs]
        for c, pool in enumerate(self.pools):
            # interleaved prefix-entry id namespaces: demote/promote trace
            # events stay globally unambiguous across cluster shards
            pool._next_key_id = c
            pool.key_id_step = clusters
        self.cluster_of: Dict[int, int] = {}          # seq -> cluster

    # ------------------------------------------------------------ routing --
    def place(self, seq: int, cluster: int):
        assert 0 <= cluster < self.clusters
        prev = self.cluster_of.get(seq)
        assert prev is None or prev == cluster, \
            f"seq {seq} already placed on cluster {prev}"
        self.cluster_of[seq] = cluster

    def forget(self, seq: int):
        self.cluster_of.pop(seq, None)

    def pool_for(self, seq: int) -> PagedKVPool:
        return self.pools[self.cluster_of[seq]]

    def least_loaded(self) -> int:
        """Cluster with the most obtainable pages (ties: lowest id) —
        HERO-style least-loaded placement."""
        return max(range(self.clusters),
                   key=lambda c: (self.pools[c].available(), -c))

    # ----------------------------------------------------------- global ids --
    def global_page(self, cluster: int, local: int) -> int:
        """Local physical page -> global slab index (incl. trash pages)."""
        return cluster * (self.num_pages + 1) + local

    def occupancy(self) -> List[int]:
        """Pages referenced by live mappings, per cluster."""
        return [p.num_pages - p.free_pages() for p in self.pools]

    # ------------------------------------------------------------- stats --
    @property
    def stats(self) -> Dict[int, int]:
        """Aggregated per-cluster pool stats (same keys as PagedKVPool)."""
        out: Dict = {}
        for p in self.pools:
            for k, v in p.stats.items():
                out[k] = out.get(k, 0) + v
        return out

    def free_pages(self) -> int:
        return sum(p.free_pages() for p in self.pools)

    def available(self) -> int:
        return sum(p.available() for p in self.pools)

    # ---------------------------------------------------------- invariants --
    def check_invariants(self):
        """Per-cluster conservation laws plus the cross-cluster partition:

        * every cluster pool individually satisfies its invariants;
        * a sequence is resident in exactly the cluster ``cluster_of``
          says, and in no other cluster's page table or seq_len map;
        * the global page namespace is partitioned — translating every
          cluster's pages to global ids yields disjoint sets that exactly
          tile ``clusters * num_pages`` (no page owned by two clusters).
        """
        seen_global: Dict[int, int] = {}
        for c, pool in enumerate(self.pools):
            pool.check_invariants()
            for s in set(pool.seq_len) | {k[0] for k in pool.page_table}:
                assert self.cluster_of.get(s) == c, \
                    f"seq {s} resident on cluster {c} but routed to " \
                    f"{self.cluster_of.get(s)}"
            for local in (set(pool.free) | set(pool.cached_free)
                          | set(pool.refcount)):
                g = self.global_page(c, local)
                assert g not in seen_global, \
                    f"global page {g} owned by clusters " \
                    f"{seen_global[g]} and {c}"
                seen_global[g] = c
        expect = {self.global_page(c, p) for c in range(self.clusters)
                  for p in range(self.num_pages)}
        assert set(seen_global) == expect, \
            "cluster shards do not partition the global page namespace"
