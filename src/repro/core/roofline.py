"""Analytic roofline cost terms: parameters, FLOPs and HBM bytes.

The byte/FLOP models that ``benchmarks/roofline.py`` renders into its
roofline table, factored into an importable library so the capacity
planner (``repro.planner``) can price engine iterations from the same
first principles the benchmark reports — one cost model, two consumers.

Everything here is a pure function of an :class:`~repro.configs.base.
ArchConfig` (plus a shape or serving knobs): no artifacts, no I/O, no
clock.  Hardware peaks live in ``repro.launch.mesh``
(``PEAK_FLOPS_BF16`` / ``HBM_BW`` / ``ICI_LINK_BW``).

Two byte models coexist on purpose:

* :func:`cache_bytes` — the *roofline* decode-cache model, per
  architecture family (paged KV, MLA latent, SSM state, sliding
  windows), with the paged-KV terms rescaled by ``kv_dtype``.  It uses
  the :data:`KV_PAGE_SIZE` default page size to amortize the int8 scale
  slab, matching the benchmark's historical output.
* :func:`kv_bytes_per_token` — the *engine's own* per-token KV
  footprint for an explicit ``page_size``, byte-identical to
  ``engine.cache_stats().bytes_per_token`` — this is the term the
  planner uses when pricing a concrete ``EngineConfig``.
"""
from __future__ import annotations

from typing import Dict

__all__ = [
    "KV_PAGE_SIZE", "param_counts", "model_flops", "analytic_bytes",
    "kv_elt_bytes", "cache_bytes", "kv_bytes_per_token",
]


def _flat_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _flat_paths(tree[k], prefix + "/" + str(k))
    else:
        out.append((prefix, tree))
    return out


def param_counts(cfg) -> Dict[str, float]:
    """total N and active N (MoE: routed experts scaled by top_k/E)."""
    from repro.models import model as M
    specs = M.param_specs(cfg)
    total = active = 0.0
    for path, leaf in _flat_paths(specs):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "/moe/w_" in path:
            active += n * cfg.moe_top_k / max(cfg.moe_num_experts, 1)
        else:
            active += n
    return {"total": total, "active": active}


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS per step (6*N_active*D train, 2*N_active*D fwd)."""
    n = param_counts(cfg)["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token / request


def analytic_bytes(cfg, shape, devices: int,
                   kv_dtype: str = "bf16") -> float:
    """Per-device HBM bytes per step (analytic lower-bound model)."""
    n_total = param_counts(cfg)["total"]
    bp = 2.0                                      # bf16 params
    if shape.kind == "train":
        # fwd read + bwd read (remat re-read) + grad write + adam m/v rw +
        # param write; all param-state is fully sharded (FSDP x TP)
        w = n_total * (bp * 3 + 4 * 4 + bp) / devices
        # activations: residual saves + recompute IO, 2 bytes, seq-sharded
        act = (cfg.num_layers + (cfg.encoder_layers or 0)) * \
            shape.global_batch * shape.seq_len * cfg.d_model * 2 * 4 / devices
        return w + act
    if shape.kind == "prefill":
        w = n_total * bp / devices
        act = (cfg.num_layers + (cfg.encoder_layers or 0)) * \
            shape.global_batch * shape.seq_len * cfg.d_model * 2 * 2 / devices
        return w + act
    # decode: weights once + full KV/state cache read + small writes
    w = n_total * bp / devices
    cache = cache_bytes(cfg, shape, kv_dtype) / devices
    return w + cache


#: CacheConfig.page_size default — amortizes the per-page scale slab
KV_PAGE_SIZE = 8


def kv_elt_bytes(kv_dtype: str, hd: int, page_size: int = KV_PAGE_SIZE
                 ) -> float:
    """Bytes per paged-KV element: int8 pages carry one f32 scale per
    (page, K/V, head), i.e. 4 bytes amortized over hd * page_size
    elements; bf16 pages are exact two-byte elements."""
    if kv_dtype == "int8":
        return 1.0 + 4.0 / (hd * page_size)
    return 2.0


def cache_bytes(cfg, shape, kv_dtype: str = "bf16") -> float:
    """Global decode-cache bytes (read once per decoded token).

    ``kv_dtype`` rescales only the paged attention KV terms — MLA's
    latent cache, SSM and mLSTM recurrent state are not paged int8."""
    B, T = shape.global_batch, cfg.cache_len(shape)
    hd = cfg.resolved_head_dim
    kvb = kv_elt_bytes(kv_dtype, hd)
    if cfg.block_kind == "mlstm":
        H = cfg.num_heads
        return cfg.num_layers * B * H * (hd * hd + hd + 1) * 4.0
    if cfg.attention_kind == "mla":
        return cfg.num_layers * B * T * (cfg.mla_kv_lora_rank +
                                         cfg.mla_qk_rope_dim) * 2.0
    if cfg.block_kind == "hymba":
        from repro.models.ssm import mamba_dims
        di, _, N = mamba_dims(cfg)
        attn = cfg.num_layers * B * T * cfg.num_kv_heads * hd * 2 * kvb
        ssm = cfg.num_layers * B * (di * N + (cfg.ssm_conv_width - 1) * di) * 4.0
        return attn + ssm
    if cfg.block_kind == "encdec":
        self_c = cfg.num_layers * B * T * cfg.num_kv_heads * hd * 2 * kvb
        cross = cfg.num_layers * B * cfg.frontend_seq * cfg.num_kv_heads * hd * 2 * kvb
        return self_c + cross
    if cfg.local_global_period:
        n_local = (cfg.num_layers + 1) // cfg.local_global_period
        n_global = cfg.num_layers - n_local
        W = min(cfg.sliding_window, T)
        return (n_local * W + n_global * T) * B * cfg.num_kv_heads * hd * 2 * kvb
    return cfg.num_layers * B * T * cfg.num_kv_heads * hd * 2 * kvb


def kv_bytes_per_token(cfg, kv_dtype: str = "bf16",
                       page_size: int = KV_PAGE_SIZE) -> float:
    """KV-cache bytes of ONE resident token across all layers, for an
    explicit engine ``page_size`` — the exact formula
    ``engine.cache_stats()`` publishes as ``bytes_per_token``.

    int8 pages add one float32 scale per (page, K/V, kv-head): 2 slots *
    4 bytes * num_kv_heads amortized over ``page_size`` tokens."""
    hd = cfg.resolved_head_dim
    kv_hd = cfg.num_kv_heads * hd
    if kv_dtype == "int8":
        return cfg.num_layers * 2.0 * (kv_hd + 4.0 * cfg.num_kv_heads
                                       / page_size)
    itemsize = 4.0 if "32" in str(cfg.param_dtype) else 2.0
    return cfg.num_layers * 2.0 * kv_hd * itemsize
