"""SVM — shared "virtual memory" between host and accelerator (HERO §2.2).

HERO's SVM lets host and PMCA exchange *pointers* instead of copies; the
host RTE reserves virtual ranges that would collide with the PMCA's own
address map (§2.2.3).  The JAX adaptation: a handle space shared by the host
scheduler and device programs.  A handle resolves to a device-resident
buffer; passing a handle is zero-copy.  Reserved ranges model the PMCA
SPM/register apertures that must never be used for shared data.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Tuple

import jax


class AddressCollision(Exception):
    pass


class SVMSpace:
    """Handle registry: logical id -> device buffer (+ reserved apertures)."""

    def __init__(self, reserved: Iterable[Tuple[int, int]] = ((0, 1 << 20),)):
        # reserved (lo, hi) handle ranges = PMCA-internal apertures (§2.2.3)
        self.reserved = tuple(reserved)
        self.buffers: Dict[int, Any] = {}
        self._next = max(hi for _, hi in self.reserved) if self.reserved else 1

    def _check(self, handle: int):
        for lo, hi in self.reserved:
            if lo <= handle < hi:
                raise AddressCollision(
                    f"handle {handle:#x} falls in reserved aperture "
                    f"[{lo:#x},{hi:#x}) — would be routed to PMCA-internal "
                    f"memory, not SVM")

    def share(self, array: jax.Array, handle: Optional[int] = None) -> int:
        """Publish a device buffer; returns its handle (the 'pointer')."""
        if handle is None:
            handle = self._next
            self._next += 1
        self._check(handle)
        if handle in self.buffers:
            raise AddressCollision(f"handle {handle:#x} already mapped")
        self.buffers[handle] = array
        return handle

    def deref(self, handle: int) -> Any:
        return self.buffers[handle]

    def update(self, handle: int, array: jax.Array):
        assert handle in self.buffers
        self.buffers[handle] = array

    def release(self, handle: int):
        self.buffers.pop(handle, None)

    def __contains__(self, handle: int) -> bool:
        return handle in self.buffers
