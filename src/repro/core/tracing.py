"""Event tracing (HERO §2.3.1), adapted to the JAX execution model.

HERO's tracers are hardware blocks that (1) never perturb execution, (2) are
cycle-accurate, (3) use buffers economically, and (4) need no application
changes.  The JAX adaptation keeps all four properties:

  * device-side events are recorded as pure array writes into a fixed-size
    ring buffer *carried through the jitted step* — no host callback in the
    hot path (non-intrusive);
  * the logical clock is a monotonically increasing counter carried with the
    buffer (all tracers share it, like HERO's common gated clock);
  * when the buffer fills, recording saturates; the host drains between steps
    (the step boundary is the analogue of HERO's clock-freeze-and-drain);
  * host-side events (offload begin/end, RAB activity) are recorded into the
    same stream with the same schema, so the analyzer sees one timeline.

Event schema (int64 x 5): (timestamp, tracer_id, event_type, arg0, arg1).
"""
from __future__ import annotations

import enum
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class EventType(enum.IntEnum):
    # device-side
    STEP_BEGIN = 1
    STEP_END = 2
    MEM_READ = 3
    MEM_WRITE = 4
    SYNC = 5
    # RAB / VMM protocol (§3.4)
    TLB_L1_HIT = 10
    TLB_L2_HIT = 11
    TLB_MISS = 12
    MISS_HANDLED = 13
    CORE_SLEEP = 14
    CORE_WAKE = 15
    # offload runtime (§2.2)
    OFFLOAD_BEGIN = 20
    OFFLOAD_COPY_TO = 21
    OFFLOAD_KERNEL_BEGIN = 22
    OFFLOAD_KERNEL_END = 23
    OFFLOAD_COPY_FROM = 24
    OFFLOAD_END = 25
    # scheduler / serving
    PAGE_ALLOC = 30
    PAGE_RELEASE = 31
    REQUEST_ADMIT = 32
    REQUEST_FINISH = 33
    # shared-prefix KV cache + preemption (HERO §2.2/§3.4: SVM pages are
    # remapped, shared and reclaimed without touching the data path)
    PAGE_COW = 34          # copy-on-write: (seq, new physical page)
    PREFIX_HIT = 35        # admission prefix-cache hit: (rid, tokens reused)
    REQUEST_PREEMPT = 36   # (rid, private pages swapped out)
    SWAP_OUT = 37          # D2H page reclamation: (rid, pages)
    SWAP_IN = 38           # H2D page restoration: (rid, pages)
    # host<->device transfers on the serving hot path (the data-path cost
    # HERO's DMA double-buffering / zero-copy SVM exist to hide)
    H2D = 40
    D2H = 41
    # multi-cluster sharded serving (HERO §2.1: the PMCA scales by adding
    # clusters behind one SVM fabric; placement and the cross-cluster token
    # gather are the observable scheduling events)
    CLUSTER_DISPATCH = 42  # request placed on a cluster: (rid, cluster)
    ALL_GATHER = 43        # cross-cluster token gather: (iter, active clusters)
    # speculative decoding (HERO §2.2/§2.3: the lightweight host proposes,
    # the parallel accelerator verifies in bulk; every proposal, acceptance
    # and rollback is an observable scheduling event)
    SPEC_PROPOSE = 44      # drafter proposal: (rid, drafted tokens)
    SPEC_ACCEPT = 45       # verified acceptance: (rid, accepted tokens)
    SPEC_ROLLBACK = 46     # rejected drafts undone: (rid, rejected tokens)
    # fault tolerance (HERO's tracing-driven validation: faults are
    # injected, observed and re-tested through the same event stream the
    # healthy engine emits — no fault may vanish without a trace)
    FAULT_INJECT = 47      # injected fault: (rid, kind code | 8*persistent)
    REQUEST_TIMEOUT = 48   # deadline exceeded: (rid, engine iteration)
    REQUEST_SHED = 49      # admission-time load shed: (rid, queue depth)
    DEGRADE = 50           # graceful degradation: (subject, cause code
    #                        1=drafter disabled, 2=watchdog abort,
    #                        3=straggler iteration flagged)
    # live-traffic serving (the host front door feeding the engine a
    # continuous arrival stream instead of one closed batch)
    REQUEST_ARRIVE = 51    # request entered the queue: (rid, queue depth)
    # hierarchical prefix cache (HERO SVM: host DRAM reachable beyond
    # scratchpad capacity — evicted-but-indexed prefix pages demote to a
    # host tier and, under host pressure, to a disk tier; an admission hit
    # on a non-resident page promotes it back).  args: (entry_id,
    # src_tier * 4 + dst_tier) with tiers 0=device, 1=host, 2=disk,
    # 3=dropped — see core.analysis.layer2_tier_residency
    PAGE_DEMOTE = 52       # cache entry moved down-tier (or dropped)
    PAGE_PROMOTE = 53      # cache entry restored to the device pool


HOST_TRACER_ID = 255


class TraceBuffer:
    """Fixed-capacity event buffer; device part is a pytree."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self.host_events: List[Tuple[int, int, int, int, int]] = []
        self._host_clock = 0
        self.dropped = 0

    # ------------------------------------------------------------- device --
    def device_init(self) -> Dict[str, jax.Array]:
        return {
            "events": jnp.zeros((self.capacity, 5), jnp.int32),
            "count": jnp.zeros((), jnp.int32),
            "clock": jnp.zeros((), jnp.int32),
        }

    @staticmethod
    def record(dev: Dict[str, jax.Array], tracer_id: int, etype: int,
               a0=0, a1=0) -> Dict[str, jax.Array]:
        """Pure-functional in-step event record (saturating)."""
        cap = dev["events"].shape[0]
        idx = jnp.minimum(dev["count"], cap - 1)
        ev = jnp.stack([dev["clock"],
                        jnp.asarray(tracer_id, jnp.int32),
                        jnp.asarray(etype, jnp.int32),
                        jnp.asarray(a0, jnp.int32),
                        jnp.asarray(a1, jnp.int32)])
        events = jax.lax.dynamic_update_slice(dev["events"], ev[None, :],
                                              (idx, 0))
        return {"events": events, "count": dev["count"] + 1,
                "clock": dev["clock"] + 1}

    @staticmethod
    def tick(dev: Dict[str, jax.Array], n: int = 1) -> Dict[str, jax.Array]:
        """Advance the logical clock without recording (models latency)."""
        return dict(dev, clock=dev["clock"] + n)

    # --------------------------------------------------------------- host --
    def record_host(self, etype: EventType, a0: int = 0, a1: int = 0):
        self._host_clock += 1
        self.host_events.append(
            (self._host_clock, HOST_TRACER_ID, int(etype), int(a0), int(a1)))

    def drain(self, dev: Optional[Dict[str, jax.Array]] = None) -> np.ndarray:
        """Freeze-and-drain: pull device events + host events, clear both.

        Returns an (N,5) int64 array sorted by (source, timestamp); device
        timestamps are kept in their own clock domain (tracer_id separates
        domains, as HERO's per-logger streams do).
        """
        rows: List[np.ndarray] = []
        if dev is not None:
            n = int(dev["count"])
            cap = dev["events"].shape[0]
            if n > cap:
                self.dropped += n - cap
                n = cap
            if n:
                rows.append(np.asarray(dev["events"][:n], np.int64))
        if self.host_events:
            rows.append(np.asarray(self.host_events, np.int64))
            self.host_events = []
        if not rows:
            return np.zeros((0, 5), np.int64)
        out = np.concatenate(rows, axis=0)
        return out[np.lexsort((out[:, 0], out[:, 1]))]
