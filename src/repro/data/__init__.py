from repro.data.pipeline import SyntheticLMData, MarkovChainData, Prefetcher

__all__ = ["SyntheticLMData", "MarkovChainData", "Prefetcher"]
