"""Deterministic synthetic LM data pipeline with per-host sharding.

Production posture: every host materializes only its slice of the global
batch (``host_id``/``num_hosts``), batches are a pure function of
(seed, step) — so restarts and elastic rescales replay identical data — and
a background prefetcher double-buffers ahead of the step.

Two generators:
  * SyntheticLMData — uniform hash-random tokens (for perf/dry-run work);
  * MarkovChainData — a fixed low-entropy Markov chain, *learnable*, so the
    end-to-end training example shows a real falling loss curve.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


class SyntheticLMData:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, seed: int = 0,
                 num_hosts: int = 1, host_id: int = 0):
        assert shape.global_batch % num_hosts == 0
        self.cfg, self.shape = cfg, shape
        self.seed = seed
        self.num_hosts, self.host_id = num_hosts, host_id
        self.local_batch = shape.global_batch // num_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S = self.local_batch, self.shape.seq_len
        toks = rng.integers(0, self.cfg.vocab_size, (B, S + 1), dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend:
            out["frontend"] = rng.standard_normal(
                (B, self.cfg.frontend_seq, self.cfg.d_model),
                dtype=np.float32).astype(np.float32) * 0.02
        return out


class MarkovChainData(SyntheticLMData):
    """Order-1 Markov chain over a small effective vocabulary."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, seed: int = 0,
                 num_hosts: int = 1, host_id: int = 0,
                 effective_vocab: int = 64, temperature: float = 0.3):
        super().__init__(cfg, shape, seed, num_hosts, host_id)
        self.k = min(effective_vocab, cfg.vocab_size)
        chain_rng = np.random.default_rng(seed + 12345)
        logits = chain_rng.standard_normal((self.k, self.k)) / temperature
        self.P = np.exp(logits - logits.max(1, keepdims=True))
        self.P /= self.P.sum(1, keepdims=True)
        self.cum = np.cumsum(self.P, axis=1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S = self.local_batch, self.shape.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.k, B)
        u = rng.random((B, S))
        for t in range(S):
            toks[:, t + 1] = (
                u[:, t, None] < self.cum[toks[:, t]]).argmax(axis=1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend:
            out["frontend"] = (rng.standard_normal(
                (B, self.cfg.frontend_seq, self.cfg.d_model)) * 0.02
            ).astype(np.float32)
        return out


class Prefetcher:
    """Background-thread double buffering over a data source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
