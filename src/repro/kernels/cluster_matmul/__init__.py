from repro.kernels.cluster_matmul.ops import cluster_matmul
from repro.kernels.cluster_matmul.ref import cluster_matmul_ref

__all__ = ["cluster_matmul", "cluster_matmul_ref"]
