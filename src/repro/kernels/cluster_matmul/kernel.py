"""SPM-tiled matmul kernel (HERO §3.2's cluster program, on the MXU).

HERO's cluster program: DMA a row tile of A and a column tile of B from DRAM
into the L1 SPM, compute the C tile locally, DMA it back.  On TPU the SPM is
VMEM and the DMA engine is the ``pallas_call`` grid pipeline: BlockSpecs
declare the HBM->VMEM tiles, and the K-innermost grid revisits the output
block while streaming A/B tiles through VMEM (double-buffered by the
pipeline — the analogue of the cluster's multi-channel DMA).

Tile sizes default to MXU-aligned 128 multiples; the fp32 accumulator lives
in a VMEM scratch across the K grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.  Shapes must tile evenly."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"({m},{k})x({k},{n}) not tiled by ({bm},{bn},{bk})"
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
