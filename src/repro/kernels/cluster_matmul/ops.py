"""Jitted public wrapper for the cluster matmul kernel.

On a real TPU, ``interpret=False`` runs the Pallas kernel; this container is
CPU-only, so the default resolves to interpret mode (kernel body executed in
Python, validated against ref.py by the test sweep).
"""
from __future__ import annotations

import jax

from repro.kernels.cluster_matmul import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cluster_matmul(a: jax.Array, b: jax.Array, *, bm: int = 128,
                   bn: int = 128, bk: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    return K.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
