from repro.kernels.flash_attention.ops import flash_attention, mha_flash
from repro.kernels.flash_attention.ref import flash_attention_ref

__all__ = ["flash_attention", "mha_flash", "flash_attention_ref"]
