"""Flash attention (forward) Pallas kernel: online softmax in VMEM.

Grid (B*Kv*G..., S/bq, T/bk) streams K/V tiles through VMEM while a running
(max, sum, acc) triple lives in scratch — the memory-hierarchy insight HERO
applies to the SPM (compute on resident tiles, never materialize the S x T
score matrix in HBM).  Supports causal masking, sliding windows, and logit
softcaps (gemma2/hymba variants).

The public op (ops.py) wraps this forward in a custom_vjp whose backward
recomputes through the chunked XLA reference — exact gradients, kernel-fast
forward.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, cap: float,
            bq: int, bk: int, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (bq, d)
    k = k_ref[0]                       # (bk, d)
    v = v_ref[0]                       # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)

    qpos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]             # (bq,1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                           # (bq,bk)
    # fully-masked rows keep m == NEG_INF: exp(NEG_INF - NEG_INF) would be 1,
    # silently attending to everything — zero those probabilities explicitly
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                  # (bq,1)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)[:, None]
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kk == nk - 1)
    def _flush():
        # rows fully masked (causal upper tiles) have l == 0
        lsum = l_ref[...]
        safe = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "bq", "bk", "interpret", "scale", "groups"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        cap: float = 0.0, scale: float | None = None,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = False,
                        groups: int = 1) -> jax.Array:
    """q: (BH, S, d); k/v: (BH // groups, T, d) — heads pre-flattened.

    GQA runs with the *unexpanded* K/V: query head ``b`` reads KV head
    ``b // groups`` through the BlockSpec index map, so the G-fold head
    expansion never materializes in HBM (consecutive query heads reuse the
    same resident K/V tile).  Query heads must be KV-major, i.e. flat index
    ``(batch * Kv + kv) * groups + g`` — the layout ``ops.mha_flash``
    produces.

    Returns (BH, S, d)."""
    BH, S, d = q.shape
    T = k.shape[1]
    assert BH == k.shape[0] * groups, (BH, k.shape[0], groups)
    bq, bk = min(bq, S), min(bk, T)
    assert S % bq == 0 and T % bk == 0
    nk = T // bk
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_kernel, scale=sc, causal=causal, window=window,
                          cap=cap, bq=bq, bk=bk, nk=nk),
        grid=(BH, S // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, kk: (b // groups, kk, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, kk: (b // groups, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, kk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
