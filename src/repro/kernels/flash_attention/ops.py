"""Public flash-attention op: Pallas forward + exact recompute backward."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=0, cap=0.0,
                    interpret=None):
    itp = (not _on_tpu()) if interpret is None else interpret
    return K.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 cap=cap, interpret=itp)


def _fwd(q, k, v, causal, window, cap, interpret):
    return flash_attention(q, k, v, causal, window, cap, interpret), (q, k, v)


def _bwd(causal, window, cap, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, cap=cap), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)


def mha_flash(q, k, v, *, causal=True, window=0, cap=0.0, interpret=None):
    """(B,S,H,hd) x (B,T,K,hd) GQA convenience wrapper -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, hd)
    out = flash_attention(qf, kf, vf, causal, window, cap, interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
