"""Public flash-attention op: Pallas forward + exact recompute backward."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, cap=0.0,
                    interpret=None, groups=1):
    itp = (not _on_tpu()) if interpret is None else interpret
    return K.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 cap=cap, interpret=itp, groups=groups)


def _fwd(q, k, v, causal, window, cap, interpret, groups):
    return (flash_attention(q, k, v, causal, window, cap, interpret, groups),
            (q, k, v))


def _bwd(causal, window, cap, interpret, groups, res, g):
    q, k, v = res

    def ref(q_, k_, v_):
        # exact recompute; the jnp.repeat is backward-only (its VJP sums the
        # per-group K/V grads) — the kernel-fast forward never expands
        if groups > 1:
            k_ = jnp.repeat(k_, groups, axis=0)
            v_ = jnp.repeat(v_, groups, axis=0)
        return flash_attention_ref(q_, k_, v_, causal=causal, window=window,
                                   cap=cap)

    return jax.vjp(ref, q, k, v)[1](g)


flash_attention.defvjp(_fwd, _bwd)


def mha_flash(q, k, v, *, causal=True, window=0, cap=0.0, interpret=None):
    """(B,S,H,hd) x (B,T,K,hd) GQA convenience wrapper -> (B,S,H,hd).

    The shared KV head is indexed inside the kernel (flat query head
    ``b*H + kv*G + g`` reads KV row ``b*Kv + kv = (b*H + kv*G + g) // G``)
    instead of materializing the G-fold ``jnp.repeat`` expansion in HBM."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, T, hd)
    out = flash_attention(qf, kf, vf, causal, window, cap, interpret, G)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
