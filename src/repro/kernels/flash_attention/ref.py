"""Pure-jnp oracle for flash attention (exact softmax attention)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        cap: float = 0.0, scale: float | None = None
                        ) -> jax.Array:
    """q: (BH,S,d); k/v: (BH,T,d)."""
    BH, S, d = q.shape
    T = k.shape[1]
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bsd,btd->bst", q, k,
                   preferred_element_type=jnp.float32) * sc
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, NEG_INF)
    # fully-masked rows -> zeros (match kernel's safe-divide)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask[None], axis=-1, keepdims=True), p, 0.0)
    return jnp.einsum("bst,btd->bsd", p.astype(v.dtype), v)
