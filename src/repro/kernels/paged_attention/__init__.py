from repro.kernels.paged_attention.ops import (
    paged_attention, paged_prefill, paged_decode_fused, paged_prefill_fused,
    pad_block_table, page_counts_for,
)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref, paged_prefill_ref,
)

__all__ = ["paged_attention", "paged_prefill", "paged_decode_fused",
           "paged_prefill_fused", "pad_block_table", "page_counts_for",
           "paged_attention_ref", "paged_prefill_ref"]
