"""Paged decode attention with in-kernel RAB translation.

The RAB insight (HERO C2): a tiny software-managed table suffices to let an
accelerator translate virtual addresses at run time.  Here the table is the
block table maintained by ``core/rab.py``; the kernel *itself* performs the
translation on its fast path — the block table is scalar-prefetched (SMEM)
and indexes the physical KV page pulled into VMEM per grid step.  A -1 entry
is an unmapped page (never touched: masked + clamped), the slow path
(allocation) having been handled by the host-side RAB miss handler before
launch.

Grid (B, max_pages): online-softmax accumulation over one request's pages.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, page_size: int,
            n_pages: int, groups: int):
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_page = bt_ref[b, j] >= 0

    @pl.when(valid_page)
    def _accumulate():
        q = q_ref[0]                              # (H, hd)
        k = k_ref[0]                              # (page, Kv, hd)
        v = v_ref[0]
        Kv = k.shape[1]
        hd = q.shape[-1]
        qg = q.reshape(Kv, groups, hd)
        s = jnp.einsum("kgh,pkh->kgp", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale  # (Kv,G,page)
        tok = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 2)
        mask = tok < len_ref[b]
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]   # (Kv,G,1)
        m_cur = jnp.max(s, axis=2, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=2, keepdims=True)
        ctx = jnp.einsum("kgp,pkh->kgh", p, v.astype(jnp.float32))
        acc_ref[...] = acc_ref[...] * alpha + ctx
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = (acc_ref[...] / safe)               # (Kv,G,hd)
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "scale"))
def paged_attention_fwd(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_table: jax.Array, lengths: jax.Array, *,
                        scale: float | None = None,
                        interpret: bool = False) -> jax.Array:
    """q: (B,H,hd); k/v_pages: (P, page, Kv, hd); block_table: (B, max_pages)
    int32 physical page ids (-1 unmapped); lengths: (B,) tokens per request.

    Returns (B,H,hd)."""
    B, H, hd = q.shape
    P, page, Kv, _ = k_pages.shape
    n_pages = block_table.shape[1]
    groups = H // Kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, Kv, hd),
                         lambda b, j, bt, ln: (jnp.maximum(bt[b, j], 0), 0, 0, 0)),
            pl.BlockSpec((1, page, Kv, hd),
                         lambda b, j, bt, ln: (jnp.maximum(bt[b, j], 0), 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Kv, groups, 1), jnp.float32),
            pltpu.VMEM((Kv, groups, 1), jnp.float32),
            pltpu.VMEM((Kv, groups, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, scale=sc, page_size=page,
                          n_pages=n_pages, groups=groups),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pages, v_pages)
