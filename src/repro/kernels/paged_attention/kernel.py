"""Paged attention kernels with in-kernel RAB translation.

The RAB insight (HERO C2): a tiny software-managed table suffices to let an
accelerator translate virtual addresses at run time.  Here the table is the
block table maintained by ``core/rab.py``; the kernels *themselves* perform
the translation on their fast path — the block table is scalar-prefetched
(SMEM) and indexes physical KV pages pulled into VMEM per grid step.  The
slow path (allocation) is handled by the host-side RAB miss handler before
launch.

One kernel body serves two entry points:

``paged_prefill_fwd``
    A whole prompt chunk (``C`` tokens) per request against the paged pool,
    flash-style.  Grid ``(B, ceil(n_pages / G))``: each step attends ``G``
    KV pages (``pages_per_step``) with a single online-softmax rescale plus
    the causal in-chunk mask (the chunk's own K/V are pool-resident by the
    time the kernel runs, so one mask covers both history and in-chunk
    causality).

``paged_decode_fwd``
    One query token per request — the C=1 special case of the above (with
    ``q_start = lengths - 1`` the masks coincide), kept as its own entry
    point for the engine's decode path.

Both take a *fused* KV pool of shape ``(P, 2, page, Kv, hd)`` — K and V for
a page live in one block and are fetched through one combined index map,
halving the address-translation work of the old separate-K/V layout.

Both require a *repeat-padded* block table: entries past a request's last
mapped page hold the last mapped physical page (never -1).  Trailing grid
steps therefore map to the same block as their predecessor, which lets the
Pallas pipeline elide the DMA entirely, and a scalar-prefetched per-request
page count (``page_counts``) gates the compute, so fully-unmapped trailing
steps cost neither fetch nor FLOPs.  ``ops.pad_block_table`` produces the
padded form from a -1-marked table.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ===========================================================================
# decode: one query token, G pages per grid step
# ===========================================================================

@functools.partial(jax.jit, static_argnames=("pages_per_step", "interpret",
                                             "scale"))
def paged_decode_fwd(q: jax.Array, kv_pages: jax.Array,
                     block_table: jax.Array, page_counts: jax.Array,
                     lengths: jax.Array, *, pages_per_step: int = 2,
                     scale: float | None = None,
                     interpret: bool = False,
                     kv_scales: jax.Array | None = None) -> jax.Array:
    """q: (B,H,hd); kv_pages: (P, 2, page, Kv, hd) fused K/V pool;
    block_table: (B, max_pages) int32 physical page ids, repeat-padded (no
    -1; see module docstring); page_counts: (B,) mapped logical pages per
    request; lengths: (B,) tokens per request; kv_scales: optional
    (P, 2, Kv) float32 per-page dequantization scales for an int8 pool
    (see ``paged_prefill_fwd``).

    Decode is exactly the C=1 case of chunked prefill: with
    ``q_start = lengths - 1`` the prefill mask ``tok < len & tok <= qpos``
    collapses to the decode mask ``tok < len``, so one kernel serves both
    paths (and empty lanes, qpos = -1, stay fully masked).

    Returns (B,H,hd)."""
    return paged_prefill_fwd(q[:, None], kv_pages, block_table, page_counts,
                             lengths, lengths - 1,
                             pages_per_step=pages_per_step, scale=scale,
                             interpret=interpret,
                             kv_scales=kv_scales)[:, 0]


# ===========================================================================
# chunked prefill: C query tokens, G pages per grid step
# ===========================================================================

def _prefill_kernel(bt_ref, cnt_ref, len_ref, start_ref, q_ref, *refs,
                    scale: float, page_size: int, g_pages: int, groups: int,
                    quant: bool):
    kv_refs = refs[:g_pages]
    rest = refs[g_pages:]
    sc_refs = rest[:g_pages] if quant else ()
    rest = rest[g_pages:] if quant else rest
    o_ref = rest[0]
    m_ref, l_ref, acc_ref = rest[1:]
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    npages = cnt_ref[b]

    @pl.when(j * g_pages < npages)
    def _accumulate():
        q = q_ref[0]                                   # (C, H, hd)
        if quant:
            # int8 pool: dequantize inside the fetch — one f32 scale per
            # (page, K/V, kv-head) broadcast over page slots and head dim.
            k = jnp.concatenate(
                [r[0, 0].astype(jnp.float32) * s[0, 0][None, :, None]
                 for r, s in zip(kv_refs, sc_refs)], axis=0)
            v = jnp.concatenate(
                [r[0, 1].astype(jnp.float32) * s[0, 1][None, :, None]
                 for r, s in zip(kv_refs, sc_refs)], axis=0)
        else:
            k = jnp.concatenate([r[0, 0] for r in kv_refs], axis=0)
            v = jnp.concatenate([r[0, 1] for r in kv_refs], axis=0)
        C, _, hd = q.shape
        Kv = k.shape[1]
        qg = q.reshape(C, Kv, groups, hd)
        s = jnp.einsum("ckgh,pkh->ckgp", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale  # (C,Kv,G,G*page)
        tok = j * g_pages * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 3)
        qpos = start_ref[b] + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        # one mask covers pool history AND in-chunk causality: the chunk's
        # own K/V are already pool-resident at positions start..start+C-1
        mask = (tok < len_ref[b]) & (tok <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]        # (C,Kv,G,1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=3, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=3, keepdims=True)
        ctx = jnp.einsum("ckgp,pkh->ckgh", p, v.astype(jnp.float32))
        acc_ref[...] = acc_ref[...] * alpha + ctx
        m_ref[...] = m_new

    last_step = (jnp.maximum(npages, 1) + g_pages - 1) // g_pages - 1

    @pl.when(j == last_step)
    def _flush():
        lsum = l_ref[...]
        safe = jnp.where(lsum == 0.0, 1.0, lsum)
        out = acc_ref[...] / safe                      # (C,Kv,G,hd)
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pages_per_step", "interpret",
                                             "scale"))
def paged_prefill_fwd(q: jax.Array, kv_pages: jax.Array,
                      block_table: jax.Array, page_counts: jax.Array,
                      lengths: jax.Array, q_start: jax.Array, *,
                      pages_per_step: int = 2, scale: float | None = None,
                      interpret: bool = False,
                      kv_scales: jax.Array | None = None) -> jax.Array:
    """q: (B,C,H,hd) — a chunk of C query tokens per request, occupying
    positions ``q_start[b] .. q_start[b]+C-1``; their K/V must already be
    written into the pool (``lengths`` includes them).  Other args as
    ``paged_decode_fwd``.  Rows past a request's real chunk length attend
    to the full resident sequence (callers ignore them).

    When ``kv_scales`` is given — (P, 2, Kv) float32, one scale per (page,
    K/V, kv-head) — the pool is int8 and each fetched page block is
    dequantized in-kernel before the attention math; the scale blocks ride
    the same page-indexed DMA as their K/V pages, so the extra traffic is
    4 bytes per (K/V, head) per page.

    Returns (B,C,H,hd)."""
    B, C, H, hd = q.shape
    P, _, page, Kv, _ = kv_pages.shape
    n_pages = block_table.shape[1]
    g = max(1, min(pages_per_step, n_pages))
    n_steps = _cdiv(n_pages, g)
    groups = H // Kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    quant = kv_scales is not None

    def page_imap(gi):
        def imap(b, j, bt, cnt, ln, st):
            idx = jnp.minimum(j * g + gi, n_pages - 1)
            return bt[b, idx]
        return imap

    def kv_spec(gi):
        im = page_imap(gi)
        return pl.BlockSpec((1, 2, page, Kv, hd),
                            lambda b, j, *a, _im=im: (_im(b, j, *a), 0, 0, 0, 0))

    def sc_spec(gi):
        im = page_imap(gi)
        return pl.BlockSpec((1, 2, Kv),
                            lambda b, j, *a, _im=im: (_im(b, j, *a), 0, 0))

    in_specs = ([pl.BlockSpec((1, C, H, hd), lambda b, j, *_: (b, 0, 0, 0))] +
                [kv_spec(gi) for gi in range(g)])
    operands = [q] + [kv_pages] * g
    if quant:
        in_specs += [sc_spec(gi) for gi in range(g)]
        operands += [kv_scales] * g

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C, H, hd), lambda b, j, *_: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, Kv, groups, 1), jnp.float32),
            pltpu.VMEM((C, Kv, groups, 1), jnp.float32),
            pltpu.VMEM((C, Kv, groups, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_prefill_kernel, scale=sc, page_size=page,
                          g_pages=g, groups=groups, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, hd), q.dtype),
        interpret=interpret,
    )(block_table, page_counts, lengths, q_start, *operands)
