"""Public paged-attention op (decode fast path of the serving engine)."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention import kernel as K
from repro.kernels.paged_attention.ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    interpret=None):
    itp = (not _on_tpu()) if interpret is None else interpret
    return K.paged_attention_fwd(q, k_pages, v_pages, block_table, lengths,
                                 interpret=itp)
