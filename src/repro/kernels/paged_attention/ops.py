"""Public paged-attention ops (decode + chunked-prefill fast paths).

Two API levels:

* ``paged_attention`` / ``paged_prefill`` — convenience wrappers over
  separate K/V pools and -1-marked block tables (the host-friendly form the
  tests and older callers use).  They fuse K/V and repeat-pad the table per
  call, which costs a stack + gather.
* ``paged_decode_fused`` / ``paged_prefill_fused`` — zero-overhead entry
  points for callers (the serving engine) that natively maintain the fused
  ``(P, 2, page, Kv, hd)`` pool and a repeat-padded device block table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel as K
from repro.kernels.paged_attention.ref import (
    paged_attention_ref, paged_prefill_ref,
)

__all__ = [
    "paged_attention", "paged_prefill", "paged_decode_fused",
    "paged_prefill_fused", "pad_block_table", "page_counts_for",
    "paged_attention_ref", "paged_prefill_ref", "validate_head_sharding",
]


def validate_head_sharding(num_heads: int, num_kv_heads: int,
                           shards: int) -> int:
    """Check a tensor-parallel head split is GQA-safe for these kernels.

    The kernels' head layout is kv-major: query head ``k*G + g`` reads kv
    head ``k`` (``G = H // Kv``).  A split into ``shards`` equal contiguous
    blocks therefore keeps every query head on the same shard as its kv
    head iff ``shards`` divides ``num_kv_heads``.  Returns the per-shard
    kv-head count; raises ``ValueError`` on an unsafe split.
    """
    if shards < 1:
        raise ValueError(f"head shards must be >= 1, got {shards}")
    if num_heads % max(num_kv_heads, 1):
        raise ValueError(f"H={num_heads} not a multiple of Kv={num_kv_heads}")
    if num_kv_heads % shards:
        raise ValueError(
            f"head axis {shards} does not divide num_kv_heads="
            f"{num_kv_heads}: a shard would split a GQA group across "
            f"devices and the block-table gather could not stay local")
    return num_kv_heads // shards


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _itp(interpret):
    return (not _on_tpu()) if interpret is None else interpret


def page_counts_for(lengths: jax.Array, page_size: int) -> jax.Array:
    """(B,) number of mapped logical pages implied by token counts."""
    return (lengths + page_size - 1) // page_size


def pad_block_table(block_table: jax.Array, page_counts: jax.Array
                    ) -> jax.Array:
    """-1-marked (B, n_pages) table -> repeat-padded form the kernels want.

    Entries past ``page_counts[b]`` are replaced by the last mapped page so
    consecutive trailing grid steps resolve to the same block (DMA elided).

    Contract: mapping must be *dense* — every logical page below
    ``page_counts[b]`` mapped (>= 0), -1 only past the mapped prefix (what
    ``PagedKVPool`` produces: pages are allocated in logical order and the
    count derives from the token length).  An interior -1 hole would be
    silently remapped to physical page 0 here, where the masked oracle
    (``paged_attention_ref``) would exclude it.
    """
    n_pages = block_table.shape[1]
    idx = jnp.arange(n_pages, dtype=jnp.int32)[None, :]
    last = jnp.maximum(page_counts - 1, 0).astype(jnp.int32)[:, None]
    return jnp.take_along_axis(jnp.maximum(block_table, 0),
                               jnp.minimum(idx, last), axis=1)


def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    interpret=None, pages_per_step: int = 2):
    """Decode fast path, host-friendly form.

    q: (B,H,hd); k/v_pages: (P, page, Kv, hd); block_table: (B, max_pages)
    int32 physical page ids, densely mapped for the first
    ``ceil(length/page)`` logical pages and -1 past them (see
    ``pad_block_table``); lengths: (B,).  Returns (B,H,hd).
    """
    counts = page_counts_for(lengths, k_pages.shape[1])
    return K.paged_decode_fwd(
        q, jnp.stack([k_pages, v_pages], axis=1),
        pad_block_table(block_table, counts), counts, lengths,
        pages_per_step=pages_per_step, interpret=_itp(interpret))


def paged_prefill(q, k_pages, v_pages, block_table, lengths, q_start, *,
                  interpret=None, pages_per_step: int = 2):
    """Chunked-prefill fast path, host-friendly form.

    q: (B,C,H,hd) — C chunk tokens at positions q_start..q_start+C-1, whose
    K/V are already in the pool; other args as ``paged_attention``.
    """
    counts = page_counts_for(lengths, k_pages.shape[1])
    return K.paged_prefill_fwd(
        q, jnp.stack([k_pages, v_pages], axis=1),
        pad_block_table(block_table, counts), counts, lengths, q_start,
        pages_per_step=pages_per_step, interpret=_itp(interpret))


def paged_decode_fused(q, kv_pages, block_table, page_counts, lengths, *,
                       interpret=None, pages_per_step: int = 2,
                       kv_scales=None):
    """Decode on a fused pool + repeat-padded device block table.  Pass
    ``kv_scales`` ((P, 2, Kv) f32) for an int8 pool — dequant happens
    inside the kernel's K/V fetch."""
    return K.paged_decode_fwd(q, kv_pages, block_table, page_counts, lengths,
                              pages_per_step=pages_per_step,
                              interpret=_itp(interpret), kv_scales=kv_scales)


def paged_prefill_fused(q, kv_pages, block_table, page_counts, lengths,
                        q_start, *, interpret=None, pages_per_step: int = 2,
                        kv_scales=None):
    """Chunked prefill on a fused pool + repeat-padded device block table.
    ``kv_scales`` as in ``paged_decode_fused``."""
    return K.paged_prefill_fwd(q, kv_pages, block_table, page_counts,
                               lengths, q_start,
                               pages_per_step=pages_per_step,
                               interpret=_itp(interpret), kv_scales=kv_scales)
