"""Pure-jnp oracle for paged decode attention: gather pages, dense attend."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_table: jax.Array, lengths: jax.Array, *,
                        scale: float | None = None,
                        k_scales: jax.Array | None = None,
                        v_scales: jax.Array | None = None) -> jax.Array:
    """Same contract as kernel.paged_attention_fwd.  ``k_scales``/
    ``v_scales`` — (P, Kv) float32 per-(page, kv-head) dequant scales —
    mark the pages as int8 and are applied to the gathered pages before
    the attention math (the bf16 path is untouched, byte-identical to
    before the knob existed)."""
    B, H, hd = q.shape
    P, page, Kv, _ = k_pages.shape
    n_pages = block_table.shape[1]
    G = H // Kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)

    safe_bt = jnp.maximum(block_table, 0)                     # (B, n_pages)
    k = k_pages[safe_bt]                                      # (B,n,page,Kv,hd)
    v = v_pages[safe_bt]
    if k_scales is not None:
        k = k.astype(jnp.float32) * k_scales[safe_bt][:, :, None, :, None]
        v = v.astype(jnp.float32) * v_scales[safe_bt][:, :, None, :, None]
    T = n_pages * page
    k = k.reshape(B, T, Kv, hd)
    v = v.reshape(B, T, Kv, hd)

    qg = q.reshape(B, Kv, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * sc
    tok = jnp.arange(T)[None, :]
    mask = (tok < lengths[:, None])[:, None, None, :]
    page_ok = jnp.repeat(block_table >= 0, page, axis=1)[:, None, None, :]
    s = jnp.where(mask & page_ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask & page_ok, axis=-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_prefill_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      block_table: jax.Array, lengths: jax.Array,
                      q_start: jax.Array, *,
                      scale: float | None = None,
                      k_scales: jax.Array | None = None,
                      v_scales: jax.Array | None = None) -> jax.Array:
    """Oracle for chunked prefill: same contract as kernel.paged_prefill_fwd
    (q: (B,C,H,hd); lengths include the chunk's pool-resident tokens).
    ``k_scales``/``v_scales`` as in ``paged_attention_ref``."""
    B, C, H, hd = q.shape
    P, page, Kv, _ = k_pages.shape
    n_pages = block_table.shape[1]
    G = H // Kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)

    safe_bt = jnp.maximum(block_table, 0)
    T = n_pages * page
    k = k_pages[safe_bt]
    v = v_pages[safe_bt]
    if k_scales is not None:
        k = k.astype(jnp.float32) * k_scales[safe_bt][:, :, None, :, None]
        v = v.astype(jnp.float32) * v_scales[safe_bt][:, :, None, :, None]
    k = k.reshape(B, T, Kv, hd)
    v = v.reshape(B, T, Kv, hd)

    qg = q.reshape(B, C, Kv, G, hd)
    s = jnp.einsum("bckgh,btkh->bckgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * sc
    tok = jnp.arange(T)[None, None, :]                        # (1,1,T)
    qpos = (q_start[:, None] + jnp.arange(C)[None, :])[..., None]  # (B,C,1)
    mask = (tok < lengths[:, None, None]) & (tok <= qpos)     # (B,C,T)
    page_ok = jnp.repeat(block_table >= 0, page, axis=1)[:, None, :]
    mask = (mask & page_ok)[:, :, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, axis=-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bckgt,btkh->bckgh", p, v.astype(jnp.float32))
    return out.reshape(B, C, H, hd).astype(q.dtype)
