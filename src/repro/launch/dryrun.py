import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the step function),
  * the per-device memory footprint fits (memory_analysis),
  * and it yields the roofline inputs (cost_analysis + collective bytes).

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

from repro.configs import all_cells, get_config, get_shape, SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_stats import (
    collective_stats, collective_stats_corrected, dot_flops,
    total_collective_bytes,
)
from repro.models import steps as ST
from repro.parallel.sharding import mesh_context, sharding_profile

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False, profile: str = "megatron") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cfg.shape_applicable(shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch, "profile": profile}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with sharding_profile(profile), mesh_context(mesh):
        fn, arg_specs = ST.lowerable(cfg, shape, mesh, profile=profile)
        lowered = fn.lower(*arg_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        mem_rec = {}
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            mem_rec[field] = getattr(mem, field, None)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float)) and k in
                    ("flops", "bytes accessed", "transcendentals",
                     "bytes accessed output", "optimal_seconds")}

        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        coll_tpu = collective_stats_corrected(hlo)
        rec.update(
            status="ok",
            devices=mesh.devices.size,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_rec,
            cost=cost_rec,
            collectives=coll,
            collective_bytes=total_collective_bytes(coll),
            collective_bytes_tpu=total_collective_bytes(coll_tpu),
            dot_flops=dot_flops(hlo),
            hlo_ops=hlo.count("\n"),
        )
        if keep_hlo:
            rec["hlo_path"] = str(ARTIFACTS / f"{arch}__{shape_name}__{mesh_name}.hlo")
            Path(rec["hlo_path"]).write_text(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--profile", default="megatron",
                    choices=["megatron", "fsdp", "serve"])
    args = ap.parse_args()

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for cfg, shape, ok, why in all_cells():
            cells.append((cfg.name, shape.name))
    else:
        archs = [args.arch] if args.arch else [c for c in SHAPES]
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(args.arch, s) for s in shapes] if args.arch else \
                [(a, args.shape) for a in archs]

    n_fail = 0
    suffix = "" if args.profile == "megatron" else f"__{args.profile}"
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            out = ARTIFACTS / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
            try:
                rec = run_cell(arch, shape_name, mp, keep_hlo=args.keep_hlo,
                               profile=args.profile)
            except Exception as e:  # noqa: BLE001 - record and continue
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                n_fail += 1
            out.write_text(json.dumps(rec, indent=2, default=str))
            status = rec["status"]
            extra = ""
            if status == "ok":
                gib = (rec["memory"]["argument_size_in_bytes"] or 0) / 2**30
                extra = (f"args={gib:.2f}GiB tmp="
                         f"{(rec['memory']['temp_size_in_bytes'] or 0)/2**30:.2f}GiB "
                         f"flops={rec['cost'].get('flops', 0):.3e} "
                         f"coll={rec['collective_bytes']/2**30:.3f}GiB "
                         f"compile={rec['compile_s']}s")
            elif status == "error":
                extra = rec["error"][:160]
            elif status == "skipped":
                extra = rec["reason"][:80]
            print(f"[{status:7s}] {arch:18s} {shape_name:12s} {mesh_name:6s} {extra}",
                  flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")
    print("DRY-RUN COMPLETE")


if __name__ == "__main__":
    main()
