"""Trip-count-aware HLO cost extraction (collective bytes + dot FLOPs).

``compiled.cost_analysis()`` counts while-loop bodies ONCE, and has no
collective-bytes entry at all.  Scanned-layer training graphs would therefore
be undercounted ~L x.  This module parses the per-device, SPMD-partitioned
HLO text into computations, builds the call graph, derives each while loop's
trip count from its condition's comparison constant, and multiplies every
computation's costs by its execution count.

Extracted per module:
  * collective stats: count/operand/result bytes per collective kind
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), trip-multiplied;
  * dot FLOPs: 2 * prod(result_dims) * contract_size per dot, trip-multiplied
    (an exact re-count of cost_analysis()'s flops that is loop-correct).

CPU-backend caveat handled here: the CPU emitter upcasts bf16 dot operands to
f32 *before* partitioning, so collectives that would be bf16 on the TPU
target appear as f32.  ``corrected=True`` halves f32 collectives >= 1 MiB.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d]


class HloModule:
    """Light structural parse of HLO text: computations, calls, whiles."""

    def __init__(self, text: str):
        self.comp_lines: Dict[str, List[str]] = {}
        self.is_entry: Optional[str] = None
        cur: Optional[str] = None
        for line in text.splitlines():
            stripped = line.rstrip()
            if not stripped:
                continue
            # computation headers sit at column 0 and end with '{'
            # (ops are indented; tuple-typed params make regexes unreliable)
            if not line.startswith(" ") and stripped.endswith("{") \
                    and "->" in stripped:
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
                if m:
                    cur = m.group(1)
                    self.comp_lines[cur] = []
                    if stripped.startswith("ENTRY"):
                        self.is_entry = cur
                    continue
            if stripped.strip() == "}":
                continue
            if cur is not None:
                self.comp_lines[cur].append(stripped)
        if getattr(self, "is_entry", None) is None:
            # fall back: computation named main-ish or the last one
            names = list(self.comp_lines)
            entry = [n for n in names if "main" in n]
            self.is_entry = entry[0] if entry else (names[-1] if names else "")
        self._trip_cache: Dict[str, int] = {}
        self._mult = self._execution_counts()

    # -- call graph -------------------------------------------------------
    def _body_cond_pairs(self, comp: str) -> List[Tuple[str, str]]:
        out = []
        for line in self.comp_lines.get(comp, ()):
            if re.search(r"\bwhile\(", line):
                c = re.search(r"condition=%?([\w.\-]+)", line)
                b = re.search(r"body=%?([\w.\-]+)", line)
                if c and b:
                    out.append((b.group(1), c.group(1)))
        return out

    def _plain_calls(self, comp: str) -> List[str]:
        out = []
        for line in self.comp_lines.get(comp, ()):
            if re.search(r"\bwhile\(", line):
                continue
            for m in _CALLED_RE.finditer(line):
                for name in m.group(1).split(","):
                    out.append(name.strip().lstrip("%"))
        return out

    def trip_count(self, cond_comp: str) -> int:
        """Largest s32 comparison constant in the while condition."""
        if cond_comp in self._trip_cache:
            return self._trip_cache[cond_comp]
        best = 1
        for line in self.comp_lines.get(cond_comp, ()):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        self._trip_cache[cond_comp] = best
        return best

    def _execution_counts(self) -> Dict[str, float]:
        mult: Dict[str, float] = defaultdict(float)
        seen_stack = set()

        def visit(comp: str, k: float):
            if comp not in self.comp_lines or comp in seen_stack:
                return
            mult[comp] += k
            seen_stack.add(comp)
            for body, cond in self._body_cond_pairs(comp):
                t = self.trip_count(cond)
                visit(cond, k * (t + 1))
                visit(body, k * t)
            for callee in self._plain_calls(comp):
                visit(callee, k)
            seen_stack.discard(comp)

        visit(self.is_entry, 1.0)
        return dict(mult)

    def multiplier(self, comp: str) -> float:
        return self._mult.get(comp, 0.0)


# ---------------------------------------------------------------------------
# Collectives
# ---------------------------------------------------------------------------

def _first_shapes_bytes(text: str) -> int:
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(text))


def collective_stats(hlo_text: str, corrected: bool = False
                     ) -> Dict[str, Dict[str, float]]:
    """Per-kind {count, operand_bytes, result_bytes}, trip-multiplied."""
    mod = HloModule(hlo_text)
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "operand_bytes": 0.0, "result_bytes": 0.0})
    for comp, lines in mod.comp_lines.items():
        k = mod.multiplier(comp)
        if k == 0.0:
            continue
        # name -> result bytes, for operand-by-name fallback
        name_bytes: Dict[str, int] = {}
        for line in lines:
            m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)", line)
            if m:
                head = m.group(2).split("(", 1)[0]
                name_bytes[m.group(1)] = _first_shapes_bytes(head)
        for line in lines:
            for kind in COLLECTIVES:
                if not re.search(rf"\b{kind}(?:-start)?\(", line):
                    continue
                m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)", line)
                if not m:
                    continue
                rhs = m.group(1)
                head, _, args = rhs.partition("(")
                args = args.rsplit(")", 1)[0]
                rb = _first_shapes_bytes(head)
                ob = _first_shapes_bytes(args)
                if ob == 0:
                    for nm in re.findall(r"%([\w.\-]+)", args):
                        ob += name_bytes.get(nm, 0)
                if corrected and _is_big_f32(head):
                    rb, ob = rb * 0.5, ob * 0.5
                d = out[kind]
                d["count"] += k
                d["operand_bytes"] += k * ob
                d["result_bytes"] += k * rb
                break
    return dict(out)


def _is_big_f32(head: str) -> bool:
    m = _SHAPE_RE.search(head)
    return bool(m and m.group(1) == "f32" and
                _shape_bytes(m.group(1), m.group(2)) >= 2 ** 20)


def collective_stats_corrected(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return collective_stats(hlo_text, corrected=True)


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    """Per-device bytes on the wire, with per-kind ring-cost weights.

    all-reduce moves ~2x its operand (reduce-scatter + all-gather phases);
    the others move ~1x their operand/result size.
    """
    total = 0.0
    for kind, d in stats.items():
        if kind == "all-reduce":
            total += 2.0 * d["operand_bytes"]
        elif kind == "all-gather":
            total += max(d["result_bytes"], d["operand_bytes"])
        else:
            total += d["operand_bytes"]
    return total


# ---------------------------------------------------------------------------
# Dot FLOPs (loop-corrected re-count of cost_analysis flops)
# ---------------------------------------------------------------------------

_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\bdot\((.*?)\),\s*"
    r"lhs_batch_dims={([0-9,]*)}[^l]*lhs_contracting_dims={([0-9,]*)}")
_DOT_RE2 = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\bdot\((.*?)\),\s*"
    r"lhs_contracting_dims={([0-9,]*)}")


_DOT_LINE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\bdot\((.*?)\).*?"
    r"lhs_contracting_dims=\{([0-9,]*)\}")


def dot_flops(hlo_text: str) -> float:
    mod = HloModule(hlo_text)
    total = 0.0
    for comp, lines in mod.comp_lines.items():
        k = mod.multiplier(comp)
        if k == 0.0:
            continue
        # name -> dims, for operands printed by name only
        name_dims: Dict[str, List[int]] = {}
        for line in lines:
            m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                         r"([a-z0-9]+)\[([0-9,]*)\]", line)
            if m:
                name_dims[m.group(1)] = _dims(m.group(3))
        for line in lines:
            if "dot(" not in line:
                continue
            m = _DOT_LINE.search(line)
            if not m:
                continue
            res_dims = _dims(m.group(2))
            args, contract = m.group(3), _dims(m.group(4))
            shapes = _SHAPE_RE.findall(args)
            if shapes:
                lhs_dims = _dims(shapes[0][1])
            else:
                names = re.findall(r"%([\w.\-]+)", args)
                lhs_dims = name_dims.get(names[0], []) if names else []
            csize = 1
            for c in contract:
                if c < len(lhs_dims):
                    csize *= lhs_dims[c]
            total += k * 2.0 * math.prod(res_dims or [1]) * csize
    return total
