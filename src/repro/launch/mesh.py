"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state.  The dry-run process
sets XLA_FLAGS --xla_force_host_platform_device_count=512 before any jax
import; everything else (smoke tests, benches) sees the real single device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (possibly virtual) devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (the roofline target)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_LINK_BW = 50e9             # bytes/s per link
