"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state.  The dry-run process
sets XLA_FLAGS --xla_force_host_platform_device_count=512 before any jax
import; everything else (smoke tests, benches) sees the real single device.
"""
from __future__ import annotations

import dataclasses
import os

import jax
from jax.sharding import Mesh

#: Recipe for getting C virtual devices on a CPU host (must be set before
#: the first jax import; see README "Scaling across clusters").
HOST_DEVICE_RECIPE = (
    "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@dataclasses.dataclass(frozen=True)
class ClusterMesh:
    """The serving engine's device mesh: C PMCA clusters x H head shards.

    HERO §2.1: the PMCA scales by adding clusters behind one SVM fabric.
    The serving adaptation maps each cluster to a data-parallel lane group
    with its own KV page shard, and splits attention heads (GQA-aware)
    tensor-parallel inside a cluster over the ``head`` axis.  Axis names
    are fixed: ``("cluster", "head")``.
    """

    mesh: Mesh
    clusters: int
    heads: int

    AXIS_NAMES = ("cluster", "head")

    @property
    def devices(self) -> int:
        return self.clusters * self.heads


def make_serving_mesh(clusters: int = 1, heads: int = 1) -> ClusterMesh:
    """Build the ``("cluster", "head")`` serving mesh.

    Works on CPU via forced virtual devices::

        XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...
    """
    n = len(jax.devices())
    if clusters * heads > n:
        raise ValueError(
            f"mesh {clusters}x{heads} needs {clusters * heads} devices, "
            f"only {n} visible (on CPU, relaunch with {HOST_DEVICE_RECIPE}; "
            f"XLA_FLAGS now: {os.environ.get('XLA_FLAGS', '<unset>')!r})")
    mesh = jax.make_mesh((clusters, heads), ClusterMesh.AXIS_NAMES)
    return ClusterMesh(mesh=mesh, clusters=clusters, heads=heads)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (possibly virtual) devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (the roofline target)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_LINK_BW = 50e9             # bytes/s per link
