"""Serving launcher: the paged continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
        --requests 8 --max-new 8 [--kernel]

Runs the smoke-sized model (this container is CPU); the engine itself —
RAB translation, paged pool, continuous batching, tracing — is the
production control path, and the decode math is the `serve`-profile
sharding proven by the decode_32k dry-run cells.
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.analysis import layer1_decode, layer2_tlb_transactions
from repro.models import model as M
from repro.runtime import PagedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--pages", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--kernel", action="store_true",
                    help="Pallas paged-attention (interpret on CPU)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: n-gram drafter proposes up "
                         "to K tokens per lane per iteration, verified in "
                         "one chunked step (0 disables)")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = PagedServer(cfg, params, num_pages=args.pages,
                      page_size=args.page_size, max_lanes=args.lanes,
                      max_pages_per_seq=16, use_kernel=args.kernel,
                      spec_k=args.spec_k)
    for rid in range(args.requests):
        srv.submit(Request(rid=rid, prompt=[rid + 1, 3, 5],
                           max_new=args.max_new))
    done = srv.run()
    for r in done:
        print(f"req {r.rid}: {r.prompt} -> {r.out}")
    print("RAB:", srv.rab.stats)
    if args.spec_k:
        gen = sum(len(r.out) for r in done)
        print(f"spec: proposed={srv.spec_proposed} "
              f"accepted={srv.spec_accepted} rejected={srv.spec_rejected} "
              f"iters/token={srv.iterations / max(gen, 1):.2f}")
    events = layer1_decode(srv.tracer.drain())
    print(f"{len(events)} trace events; "
          f"{len(layer2_tlb_transactions(events))} TLB transactions")


if __name__ == "__main__":
    main()
