"""Serving launcher: the paged continuous-batching engine behind the
unified generation API.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
        --requests 8 --max-new 8 [--kernel] [--temperature 0.8 --top-p 0.9 \
        --top-k 40 --seed 7 --stop 13 --stop 17]

Runs the smoke-sized model (this container is CPU); the engine itself —
RAB translation, paged pool, continuous batching, on-device sampling,
tracing — is the production control path, and the decode math is the
`serve`-profile sharding proven by the decode_32k dry-run cells.
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.analysis import layer1_decode, layer2_tlb_transactions
from repro.models import model as M
from repro.runtime import (
    CacheConfig, EngineConfig, GenerationRequest, SamplingParams,
    make_engine,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--pages", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--kernel", action="store_true",
                    help="Pallas paged-attention (interpret on CPU)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: n-gram drafter proposes up "
                         "to K tokens per lane per iteration, verified in "
                         "one chunked step (0 disables; greedy lanes only)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax; > 0 samples on device with a "
                         "per-request PRNG key folded by position")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 disables)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus truncation mass (1.0 disables)")
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed (request i uses "
                         "seed + i so lanes differ but stay reproducible)")
    ap.add_argument("--stop", type=int, action="append", default=None,
                    help="stop token id; repeatable — any of them ends a "
                         "request with finish_reason='stop'")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine_cfg = EngineConfig(
        cache=CacheConfig(num_pages=args.pages,
                          page_size=args.page_size,
                          max_pages_per_seq=16),
        max_lanes=args.lanes, use_kernel=args.kernel,
        spec_k=args.spec_k)
    srv = make_engine(cfg, params, engine_cfg)
    requests = [
        GenerationRequest(
            rid=rid, prompt=(rid + 1, 3, 5),
            sampling=SamplingParams(
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, seed=args.seed + rid,
                stop_tokens=tuple(args.stop or ()), max_new=args.max_new))
        for rid in range(args.requests)
    ]
    for _ in srv.generate(requests):
        pass                        # drain the stream; results accumulate
    for r in srv.finished:
        print(f"req {r.rid}: {list(r.prompt)} -> {list(r.tokens)} "
              f"[{r.finish_reason}]")
    print("RAB:", srv.rab.stats)
    if args.spec_k:
        gen = sum(len(r.tokens) for r in srv.finished)
        print(f"spec: proposed={srv.spec_proposed} "
              f"accepted={srv.spec_accepted} rejected={srv.spec_rejected} "
              f"iters/token={srv.iterations / max(gen, 1):.2f}")
    events = layer1_decode(srv.tracer.drain())
    print(f"{len(events)} trace events; "
          f"{len(layer2_tlb_transactions(events))} TLB transactions")


if __name__ == "__main__":
    main()
