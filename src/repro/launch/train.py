"""Training launcher.

Local (CPU-sim) execution with the full production loop:
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke --steps 20

Cluster posture: on a real fleet this same entrypoint runs per host under
`jax.distributed.initialize()` (flags below); data is sharded per host by
(host_id, num_hosts); the dry-run path (`--dryrun`) AOT-compiles the step
for the production mesh instead of executing.
"""
import argparse
import tempfile

from repro.configs import get_config, SHAPES, smoke_shape
from repro.data import MarkovChainData, SyntheticLMData
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny batch (CPU-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--profile", default="megatron",
                    choices=["megatron", "fsdp", "serve"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--data", choices=["markov", "uniform"], default="markov")
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--num-hosts", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        shape = smoke_shape("train")
    else:
        shape = SHAPES[args.shape]
        assert shape.kind == "train", "use serve.py for inference shapes"

    data_cls = MarkovChainData if args.data == "markov" else SyntheticLMData
    data = data_cls(cfg, shape, seed=0, num_hosts=args.num_hosts,
                    host_id=args.host_id)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix=f"{args.arch}_ckpt_")
    trainer = Trainer(
        cfg, shape, data,
        TrainerConfig(total_steps=args.steps,
                      ckpt_every=max(args.steps // 5, 5),
                      ckpt_dir=ckpt, log_every=max(args.steps // 20, 1)),
        opt_cfg=AdamWConfig(warmup_steps=min(100, args.steps // 3 or 1),
                            total_steps=args.steps),
        compress=args.compress_grads)
    res = trainer.run_with_recovery()
    for m in res["metrics"]:
        print(f"step {m['step']:6d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
              f"{m['step_s']*1e3:.0f} ms")
    print(f"done: {res['final_step']} steps, {res['restarts']} restarts, "
          f"{len(res['stragglers'])} straggler flags; checkpoints: {ckpt}")


if __name__ == "__main__":
    main()
