"""Attention: GQA (full-seq chunked + decode) and MLA (deepseek-v2).

Full-sequence attention is computed as an exact scan over query chunks so
that (q_chunk, T) score tiles — never (S, T) — are materialized.  This is the
XLA-level analogue of the flash kernel (``repro/kernels/flash_attention``
provides the Pallas version for the TPU target; both agree with the same
oracle).

Masks are never materialized globally: they are built inside each chunk from
position iotas, so sliding-window / causal / bidirectional variants are pure
elementwise fusions.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ParamFactory, shard, current_mesh
from repro.models.layers import rope, rms_head_norm, softcap

NEG_INF = -1e30


def _tp_size() -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    return mesh.shape.get("model", 1)


def kv_cache_axes(cfg: ArchConfig) -> Tuple[Optional[str], ...]:
    """(B, T, kv, hd) cache sharding: heads-TP if divisible, else seq."""
    tp = _tp_size()
    if cfg.num_kv_heads % tp == 0:
        return ("dp", None, "tp", None)
    return ("dp", "sp", None, None)


# ---------------------------------------------------------------------------
# Parameter builders
# ---------------------------------------------------------------------------

def build_gqa(f: ParamFactory, cfg: ArchConfig, name: str = "attn"):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    with f.scope(name):
        p = {
            "wq": f("wq", (d, H, hd), ("fsdp", "tp", None)),
            "wk": f("wk", (d, K, hd), ("fsdp", "tp", None)),
            "wv": f("wv", (d, K, hd), ("fsdp", "tp", None)),
            "wo": f("wo", (H, hd, d), ("tp", None, "fsdp"), fan_in=H * hd),
        }
        if cfg.use_qk_norm:
            p["q_norm"] = f("q_norm", (hd,), (None,), init="ones", dtype=jnp.float32)
            p["k_norm"] = f("k_norm", (hd,), (None,), init="ones", dtype=jnp.float32)
        return p


def build_cross_attn(f: ParamFactory, cfg: ArchConfig, name: str = "xattn"):
    return build_gqa(f, cfg, name)


def build_mla(f: ParamFactory, cfg: ArchConfig, name: str = "attn"):
    d, H = cfg.d_model, cfg.num_heads
    r_kv, r_q = cfg.mla_kv_lora_rank, cfg.mla_q_lora_rank
    dn, dr, dv = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_head_dim
    with f.scope(name):
        return {
            "w_dq": f("w_dq", (d, r_q), ("fsdp", None)),
            "q_norm": f("q_norm", (r_q,), (None,), init="ones", dtype=jnp.float32),
            "w_uq": f("w_uq", (r_q, H, dn + dr), (None, "tp", None), fan_in=r_q),
            "w_dkv": f("w_dkv", (d, r_kv + dr), ("fsdp", None)),
            "kv_norm": f("kv_norm", (r_kv,), (None,), init="ones", dtype=jnp.float32),
            "w_uk": f("w_uk", (r_kv, H, dn), (None, "tp", None), fan_in=r_kv),
            "w_uv": f("w_uv", (r_kv, H, dv), (None, "tp", None), fan_in=r_kv),
            "wo": f("wo", (H, dv, d), ("tp", None, "fsdp"), fan_in=H * dv),
        }


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def attend_fullseq(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   q_positions: jax.Array, k_positions: jax.Array,
                   causal: bool, window: int = 0, cap: float = 0.0,
                   chunk: int = 512, scale: Optional[float] = None) -> jax.Array:
    """Exact chunked attention.

    q: (B,S,H,hd), k/v: (B,T,K,hd), GQA via H = K*G.
    q_positions: (S,), k_positions: (T,).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    # Unrolled query chunking (max 8 chunks): bounds the live (c, T) score
    # tile without a lax.scan, whose stacked/transposed xs resist GSPMD
    # partitioning (involuntary remat).  Static slices partition cleanly.
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    if n > 8:
        n = max(i for i in range(1, 9) if S % i == 0)
        c = S // n

    def one_chunk(qc, qpos, kk, vv, kpos):
        # qc: (B,c,K,G,hd); qpos: (c,); kk/vv: (B,t,K,hd); kpos: (t,)
        s = jnp.einsum("bckgh,btkh->bckgt", qc, kk,
                       preferred_element_type=jnp.float32) * sc
        s = softcap(s, cap)
        mask = jnp.ones((qc.shape[1], kk.shape[1]), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bckgt,btkh->bckgh", pr, vv)

    qg = q.reshape(B, S, K, G, hd)
    # sliding-window + causal (self-attention) layers only ever see keys in
    # (qpos - window, qpos]: statically slice the K/V band per query chunk
    # instead of masking the full T (perf iteration 4 — cuts local-layer
    # attention FLOPs from S*T to ~S*(window+c))
    banded = bool(window) and causal and q_positions.shape[0] == T and S == T
    outs = []
    for i in range(n):
        lo, hi = 0, T
        if banded:
            lo = max(0, i * c - window + 1)
            hi = min(T, i * c + c)
        outs.append(one_chunk(qg[:, i * c:(i + 1) * c],
                              q_positions[i * c:(i + 1) * c],
                              k[:, lo:hi], v[:, lo:hi], k_positions[lo:hi]))
    out = outs[0] if n == 1 else jnp.concatenate(outs, axis=1)
    return out.reshape(B, S, H, v.shape[-1])


def attend_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  lengths: jax.Array, k_positions: jax.Array,
                  window: int = 0, cap: float = 0.0,
                  scale: Optional[float] = None) -> jax.Array:
    """One-token decode attention against a (ring or linear) cache.

    q: (B,1,H,hd); k/v: (B,T,K,hd); lengths: (B,) current position (the new
    token's position); k_positions: (B,T) absolute position stored per slot
    (rings make slot != position).
    """
    B, _, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k,
                   preferred_element_type=jnp.float32) * sc
    s = softcap(s, cap)
    mask = k_positions <= lengths[:, None]                      # (B,T)
    if window:
        mask &= (lengths[:, None] - k_positions) < window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", pr, v)
    return out.reshape(B, 1, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA block-level forward
# ---------------------------------------------------------------------------

def gqa_fullseq(cfg: ArchConfig, p, x: jax.Array, positions: jax.Array, *,
                window: int = 0, causal: bool = True,
                kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence GQA (train / prefill / encoder / cross-attention)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        kpos = positions
    else:
        k, v = kv_override
        kpos = kv_positions
    if cfg.use_qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps) if kv_override is None else k
    if cfg.use_rope and kv_override is None:
        q = rope(q, positions[None, :], cfg.rope_theta)
        k = rope(k, kpos[None, :], cfg.rope_theta)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    out = attend_fullseq(q, k, v, q_positions=positions, k_positions=kpos,
                         causal=causal, window=window, cap=cfg.attn_softcap)
    # pin the concat output to the head-TP layout so its backward split does
    # not force GSPMD into involuntary full rematerialization
    out = shard(out, "dp", None, "tp", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def gqa_make_kv(cfg: ArchConfig, p, x: jax.Array, positions: jax.Array,
                apply_rope: bool = True):
    """K/V for cross-attention caches (encoder side)."""
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_rope and apply_rope:
        k = rope(k, positions[None, :], cfg.rope_theta)
    return k, v


def gqa_decode(cfg: ArchConfig, p, x: jax.Array, pos: jax.Array,
               k_cache: jax.Array, v_cache: jax.Array, slot: jax.Array,
               k_positions: jax.Array, *, window: int = 0,
               update_cache: bool = True):
    """One-token GQA decode.

    x: (B,1,d); pos: (B,) absolute positions; slot: (B,) cache slot to write
    (== pos for linear caches, pos % W for ring caches); k_positions: (B,T)
    absolute position per slot, already updated for this token by the caller
    (positions are shared across layers and updated once per step).
    Returns (out, k_cache, v_cache).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)

    if update_cache:
        def upd(cache_b, new_b, s):
            return jax.lax.dynamic_update_slice(cache_b, new_b, (s, 0, 0))
        k_cache = jax.vmap(upd)(k_cache, k, slot)
        v_cache = jax.vmap(upd)(v_cache, v, slot)
    k_cache = shard(k_cache, *kv_cache_axes(cfg))
    v_cache = shard(v_cache, *kv_cache_axes(cfg))
    out = attend_decode(q, k_cache, v_cache, lengths=pos,
                        k_positions=k_positions, window=window,
                        cap=cfg.attn_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------

def _mla_q(cfg: ArchConfig, p, x, positions):
    dn, dr = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
    cq = rms_head_norm(p["q_norm"], cq, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])       # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions if positions.ndim == 2 else positions[None, :],
                  cfg.rope_theta)
    return q_nope, q_rope


def mla_compress_kv(cfg: ArchConfig, p, x, positions):
    """(B,S,r_kv) normed compressed KV + (B,S,dr) roped shared key."""
    r_kv, dr = cfg.mla_kv_lora_rank, cfg.mla_qk_rope_dim
    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])       # (B,S,r_kv+dr)
    c, k_rope = ckv[..., :r_kv], ckv[..., r_kv:]
    c = rms_head_norm(p["kv_norm"], c, cfg.norm_eps)
    k_rope = rope(k_rope[..., None, :],
                  positions if positions.ndim == 2 else positions[None, :],
                  cfg.rope_theta)[..., 0, :]
    return c, k_rope


def mla_fullseq(cfg: ArchConfig, p, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Training/prefill MLA: decompress per-head K/V (heads are TP-sharded)."""
    B, S, _ = x.shape
    dn, dv = cfg.mla_qk_nope_dim, cfg.mla_v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c, k_rope = mla_compress_kv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["w_uk"])    # (B,S,H,dn)
    v = jnp.einsum("bsr,rhk->bshk", c, p["w_uv"])         # (B,S,H,dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, cfg.num_heads, k_rope.shape[-1]))],
                        axis=-1)
    q = shard(q, "dp", None, "tp", None)
    k = shard(k, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)
    scale = 1.0 / math.sqrt(dn + cfg.mla_qk_rope_dim)
    out = attend_fullseq(q, k, v, q_positions=positions, k_positions=positions,
                         causal=True, chunk=512, scale=scale)
    out = shard(out, "dp", None, "tp", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_decode(cfg: ArchConfig, p, x: jax.Array, pos: jax.Array,
               c_cache: jax.Array, rope_cache: jax.Array, slot: jax.Array,
               k_positions: jax.Array):
    """Absorbed-matrix MLA decode against the compressed cache.

    c_cache: (B,T,r_kv); rope_cache: (B,T,dr).  Scores are computed directly
    in compressed space: q_c = q_nope @ W_uk  (absorb), ctx_c = probs @ c,
    v = ctx_c @ W_uv.  This is the deepseek-v2 serving formulation — the KV
    cache is 576 B/token instead of 2*H*128.
    """
    dn = cfg.mla_qk_nope_dim
    q_nope, q_rope = _mla_q(cfg, p, x, pos[:, None])      # (B,1,H,*)
    c_new, kr_new = mla_compress_kv(cfg, p, x, pos[:, None])

    def upd2(cache_b, new_b, s):
        return jax.lax.dynamic_update_slice(cache_b, new_b, (s, 0))
    c_cache = jax.vmap(upd2)(c_cache, c_new, slot)
    rope_cache = jax.vmap(upd2)(rope_cache, kr_new, slot)
    c_cache = shard(c_cache, "dp", "sp", None)
    rope_cache = shard(rope_cache, "dp", "sp", None)

    q_c = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"])  # absorbed (B,1,H,r_kv)
    s_c = jnp.einsum("bshr,btr->bhst", q_c, c_cache,
                     preferred_element_type=jnp.float32)
    s_r = jnp.einsum("bshr,btr->bhst", q_rope, rope_cache,
                     preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(dn + cfg.mla_qk_rope_dim)
    s = (s_c + s_r) * scale
    mask = (k_positions <= pos[:, None])[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(c_cache.dtype)
    ctx_c = jnp.einsum("bhst,btr->bshr", pr, c_cache)      # (B,1,H,r_kv)
    out = jnp.einsum("bshr,rhv->bshv", ctx_c, p["w_uv"])   # (B,1,H,dv)
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return out, c_cache, rope_cache
