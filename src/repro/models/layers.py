"""Shared model primitives: norms, RoPE, MLPs, embeddings, chunked loss."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ParamFactory, shard


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def build_norm(f: ParamFactory, cfg: ArchConfig, name: str, dim: int):
    with f.scope(name):
        p = {"scale": f("scale", (dim,), (None,), init="ones", dtype=jnp.float32)}
        if cfg.norm_eps and cfg.mlp_kind == "gelu" and cfg.block_kind == "encdec":
            # whisper uses LayerNorm (with bias)
            p["bias"] = f("bias", (dim,), (None,), init="zeros", dtype=jnp.float32)
        return p


def norm_forward(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # LayerNorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"] + p["bias"]
    else:  # RMSNorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return out.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """Per-head RMSNorm over the trailing head_dim (qwen3/olmoe qk-norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, rotate-half convention.

    x: (..., S, H, hd) with matching positions (..., S) broadcastable.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def build_mlp(f: ParamFactory, cfg: ArchConfig, name: str, d: int, ff: int):
    with f.scope(name):
        p = {}
        if cfg.mlp_kind in ("swiglu", "geglu"):
            p["w_gate"] = f("w_gate", (d, ff), ("fsdp", "tp"))
            p["w_up"] = f("w_up", (d, ff), ("fsdp", "tp"))
        else:  # gelu (ungated)
            p["w_up"] = f("w_up", (d, ff), ("fsdp", "tp"))
        p["w_down"] = f("w_down", (ff, d), ("tp", "fsdp"), fan_in=ff)
        return p


def mlp_forward(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if cfg.mlp_kind in ("swiglu", "geglu"):
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        act = jax.nn.silu(gate) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "dp", None, "tp")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy
# ---------------------------------------------------------------------------

def build_embedding(f: ParamFactory, cfg: ArchConfig):
    p = {"table": f("table", (cfg.vocab_size, cfg.d_model), ("tp", "fsdp"),
                    fan_in=cfg.d_model)}
    if not cfg.tie_embeddings:
        p["head"] = f("head", (cfg.vocab_size, cfg.d_model), ("tp", "fsdp"),
                      fan_in=cfg.d_model)
    return p


def embed_tokens(cfg: ArchConfig, p, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    # residual stream is sequence-parallel between blocks (Megatron-SP style)
    return shard(x, "dp", "sp", None)


def head_matrix(cfg: ArchConfig, p) -> jax.Array:
    return p["table"] if cfg.tie_embeddings else p["head"]


def logits_from_hidden(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    w = head_matrix(cfg, p)
    logits = jnp.einsum("...d,vd->...v", x, w,
                        preferred_element_type=jnp.float32)
    return softcap(logits, cfg.final_softcap)


def chunked_xent(cfg: ArchConfig, p, hidden: jax.Array, labels: jax.Array,
                 chunk: Optional[int] = None) -> jax.Array:
    """Cross-entropy without materializing full (B,S,V) logits.

    Scans over sequence chunks; with remat-of-dots the backward recomputes
    each chunk's logits, keeping peak memory at O(B*chunk*V / shards).
    """
    B, S, D = hidden.shape
    c = min(chunk or cfg.loss_chunk, S)
    while S % c:
        c -= 1
    n = S // c
    if n > 16:  # cap unroll; larger chunks are fine, V/tp is the live dim
        n = max(i for i in range(1, 17) if S % i == 0)
        c = S // n
    w = head_matrix(cfg, p)

    @jax.checkpoint
    def body(h, lab):
        logits = jnp.einsum("bcd,vd->bcv", h, w,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, cfg.final_softcap)
        logits = shard(logits, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)              # (B,c)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        correct = jnp.sum(jnp.where(iota == lab[..., None], logits, 0.0),
                          axis=-1)                            # (B,c)
        return jnp.sum(lse - correct)

    total = jnp.zeros((), jnp.float32)
    for i in range(n):
        total = total + body(hidden[:, i * c:(i + 1) * c],
                             labels[:, i * c:(i + 1) * c])
    return total / (B * S)
