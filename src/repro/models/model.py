"""Unified model assembly for all assigned architectures.

One parameter-building function (interpreted for init / shape-spec / axes by
``ParamFactory``), one full-sequence forward (train / prefill), and one
single-token decode forward (with per-family caches).

Layer loops are unrolled in Python (each layer indexes a stacked parameter
tree).  This keeps `compiled.cost_analysis()` and collective-byte parsing
exact — while-loop bodies would be counted once — at the cost of larger HLO,
which is acceptable at <=64 layers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ParamFactory, shard, tree_pspecs
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as S

# "slot not written" marker for position caches.  Must be a large POSITIVE
# value: masks keep slots with kpos <= current position, so an empty slot
# must compare greater than any real position (a negative sentinel would
# silently attend to zero-valued K/V rows).
EMPTY_POS = 2 ** 30


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ===========================================================================
# Parameter building
# ===========================================================================

def _build_tf_layer(f: ParamFactory, cfg: ArchConfig, use_moe: bool):
    d = cfg.d_model
    lp: Dict[str, Any] = {"ln1": L.build_norm(f, cfg, "ln1", d)}
    if cfg.attention_kind == "mla":
        lp["attn"] = A.build_mla(f, cfg)
    else:
        lp["attn"] = A.build_gqa(f, cfg)
    lp["ln2"] = L.build_norm(f, cfg, "ln2", d)
    if use_moe:
        lp["moe"] = MOE.build_moe(f, cfg)
    else:
        lp["mlp"] = L.build_mlp(f, cfg, "mlp", d, cfg.d_ff)
    if cfg.post_block_norm:
        lp["pln1"] = L.build_norm(f, cfg, "pln1", d)
        lp["pln2"] = L.build_norm(f, cfg, "pln2", d)
    return lp


def _build_hymba_layer(f: ParamFactory, cfg: ArchConfig):
    d = cfg.d_model
    return {
        "ln1": L.build_norm(f, cfg, "ln1", d),
        "attn": A.build_gqa(f, cfg),
        "mamba": S.build_mamba(f, cfg),
        "bn_attn": L.build_norm(f, cfg, "bn_attn", d),
        "bn_ssm": L.build_norm(f, cfg, "bn_ssm", d),
        "ln2": L.build_norm(f, cfg, "ln2", d),
        "mlp": L.build_mlp(f, cfg, "mlp", d, cfg.d_ff),
    }


def _build_encdec(f: ParamFactory, cfg: ArchConfig):
    d = cfg.d_model
    p: Dict[str, Any] = {}
    with f.scope("enc"):
        with f.stacked(cfg.encoder_layers):
            p["enc_layers"] = {
                "ln1": L.build_norm(f, cfg, "ln1", d),
                "attn": A.build_gqa(f, cfg),
                "ln2": L.build_norm(f, cfg, "ln2", d),
                "mlp": L.build_mlp(f, cfg, "mlp", d, cfg.d_ff),
            }
        p["enc_norm"] = L.build_norm(f, cfg, "enc_norm", d)
        p["enc_pos"] = f("enc_pos", (cfg.frontend_seq, d), (None, "fsdp"))
    with f.scope("dec"):
        with f.stacked(cfg.num_layers):
            p["dec_layers"] = {
                "ln1": L.build_norm(f, cfg, "ln1", d),
                "attn": A.build_gqa(f, cfg),
                "lnx": L.build_norm(f, cfg, "lnx", d),
                "xattn": A.build_cross_attn(f, cfg),
                "ln2": L.build_norm(f, cfg, "ln2", d),
                "mlp": L.build_mlp(f, cfg, "mlp", d, cfg.d_ff),
            }
        p["dec_pos"] = f("dec_pos", (cfg.max_positions, d), (None, "fsdp"))
    return p


def build_params(f: ParamFactory, cfg: ArchConfig):
    p: Dict[str, Any] = {"embed": L.build_embedding(f, cfg)}
    if cfg.block_kind == "encdec":
        p.update(_build_encdec(f, cfg))
    elif cfg.block_kind == "mlstm":
        n_s = -(-cfg.num_layers // cfg.slstm_every) if cfg.slstm_every else 0
        n_m = cfg.num_layers - n_s
        with f.scope("mlstm"):
            with f.stacked(n_m):
                p["mlstm_layers"] = {
                    "ln1": L.build_norm(f, cfg, "ln1", cfg.d_model),
                    "cell": S.build_mlstm(f, cfg),
                }
        if n_s:
            with f.scope("slstm"):
                with f.stacked(n_s):
                    p["slstm_layers"] = {
                        "ln1": L.build_norm(f, cfg, "ln1", cfg.d_model),
                        "cell": S.build_slstm(f, cfg),
                    }
    elif cfg.block_kind == "hymba":
        with f.scope("layers"):
            with f.stacked(cfg.num_layers):
                p["layers"] = _build_hymba_layer(f, cfg)
    else:  # transformer (dense / moe / vlm)
        n_dense_first = cfg.moe_first_dense_layers if cfg.moe_num_experts else 0
        n_main = cfg.num_layers - n_dense_first
        use_moe = bool(cfg.moe_num_experts)
        if n_dense_first:
            with f.scope("first_layers"):
                with f.stacked(n_dense_first):
                    p["first_layers"] = _build_tf_layer(f, cfg, use_moe=False)
        with f.scope("layers"):
            with f.stacked(n_main):
                p["layers"] = _build_tf_layer(f, cfg, use_moe=use_moe)
    p["final_norm"] = L.build_norm(f, cfg, "final_norm", cfg.d_model)
    return p


def init_params(cfg: ArchConfig, rng: jax.Array):
    f = ParamFactory("init", _dtype(cfg), rng)
    return build_params(f, cfg)


def param_specs(cfg: ArchConfig):
    return build_params(ParamFactory("spec", _dtype(cfg)), cfg)


def param_axes(cfg: ArchConfig):
    return build_params(ParamFactory("axes", _dtype(cfg)), cfg)


def param_pspecs(cfg: ArchConfig, mesh):
    return tree_pspecs(param_specs(cfg), param_axes(cfg), mesh)


# ===========================================================================
# Decode caches
# ===========================================================================

def build_cache(f: ParamFactory, cfg: ArchConfig, B: int, T: int):
    """Cache tree for one-token decode with context length T."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    c: Dict[str, Any] = {}
    if cfg.block_kind == "mlstm":
        n_s = -(-cfg.num_layers // cfg.slstm_every) if cfg.slstm_every else 0
        n_m = cfg.num_layers - n_s
        with f.scope("mlstm_state"):
            with f.stacked(n_m):
                c["mlstm"] = {
                    k: f(k, shape, ax, init="zeros", dtype=dtype)
                    for k, (shape, dtype, ax) in S.mlstm_state_specs(cfg, B).items()
                }
        if n_s:
            with f.scope("slstm_state"):
                with f.stacked(n_s):
                    c["slstm"] = {
                        k: f(k, shape, ax, init="zeros", dtype=dtype)
                        for k, (shape, dtype, ax) in S.slstm_state_specs(cfg, B).items()
                    }
        return c

    if cfg.block_kind == "hymba":
        W = min(cfg.sliding_window, T) if cfg.sliding_window else T
        with f.scope("attn_cache"):
            with f.stacked(cfg.num_layers):
                c["k"] = f("k", (B, W, kv, hd), ("dp", None, None, None), init="zeros")
                c["v"] = f("v", (B, W, kv, hd), ("dp", None, None, None), init="zeros")
        c["kpos"] = f("kpos", (B, W), ("dp", None), init="fill", fill=EMPTY_POS,
                      dtype=jnp.int32)
        with f.scope("mamba_state"):
            with f.stacked(cfg.num_layers):
                c["mamba"] = {
                    k: f(k, shape, ax, init="zeros", dtype=dtype)
                    for k, (shape, dtype, ax) in S.mamba_state_specs(cfg, B).items()
                }
        return c

    if cfg.block_kind == "encdec":
        F = cfg.frontend_seq
        with f.scope("self_cache"):
            with f.stacked(cfg.num_layers):
                c["k"] = f("k", (B, T, kv, hd), ("dp", None, "tp", None), init="zeros")
                c["v"] = f("v", (B, T, kv, hd), ("dp", None, "tp", None), init="zeros")
        with f.scope("cross_cache"):
            with f.stacked(cfg.num_layers):
                c["xk"] = f("xk", (B, F, kv, hd), ("dp", None, "tp", None), init="zeros")
                c["xv"] = f("xv", (B, F, kv, hd), ("dp", None, "tp", None), init="zeros")
        c["kpos"] = f("kpos", (B, T), ("dp", None), init="fill", fill=EMPTY_POS,
                      dtype=jnp.int32)
        return c

    if cfg.attention_kind == "mla":
        r, dr = cfg.mla_kv_lora_rank, cfg.mla_qk_rope_dim
        with f.scope("mla_cache"):
            with f.stacked(cfg.num_layers):
                c["c"] = f("c", (B, T, r), ("dp", "sp", None), init="zeros")
                c["rope"] = f("rope", (B, T, dr), ("dp", "sp", None), init="zeros")
        c["kpos"] = f("kpos", (B, T), ("dp", None), init="fill", fill=EMPTY_POS,
                      dtype=jnp.int32)
        return c

    # plain GQA transformer; gemma2 splits local(ring W) / global(linear T)
    if cfg.local_global_period:
        n_local = (cfg.num_layers + 1) // cfg.local_global_period
        n_global = cfg.num_layers - n_local
        W = min(cfg.sliding_window, T)
        with f.scope("local_cache"):
            with f.stacked(n_local):
                c["k_local"] = f("k", (B, W, kv, hd), A.kv_cache_axes(cfg), init="zeros")
                c["v_local"] = f("v", (B, W, kv, hd), A.kv_cache_axes(cfg), init="zeros")
        with f.scope("global_cache"):
            with f.stacked(n_global):
                c["k_global"] = f("k", (B, T, kv, hd), A.kv_cache_axes(cfg), init="zeros")
                c["v_global"] = f("v", (B, T, kv, hd), A.kv_cache_axes(cfg), init="zeros")
        c["kpos_local"] = f("kpos_local", (B, W), ("dp", None), init="fill",
                            fill=EMPTY_POS, dtype=jnp.int32)
        c["kpos"] = f("kpos", (B, T), ("dp", None), init="fill",
                      fill=EMPTY_POS, dtype=jnp.int32)
        return c

    with f.scope("kv_cache"):
        with f.stacked(cfg.num_layers):
            c["k"] = f("k", (B, T, kv, hd), A.kv_cache_axes(cfg), init="zeros")
            c["v"] = f("v", (B, T, kv, hd), A.kv_cache_axes(cfg), init="zeros")
    c["kpos"] = f("kpos", (B, T), ("dp", None), init="fill", fill=EMPTY_POS,
                  dtype=jnp.int32)
    return c


def init_cache(cfg: ArchConfig, B: int, T: int):
    return build_cache(ParamFactory("init", _dtype(cfg)), cfg, B, T)


def cache_specs(cfg: ArchConfig, B: int, T: int):
    return build_cache(ParamFactory("spec", _dtype(cfg)), cfg, B, T)


def cache_axes(cfg: ArchConfig, B: int, T: int):
    return build_cache(ParamFactory("axes", _dtype(cfg)), cfg, B, T)


# ===========================================================================
# Full-sequence forward (train / prefill)
# ===========================================================================

def _sub(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _remat(cfg: ArchConfig, fn):
    """Per-layer activation checkpointing (policy from config)."""
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _stack_apply(cfg: ArchConfig, stacked, x, body, n: int):
    """Apply `body(layer_params, x) -> x` over a homogeneous layer stack.

    cfg.scan_layers=True uses lax.scan (one HLO body; compile time ~n x
    smaller — the HLO cost accounting multiplies loop bodies by their trip
    count, see launch/hlo_stats.py).  Otherwise a Python unrolled loop.
    """
    body = _remat(cfg, body)
    if n == 0:
        return x
    if not cfg.scan_layers or n == 1:
        for i in range(n):
            x = body(_sub(stacked, i), x)
        return x

    def scan_body(carry, lp):
        return body(lp, carry), None

    x, _ = jax.lax.scan(scan_body, x, stacked)
    return x


def _tf_block(cfg: ArchConfig, lp, x, positions, *, window: int,
              use_moe: bool):
    # block entry: one explicit seq all-gather (Megatron-SP pattern); the
    # residual itself stays sequence-sharded between blocks
    h = shard(L.norm_forward(cfg, lp["ln1"], x), "dp", None, None)
    if cfg.attention_kind == "mla":
        a = A.mla_fullseq(cfg, lp["attn"], h, positions)
    else:
        a = A.gqa_fullseq(cfg, lp["attn"], h, positions, window=window)
    if cfg.post_block_norm:
        a = L.norm_forward(cfg, lp["pln1"], a)
    x = x + a
    h = shard(L.norm_forward(cfg, lp["ln2"], x), "dp", None, None)
    if use_moe:
        m = MOE.moe_forward(cfg, lp["moe"], h)
    else:
        m = L.mlp_forward(cfg, lp["mlp"], h)
    if cfg.post_block_norm:
        m = L.norm_forward(cfg, lp["pln2"], m)
    x = x + m
    return shard(x, "dp", "sp", None)


def _layer_window(cfg: ArchConfig, i: int) -> int:
    if cfg.local_global_period:
        return cfg.sliding_window if i % cfg.local_global_period == 0 else 0
    if cfg.block_kind == "hymba":
        return cfg.sliding_window
    return cfg.sliding_window or 0


def _hymba_block(cfg: ArchConfig, lp, x, positions):
    h = shard(L.norm_forward(cfg, lp["ln1"], x), "dp", None, None)
    a = A.gqa_fullseq(cfg, lp["attn"], h, positions,
                      window=cfg.sliding_window)
    m = S.mamba_fullseq(cfg, lp["mamba"], h)
    fused = 0.5 * (L.norm_forward(cfg, lp["bn_attn"], a) +
                   L.norm_forward(cfg, lp["bn_ssm"], m))
    x = x + fused
    h = shard(L.norm_forward(cfg, lp["ln2"], x), "dp", None, None)
    x = x + L.mlp_forward(cfg, lp["mlp"], h)
    return shard(x, "dp", "sp", None)


def forward_fullseq(cfg: ArchConfig, params, tokens: jax.Array,
                    frontend: Optional[jax.Array] = None) -> jax.Array:
    """Returns final hidden states (B,S,d)."""
    B, Sq = tokens.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)
    x = L.embed_tokens(cfg, params["embed"], tokens)

    if cfg.frontend == "patch" and frontend is not None:
        Fs = frontend.shape[1]
        x = jnp.concatenate([frontend.astype(x.dtype), x[:, Fs:, :]], axis=1)

    if cfg.block_kind == "encdec":
        enc = frontend.astype(x.dtype) + params["enc_pos"][None]
        enc_pos = jnp.arange(cfg.frontend_seq, dtype=jnp.int32)

        def enc_block(lp, enc):
            h = shard(L.norm_forward(cfg, lp["ln1"], enc), "dp", None, None)
            a = A.gqa_fullseq(cfg, lp["attn"], h, enc_pos, causal=False)
            enc = enc + a
            h = L.norm_forward(cfg, lp["ln2"], enc)
            return enc + L.mlp_forward(cfg, lp["mlp"], h)

        def dec_block(lp, x, enc):
            h = shard(L.norm_forward(cfg, lp["ln1"], x), "dp", None, None)
            x = x + A.gqa_fullseq(cfg, lp["attn"], h, positions)
            h = shard(L.norm_forward(cfg, lp["lnx"], x), "dp", None, None)
            xk, xv = A.gqa_make_kv(cfg, lp["xattn"], enc, enc_pos)
            x = x + A.gqa_fullseq(cfg, lp["xattn"], h, positions, causal=False,
                                  kv_override=(xk, xv), kv_positions=enc_pos)
            h = shard(L.norm_forward(cfg, lp["ln2"], x), "dp", None, None)
            x = x + L.mlp_forward(cfg, lp["mlp"], h)
            return shard(x, "dp", "sp", None)

        enc = _stack_apply(cfg, params["enc_layers"], enc, enc_block,
                           cfg.encoder_layers)
        enc = L.norm_forward(cfg, params["enc_norm"], enc)
        x = x + params["dec_pos"][None, :Sq, :]
        x = _stack_apply(cfg, params["dec_layers"], x,
                         lambda lp, x: dec_block(lp, x, enc), cfg.num_layers)
    elif cfg.block_kind == "mlstm":
        def m_block(lp, x):
            h = shard(L.norm_forward(cfg, lp["ln1"], x), "dp", None, None)
            return shard(x + S.mlstm_fullseq(cfg, lp["cell"], h),
                         "dp", "sp", None)

        def s_block(lp, x):
            h = shard(L.norm_forward(cfg, lp["ln1"], x), "dp", None, None)
            return shard(x + S.slstm_fullseq(cfg, lp["cell"], h),
                         "dp", "sp", None)

        # grouped stacks: one sLSTM heads each group of (slstm_every) layers
        n_s = -(-cfg.num_layers // cfg.slstm_every) if cfg.slstm_every else 0
        if n_s == 0:
            x = _stack_apply(cfg, params["mlstm_layers"], x, m_block,
                             cfg.num_layers)
        else:
            per = cfg.slstm_every - 1
            s_block_r = _remat(cfg, s_block)
            for g in range(n_s):
                x = s_block_r(_sub(params["slstm_layers"], g), x)
                lo = g * per
                hi = min(lo + per, cfg.num_layers - n_s)
                grp = jax.tree.map(lambda t: t[lo:hi], params["mlstm_layers"])
                x = _stack_apply(cfg, grp, x, m_block, hi - lo)
    elif cfg.block_kind == "hymba":
        x = _stack_apply(cfg, params["layers"], x,
                         lambda lp, x: _hymba_block(cfg, lp, x, positions),
                         cfg.num_layers)
    else:
        n_first = cfg.moe_first_dense_layers if cfg.moe_num_experts else 0
        n_main = cfg.num_layers - n_first
        use_moe = bool(cfg.moe_num_experts)

        def mk_block(window, moe):
            return lambda lp, x: _tf_block(
                cfg, lp, x, positions, window=window, use_moe=moe)

        if n_first:
            x = _stack_apply(cfg, params["first_layers"], x,
                             mk_block(_layer_window(cfg, 0), False), n_first)
        if cfg.local_global_period:
            # scan over [local, global] pairs: reshape stacks (L,..)->(L/p,p,..)
            p_ = cfg.local_global_period
            pairs = jax.tree.map(
                lambda t: t.reshape((n_main // p_, p_) + t.shape[1:]),
                params["layers"])

            def pair_block(lp, x):
                for j in range(p_):
                    x = _tf_block(cfg, _sub(lp, j), x, positions,
                                  window=_layer_window(cfg, j), use_moe=use_moe)
                return x

            x = _stack_apply(cfg, pairs, x, pair_block, n_main // p_)
        else:
            x = _stack_apply(cfg, params["layers"], x,
                             mk_block(_layer_window(cfg, n_first), use_moe),
                             n_main)

    return L.norm_forward(cfg, params["final_norm"], x)


def loss_fn(cfg: ArchConfig, params, batch: Dict[str, jax.Array]) -> jax.Array:
    hidden = forward_fullseq(cfg, params, batch["tokens"],
                             frontend=batch.get("frontend"))
    # gather the sequence-parallel residual once; the chunked loss then keeps
    # only (B, chunk, V/tp) logits alive
    hidden = shard(hidden, "dp", None, None)
    return L.chunked_xent(cfg, params["embed"], hidden, batch["labels"])


def encode_frontend(cfg: ArchConfig, params, frontend: jax.Array) -> jax.Array:
    """Run the (stub-fed) encoder once; returns encoder hidden states."""
    assert cfg.block_kind == "encdec"
    enc = frontend.astype(_dtype(cfg)) + params["enc_pos"][None]
    enc_pos = jnp.arange(cfg.frontend_seq, dtype=jnp.int32)

    def enc_block(lp, enc):
        h = shard(L.norm_forward(cfg, lp["ln1"], enc), "dp", None, None)
        a = A.gqa_fullseq(cfg, lp["attn"], h, enc_pos, causal=False)
        enc = enc + a
        h = L.norm_forward(cfg, lp["ln2"], enc)
        return enc + L.mlp_forward(cfg, lp["mlp"], h)

    enc = _stack_apply(cfg, params["enc_layers"], enc, enc_block,
                       cfg.encoder_layers)
    return L.norm_forward(cfg, params["enc_norm"], enc)


def encdec_cross_cache(cfg: ArchConfig, params, frontend: jax.Array):
    """(xk, xv) stacked (L,B,F,kv,hd) for the decode cache, from one encode."""
    enc = encode_frontend(cfg, params, frontend)
    enc_pos = jnp.arange(cfg.frontend_seq, dtype=jnp.int32)
    xks, xvs = [], []
    for i in range(cfg.num_layers):
        lp = _sub(params["dec_layers"], i)
        xk, xv = A.gqa_make_kv(cfg, lp["xattn"], enc, enc_pos)
        xks.append(xk)
        xvs.append(xv)
    return jnp.stack(xks), jnp.stack(xvs)


def prefill_logits(cfg: ArchConfig, params, batch) -> jax.Array:
    hidden = forward_fullseq(cfg, params, batch["tokens"],
                             frontend=batch.get("frontend"))
    return L.logits_from_hidden(cfg, params["embed"], hidden[:, -1:, :])


# ===========================================================================
# Decode forward
# ===========================================================================

def decode_forward(cfg: ArchConfig, params, cache, tokens: jax.Array,
                   pos: jax.Array,
                   inputs_embeds: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Any]:
    """One decode step.  tokens: (B,1) int32; pos: (B,) positions of the new
    token.  ``inputs_embeds`` (B,1,d) overrides the token embedding (VLM
    patch positions during prefill-by-decode).  Returns (logits, cache)."""
    B = tokens.shape[0]
    if inputs_embeds is not None:
        x = inputs_embeds.astype(_dtype(cfg))
    else:
        x = L.embed_tokens(cfg, params["embed"], tokens)
    cache = dict(cache)

    def upd_pos(kp, slot):
        return jax.vmap(lambda kpb, s, pv: jax.lax.dynamic_update_slice(
            kpb, pv[None], (s,)))(kp, slot, pos)

    if cfg.block_kind == "mlstm":
        im, isl = 0, 0
        m_state = dict(cache["mlstm"])
        s_state = dict(cache.get("slstm", {}))
        for i in range(cfg.num_layers):
            if cfg.slstm_every and i % cfg.slstm_every == 0:
                lp = _sub(params["slstm_layers"], isl)
                h = L.norm_forward(cfg, lp["ln1"], x)
                out, new = S.slstm_decode(cfg, lp["cell"], h, _sub(s_state, isl))
                s_state = {k: s_state[k].at[isl].set(new[k]) for k in s_state}
                x = x + out
                isl += 1
            else:
                lp = _sub(params["mlstm_layers"], im)
                h = L.norm_forward(cfg, lp["ln1"], x)
                out, new = S.mlstm_decode(cfg, lp["cell"], h, _sub(m_state, im))
                m_state = {k: m_state[k].at[im].set(new[k]) for k in m_state}
                x = x + out
                im += 1
        cache["mlstm"] = m_state
        if s_state:
            cache["slstm"] = s_state

    elif cfg.block_kind == "hymba":
        W = cache["k"].shape[2]
        slot = pos % W
        kpos = upd_pos(cache["kpos"], slot)
        cache["kpos"] = kpos
        k_all, v_all = cache["k"], cache["v"]
        mamba_state = dict(cache["mamba"])
        for i in range(cfg.num_layers):
            lp = _sub(params["layers"], i)
            h = L.norm_forward(cfg, lp["ln1"], x)
            a, k_new, v_new = A.gqa_decode(
                cfg, lp["attn"], h, pos, k_all[i], v_all[i], slot, kpos,
                window=cfg.sliding_window)
            k_all = k_all.at[i].set(k_new)
            v_all = v_all.at[i].set(v_new)
            m_out, new_ms = S.mamba_decode(cfg, lp["mamba"], h,
                                           _sub(mamba_state, i))
            mamba_state = {k: mamba_state[k].at[i].set(new_ms[k])
                           for k in mamba_state}
            fused = 0.5 * (L.norm_forward(cfg, lp["bn_attn"], a) +
                           L.norm_forward(cfg, lp["bn_ssm"], m_out))
            x = x + fused
            h = L.norm_forward(cfg, lp["ln2"], x)
            x = x + L.mlp_forward(cfg, lp["mlp"], h)
        cache["k"], cache["v"], cache["mamba"] = k_all, v_all, mamba_state

    elif cfg.block_kind == "encdec":
        slot = pos
        kpos = upd_pos(cache["kpos"], slot)
        cache["kpos"] = kpos
        x = x + jnp.take(params["dec_pos"], pos, axis=0)[:, None, :]
        F = cache["xk"].shape[2]
        xpos = jnp.arange(F, dtype=jnp.int32)
        xk_positions = jnp.broadcast_to(xpos[None], (B, F))
        full_len = jnp.full((B,), F - 1, jnp.int32)
        k_all, v_all = cache["k"], cache["v"]
        for i in range(cfg.num_layers):
            lp = _sub(params["dec_layers"], i)
            h = L.norm_forward(cfg, lp["ln1"], x)
            a, k_new, v_new = A.gqa_decode(cfg, lp["attn"], h, pos,
                                           k_all[i], v_all[i], slot, kpos)
            k_all = k_all.at[i].set(k_new)
            v_all = v_all.at[i].set(v_new)
            x = x + a
            h = L.norm_forward(cfg, lp["lnx"], x)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"])
            xa = A.attend_decode(q, cache["xk"][i], cache["xv"][i],
                                 lengths=full_len, k_positions=xk_positions)
            x = x + jnp.einsum("bshk,hkd->bsd", xa, lp["xattn"]["wo"])
            h = L.norm_forward(cfg, lp["ln2"], x)
            x = x + L.mlp_forward(cfg, lp["mlp"], h)
        cache["k"], cache["v"] = k_all, v_all

    elif cfg.attention_kind == "mla":
        slot = pos
        kpos = upd_pos(cache["kpos"], slot)
        cache["kpos"] = kpos
        c_all, r_all = cache["c"], cache["rope"]
        n_first = cfg.moe_first_dense_layers
        for i in range(cfg.num_layers):
            lp = (_sub(params["first_layers"], i) if i < n_first
                  else _sub(params["layers"], i - n_first))
            h = L.norm_forward(cfg, lp["ln1"], x)
            a, c_new, r_new = A.mla_decode(cfg, lp["attn"], h, pos,
                                           c_all[i], r_all[i], slot, kpos)
            c_all = c_all.at[i].set(c_new)
            r_all = r_all.at[i].set(r_new)
            if cfg.post_block_norm:
                a = L.norm_forward(cfg, lp["pln1"], a)
            x = x + a
            h = L.norm_forward(cfg, lp["ln2"], x)
            if "moe" in lp:
                m = MOE.moe_forward(cfg, lp["moe"], h, dropless=True)
            else:
                m = L.mlp_forward(cfg, lp["mlp"], h)
            x = x + m
        cache["c"], cache["rope"] = c_all, r_all

    elif cfg.local_global_period:
        W = cache["k_local"].shape[2]
        slot_local = pos % W
        slot_global = pos
        cache["kpos_local"] = upd_pos(cache["kpos_local"], slot_local)
        cache["kpos"] = upd_pos(cache["kpos"], slot_global)
        kl, vl = cache["k_local"], cache["v_local"]
        kg, vg = cache["k_global"], cache["v_global"]
        il = ig = 0
        for i in range(cfg.num_layers):
            lp = _sub(params["layers"], i)
            h = L.norm_forward(cfg, lp["ln1"], x)
            local = i % cfg.local_global_period == 0
            if local:
                a, k_new, v_new = A.gqa_decode(
                    cfg, lp["attn"], h, pos, kl[il], vl[il], slot_local,
                    cache["kpos_local"], window=cfg.sliding_window)
                kl, vl = kl.at[il].set(k_new), vl.at[il].set(v_new)
                il += 1
            else:
                a, k_new, v_new = A.gqa_decode(
                    cfg, lp["attn"], h, pos, kg[ig], vg[ig], slot_global,
                    cache["kpos"])
                kg, vg = kg.at[ig].set(k_new), vg.at[ig].set(v_new)
                ig += 1
            if cfg.post_block_norm:
                a = L.norm_forward(cfg, lp["pln1"], a)
            x = x + a
            h = L.norm_forward(cfg, lp["ln2"], x)
            m = L.mlp_forward(cfg, lp["mlp"], h)
            if cfg.post_block_norm:
                m = L.norm_forward(cfg, lp["pln2"], m)
            x = x + m
        cache["k_local"], cache["v_local"] = kl, vl
        cache["k_global"], cache["v_global"] = kg, vg

    else:  # plain GQA transformer (incl. MoE without MLA: olmoe)
        slot = pos
        kpos = upd_pos(cache["kpos"], slot)
        cache["kpos"] = kpos
        k_all, v_all = cache["k"], cache["v"]
        for i in range(cfg.num_layers):
            lp = _sub(params["layers"], i)
            h = L.norm_forward(cfg, lp["ln1"], x)
            a, k_new, v_new = A.gqa_decode(cfg, lp["attn"], h, pos,
                                           k_all[i], v_all[i], slot, kpos,
                                           window=_layer_window(cfg, i))
            k_all = k_all.at[i].set(k_new)
            v_all = v_all.at[i].set(v_new)
            x = x + a
            h = L.norm_forward(cfg, lp["ln2"], x)
            if "moe" in lp:
                m = MOE.moe_forward(cfg, lp["moe"], h, dropless=True)
            else:
                m = L.mlp_forward(cfg, lp["mlp"], h)
            x = x + m
        cache["k"], cache["v"] = k_all, v_all

    x = L.norm_forward(cfg, params["final_norm"], x)
    logits = L.logits_from_hidden(cfg, params["embed"], x)
    return logits, cache
