"""Capacity-based routed MoE, expert-parallel over `model`, with
*group-local dispatch* (perf iteration 3, EXPERIMENTS.md §Perf).

Dispatch is sort-based, but the sort/scatter bookkeeping runs independently
per data-parallel shard group: tokens are reshaped (T,) -> (G, T/G) with G =
the mesh's dp degree, so the argsort, run-start search and position
computation stay *local* to each shard (GSPMD keeps per-group ops on the
shard that owns the group).  The only cross-device movement left is the
token payload exchange into the expert-sharded (G, E, C, d) buffer — the
canonical MoE all-to-all — instead of a distributed global sort (the
baseline's dominant collective cost: a global argsort over T*k elements plus
repeated (T*k, d) resharding).

FLOPs scale with E*C ~= T*top_k*capacity_factor — the routed compute —
keeping MODEL_FLOPS/HLO_FLOPs honest.  Overflow tokens (per-expert,
per-group load > C) drop, the standard capacity trade-off; ``dropless=True``
(decode) sizes C for the worst case instead.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import (
    ParamFactory, current_mesh, current_profile, PROFILES, shard,
)
from repro.models.layers import build_mlp, mlp_forward


def _capacity(cfg: ArchConfig, tokens: int) -> int:
    c = int(tokens * cfg.moe_top_k * cfg.moe_capacity_factor /
            cfg.moe_num_experts)
    c = max(c, 8)
    return -(-c // 8) * 8  # round up to 8


def _dp_groups(T: int) -> int:
    """Dispatch group count = the mesh's data-parallel degree.

    Grouping only pays off at prefill/train token counts; at decode scale
    the (G, E*C, d) scatter buffer costs more than a tiny global sort
    (measured: ds-v2 decode 196 GiB grouped vs 23 GiB simple)."""
    mesh = current_mesh()
    if mesh is None or T < 4096:
        return 1
    prof = PROFILES[current_profile()]
    g = 1
    for a in prof["dp"]:
        g *= mesh.shape.get(a, 1)
    if g <= 1 or T % g or (T // g) < 8:
        return 1
    return g


def build_moe(f: ParamFactory, cfg: ArchConfig, name: str = "moe"):
    d, E, ff = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    with f.scope(name):
        p = {
            "router": f("router", (d, E), (None, None), dtype=jnp.float32),
            "w_gate": f("w_gate", (E, d, ff), ("ep", "fsdp", None)),
            "w_up": f("w_up", (E, d, ff), ("ep", "fsdp", None)),
            "w_down": f("w_down", (E, ff, d), ("ep", None, "fsdp"), fan_in=ff),
        }
        if cfg.moe_shared_experts:
            with f.scope("shared"):
                p["shared"] = build_mlp(
                    f, cfg, "mlp", d, ff * cfg.moe_shared_experts)
        return p


def moe_forward(cfg: ArchConfig, p, x: jax.Array,
                capacity: Optional[int] = None,
                dropless: bool = False) -> jax.Array:
    """x: (B,S,d) -> (B,S,d).

    dropless=True sizes capacity for the worst case (every token on one
    expert) — the decode path; training/prefill use the capacity factor.

    With a mesh whose expert-parallel degree divides E, dispatch runs under
    ``shard_map``: routing/sort/scatter are shard-local by construction and
    the only cross-device traffic is one explicit all-to-all pair (perf
    iteration 3b — GSPMD-level constraints could not stop the partitioner
    from distributing the sort; see EXPERIMENTS.md §Perf)."""
    out = _moe_shardmap(cfg, p, x, capacity, dropless)
    if out is not None:
        if cfg.moe_shared_experts:
            out = out + mlp_forward(cfg, p["shared"], x)
        return out
    return _moe_gspmd(cfg, p, x, capacity, dropless)


def _dispatch_local(cfg, router, xf, C, dropless):
    """Sort-based local dispatch.  xf: (T,d) -> buf (E,C,d) + combine meta."""
    T, d = xf.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    N = T * k
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    gate_vals, expert_idx = jax.lax.top_k(logits, k)
    probs = jax.nn.softmax(gate_vals, axis=-1)

    flat_expert = expert_idx.reshape(N)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    starts = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(N) - starts[sorted_expert]
    valid = pos_in_e < C
    dest = jnp.where(valid, sorted_expert * C + pos_in_e, E * C)
    gathered = xf[order // k]
    buf = jnp.zeros((E * C + 1, d), xf.dtype).at[dest].set(
        gathered, mode="drop", unique_indices=True)[:E * C]
    return buf.reshape(E, C, d), (order, dest, valid, probs)


def _combine_local(y, meta, T, k, d):
    """Inverse of _dispatch_local.  y: (E,C,d) -> (T,d)."""
    order, dest, valid, probs = meta
    E_C = y.shape[0] * y.shape[1]
    y_flat = jnp.concatenate([y.reshape(E_C, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    y_slots = y_flat[jnp.minimum(dest, E_C)] * valid[:, None].astype(y.dtype)
    unsorted = jnp.zeros((T * k, d), y.dtype).at[order].set(y_slots)
    return jnp.einsum("tkd,tk->td", unsorted.reshape(T, k, d),
                      probs.astype(y.dtype))


def _moe_shardmap(cfg: ArchConfig, p, x: jax.Array, capacity, dropless
                  ) -> Optional[jax.Array]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    if mesh is None:
        return None
    prof = PROFILES[current_profile()]
    ep_axes = tuple(a for a in prof["ep"] if mesh.shape.get(a, 1) > 1)
    if len(ep_axes) != 1:
        return None
    ep = ep_axes[0]
    ntp = mesh.shape[ep]
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    B, S, d = x.shape
    if E % ntp or ntp <= 1:
        return None
    dp_axes = tuple(a for a in prof["dp"]
                    if mesh.shape.get(a, 1) > 1 and a != ep)
    ndp = 1
    for a in dp_axes:
        ndp *= mesh.shape[a]
    if B % ndp:
        return None
    T_loc = (B // ndp) * S
    if T_loc < E:
        # decode-sized token counts: a2a capacity padding (E*C slots for
        # T_loc*k assignments) would dominate the wire — the local/GSPMD
        # path is strictly cheaper (perf iteration 3c, refuted-then-guarded)
        return None
    if dropless:
        C = -(-T_loc * k // 8) * 8
    else:
        C = capacity or _capacity(cfg, T_loc)

    def body(xl, router, wg, wu, wd):
        # xl: (B_loc, S, d); wg/wu/wd: (E_loc, d, f)/(E_loc, f, d) — E sharded
        Bl = xl.shape[0]
        xf = xl.reshape(Bl * S, d)
        buf, meta = _dispatch_local(cfg, router, xf, C, dropless)
        # token payload exchange: (E,C,d) -> (E/ntp, C*ntp, d)
        buf = jax.lax.all_to_all(buf, ep, split_axis=0, concat_axis=1,
                                 tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)
        # reverse exchange back to the token owners
        y = jax.lax.all_to_all(y, ep, split_axis=1, concat_axis=0, tiled=True)
        out = _combine_local(y, meta, Bl * S, k, d)
        return out.reshape(Bl, S, d)

    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P(ep, None, None), P(ep, None, None), P(ep, None, None)),
        out_specs=P(dp_spec, None, None),
        check_rep=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_gspmd(cfg: ArchConfig, p, x: jax.Array,
               capacity: Optional[int] = None,
               dropless: bool = False) -> jax.Array:
    """GSPMD fallback (no usable ep axis): group-local dispatch."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    G = _dp_groups(T)
    Tg = T // G
    if dropless:
        C = -(-Tg * k // 8) * 8
    else:
        C = capacity or _capacity(cfg, Tg)
    N = Tg * k

    xg = shard(x.reshape(G, Tg, d), "dp", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    gate_vals, expert_idx = jax.lax.top_k(logits, k)            # (G,Tg,k)
    probs = jax.nn.softmax(gate_vals, axis=-1)

    flat_expert = expert_idx.reshape(G, N)
    order = jnp.argsort(flat_expert, axis=1)                    # group-local
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    starts = jax.vmap(lambda s: jnp.searchsorted(s, jnp.arange(E),
                                                 side="left"))(sorted_expert)
    pos_in_e = jnp.arange(N)[None, :] - \
        jnp.take_along_axis(starts, sorted_expert, axis=1)
    valid = pos_in_e < C
    # overflow -> out-of-bounds destination, dropped by the scatter
    dest = jnp.where(valid, sorted_expert * C + pos_in_e, E * C)

    tok_of_slot = order // k                                    # (G,N)
    gathered = jnp.take_along_axis(
        xg, tok_of_slot[..., None], axis=1)                     # (G,N,d)

    g_off = (jnp.arange(G) * (E * C + 1))[:, None]
    buf_flat = jnp.zeros((G * (E * C + 1), d), xg.dtype).at[
        (dest + g_off).reshape(-1)].set(
        gathered.reshape(-1, d), mode="drop", unique_indices=True)
    buf = buf_flat.reshape(G, E * C + 1, d)[:, :E * C, :].reshape(G, E, C, d)
    buf = shard(buf, "dp", "ep", None, None)                    # the MoE a2a

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = shard(h, "dp", "ep", None, None)
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = shard(y, "dp", "ep", None, None)

    y_flat = jnp.concatenate(
        [y.reshape(G, E * C, d),
         jnp.zeros((G, 1, d), y.dtype)], axis=1)                # OOB row
    y_slots = jnp.take_along_axis(
        y_flat, jnp.minimum(dest, E * C)[..., None], axis=1)    # (G,N,d)
    y_slots = y_slots * valid[..., None].astype(y.dtype)

    unsorted = jnp.zeros((G, N, d), y.dtype).at[
        jnp.arange(G)[:, None], order].set(y_slots)
    combined = jnp.einsum("gtkd,gtk->gtd",
                          unsorted.reshape(G, Tg, k, d),
                          probs.astype(y.dtype))
    out = shard(combined, "dp", None, None).reshape(B, S, d)

    if cfg.moe_shared_experts:
        out = out + mlp_forward(cfg, p["shared"], x)
    return out


def router_load(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    """Expert load histogram (for balance metrics / tests)."""
    T = x.shape[0] * x.shape[1]
    logits = jnp.einsum("td,de->te",
                        x.reshape(T, -1).astype(jnp.float32), p["router"])
    _, idx = jax.lax.top_k(logits, cfg.moe_top_k)
    return jnp.bincount(idx.reshape(-1), length=cfg.moe_num_experts)
