"""Recurrent blocks: mLSTM / sLSTM (xLSTM) and Mamba selective SSM (hymba).

Training-time mLSTM uses the *chunkwise-parallel* formulation (intra-chunk
quadratic form + inter-chunk recurrent state), the standard way to make
matrix-memory RNNs MXU-friendly: within a chunk it is an attention-like
einsum with a decay mask; across chunks a (B,H,hd,hd) state is carried.
Correctness is pinned against the per-token recurrence in tests.

All state math runs in fp32 (exp-gating is numerically fragile in bf16).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ParamFactory
from repro.models.layers import rms_head_norm

NEG_INF = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================

def build_mlstm(f: ParamFactory, cfg: ArchConfig, name: str = "mlstm"):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    with f.scope(name):
        return {
            "wq": f("wq", (d, H, hd), ("fsdp", "tp", None)),
            "wk": f("wk", (d, H, hd), ("fsdp", "tp", None)),
            "wv": f("wv", (d, H, hd), ("fsdp", "tp", None)),
            "w_if": f("w_if", (d, 2 * H), ("fsdp", None), dtype=jnp.float32),
            "b_if": f("b_if", (2 * H,), (None,), init="zeros", dtype=jnp.float32),
            "w_og": f("w_og", (d, d), ("fsdp", "tp")),
            "head_norm": f("head_norm", (H, hd), ("tp", None), init="ones",
                           dtype=jnp.float32),
            "w_out": f("w_out", (d, d), ("tp", "fsdp")),
        }


def mlstm_state_specs(cfg: ArchConfig, B: int):
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    return {
        "C": ((B, H, hd, hd), jnp.float32, ("dp", "tp", None, None)),
        "n": ((B, H, hd), jnp.float32, ("dp", "tp", None)),
        "m": ((B, H), jnp.float32, ("dp", "tp")),
    }


def _mlstm_gates(p, x):
    """(B,S,H) log input gate, log forget gate (sigmoid-gated, stable)."""
    raw = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["w_if"]) + p["b_if"]
    H = raw.shape[-1] // 2
    log_i = raw[..., :H]                         # exp input gate: log i = raw
    log_f = -jax.nn.softplus(-raw[..., H:])      # log sigmoid(f_raw)
    return log_i, log_f


def mlstm_fullseq(cfg: ArchConfig, p, x: jax.Array, chunk: int = 256) -> jax.Array:
    """Chunkwise-parallel mLSTM over the full sequence.  x: (B,S,d)."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(jnp.float32) / math.sqrt(hd)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, x)

    c = min(chunk, S)
    while S % c:
        c -= 1
    n_chunks = S // c

    def to_chunks(t):  # (B,S,...) -> (n,B,c,...)
        return t.reshape((B, n_chunks, c) + t.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    lis, lfs = to_chunks(log_i), to_chunks(log_f)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), 0.0, jnp.float32)

    def body(carry, xs):
        C_prev, n_prev, m_prev = carry
        qc, kc, vc, li, lf = xs                 # (B,c,H,*) / (B,c,H)
        F = jnp.cumsum(lf, axis=1)              # inclusive log-decay  (B,c,H)
        b = F + m_prev[:, None, :]              # inter-chunk decay    (B,c,H)
        # intra-chunk decay matrix D[t,s] = F_t - F_s + li_s  (s <= t)
        D = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(tri[None, :, :, None], D, NEG_INF)
        m_t = jnp.maximum(jnp.max(D, axis=2), b)        # (B,c,H)
        m_t = jax.lax.stop_gradient(m_t)
        dec = jnp.exp(D - m_t[:, :, None, :])           # (B,c,c,H)
        scores = jnp.einsum("bthk,bshk->btsh", qc, kc) * dec
        intra = jnp.einsum("btsh,bshk->bthk", scores, vc)
        inter_w = jnp.exp(b - m_t)                      # (B,c,H)
        inter = jnp.einsum("bthk,bhkj->bthj", qc, C_prev) * inter_w[..., None]
        num = intra + inter
        nvec = jnp.einsum("btsh,bshk->bthk", dec, kc)
        nvec = nvec + n_prev[:, None, :, :] * inter_w[..., None]
        denom = jnp.maximum(jnp.abs(jnp.einsum("bthk,bthk->bth", qc, nvec)),
                            jnp.exp(-m_t))
        h = num / denom[..., None]                      # (B,c,H,hd)

        # chunk-final state
        F_tot = F[:, -1, :]                             # (B,H)
        m_new = jnp.maximum(F_tot + m_prev,
                            jnp.max(F_tot[:, None, :] - F + li, axis=1))
        m_new = jax.lax.stop_gradient(m_new)
        w_old = jnp.exp(F_tot + m_prev - m_new)         # (B,H)
        w_s = jnp.exp(F_tot[:, None, :] - F + li - m_new[:, None, :])  # (B,c,H)
        C_new = C_prev * w_old[..., None, None] + \
            jnp.einsum("bsh,bshk,bshj->bhkj", w_s, kc, vc)
        n_new = n_prev * w_old[..., None] + jnp.einsum("bsh,bshk->bhk", w_s, kc)
        return (C_new, n_new, m_new), h

    (_, _, _), hs = jax.lax.scan(body, (C0, n0, m0), (qs, ks, vs, lis, lfs))
    h = hs.swapaxes(0, 1).reshape(B, S, H, hd)          # (B,S,H,hd)
    h = rms_head_norm(p["head_norm"], h, cfg.norm_eps)
    h = h.reshape(B, S, d).astype(x.dtype)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_og"]))
    return jnp.einsum("bsd,de->bse", h * og, p["w_out"])


def mlstm_decode(cfg: ArchConfig, p, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    """Single-token recurrent mLSTM.  x: (B,1,d)."""
    B, _, d = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0].astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])[:, 0].astype(jnp.float32) / math.sqrt(hd)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])[:, 0].astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, x)
    li, lf = log_i[:, 0], log_f[:, 0]                   # (B,H)

    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    w_old = jnp.exp(lf + m - m_new)
    w_in = jnp.exp(li - m_new)
    C = C * w_old[..., None, None] + \
        jnp.einsum("bhk,bhj->bhkj", k * w_in[..., None], v)
    n = n * w_old[..., None] + k * w_in[..., None]
    num = jnp.einsum("bhk,bhkj->bhj", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                        jnp.exp(-m_new))
    h = num / denom[..., None]                          # (B,H,hd)
    h = rms_head_norm(p["head_norm"], h, cfg.norm_eps)
    h = h.reshape(B, 1, d).astype(x.dtype)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_og"]))
    out = jnp.einsum("bsd,de->bse", h * og, p["w_out"])
    return out, {"C": C, "n": n, "m": m_new}


def mlstm_reference(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    """Per-token oracle for tests (slow lax.scan over S)."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    state = {
        "C": jnp.zeros((B, H, hd, hd), jnp.float32),
        "n": jnp.zeros((B, H, hd), jnp.float32),
        "m": jnp.zeros((B, H), jnp.float32),
    }

    def body(st, xt):
        out, st = mlstm_decode(cfg, p, xt[:, None, :], st)
        return st, out[:, 0]

    _, ys = jax.lax.scan(body, state, x.swapaxes(0, 1))
    return ys.swapaxes(0, 1)


# ===========================================================================
# sLSTM
# ===========================================================================

def build_slstm(f: ParamFactory, cfg: ArchConfig, name: str = "slstm"):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    with f.scope(name):
        return {
            "w_in": f("w_in", (d, 4, H, hd), ("fsdp", None, "tp", None)),
            "r": f("r", (4, H, hd, hd), (None, "tp", None, None), fan_in=hd),
            "b": f("b", (4, H, hd), (None, "tp", None), init="zeros",
                   dtype=jnp.float32),
            "head_norm": f("head_norm", (H, hd), ("tp", None), init="ones",
                           dtype=jnp.float32),
            "w_out": f("w_out", (d, d), ("tp", "fsdp")),
        }


def slstm_state_specs(cfg: ArchConfig, B: int):
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    return {
        "c": ((B, H, hd), jnp.float32, ("dp", "tp", None)),
        "n": ((B, H, hd), jnp.float32, ("dp", "tp", None)),
        "m": ((B, H, hd), jnp.float32, ("dp", "tp", None)),
        "h": ((B, H, hd), jnp.float32, ("dp", "tp", None)),
    }


def _slstm_step(cfg, p, xt_proj, state):
    """xt_proj: (B,4,H,hd) pre-computed x W_in.  Recurrent R on h."""
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]
    rec = jnp.einsum("bhk,ghkj->bghj", h, p["r"].astype(jnp.float32))
    g = xt_proj.astype(jnp.float32) + rec + p["b"]       # (B,4,H,hd)
    z = jnp.tanh(g[:, 0])
    i_raw, f_raw = g[:, 1], g[:, 2]
    o = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(f_raw + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_raw + m - m_new)
    c_new = f_g * c + i_g * z
    n_new = jnp.maximum(f_g * n + i_g, 1e-6)
    h_new = o * (c_new / n_new)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_fullseq(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    xp = jnp.einsum("bsd,dghk->bsghk", x, p["w_in"])     # (B,S,4,H,hd)
    state = {k: jnp.zeros(s, dt) for k, (s, dt, _) in
             slstm_state_specs(cfg, B).items()}

    def body(st, xt):
        st = _slstm_step(cfg, p, xt, st)
        return st, st["h"]

    _, hs = jax.lax.scan(body, state, xp.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                                # (B,S,H,hd)
    h = rms_head_norm(p["head_norm"], h, cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", h.reshape(B, S, d).astype(x.dtype),
                      p["w_out"])


def slstm_decode(cfg: ArchConfig, p, x: jax.Array, state: dict):
    B, _, d = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    xp = jnp.einsum("bsd,dghk->bsghk", x, p["w_in"])[:, 0]
    state = _slstm_step(cfg, p, xp, state)
    h = rms_head_norm(p["head_norm"], state["h"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h.reshape(B, 1, d).astype(x.dtype),
                     p["w_out"])
    return out, state


# ===========================================================================
# Mamba selective SSM (hymba branch)
# ===========================================================================

def mamba_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, -(-cfg.d_model // 16))
    return d_inner, dt_rank, cfg.ssm_state


def build_mamba(f: ParamFactory, cfg: ArchConfig, name: str = "mamba"):
    d = cfg.d_model
    di, dtr, N = mamba_dims(cfg)
    with f.scope(name):
        return {
            "w_in": f("w_in", (d, 2 * di), ("fsdp", "tp")),
            "conv_w": f("conv_w", (cfg.ssm_conv_width, di), (None, "tp")),
            "conv_b": f("conv_b", (di,), ("tp",), init="zeros"),
            "w_dt_down": f("w_dt_down", (di, dtr), ("tp", None)),
            "w_dt_up": f("w_dt_up", (dtr, di), (None, "tp"), fan_in=dtr),
            "b_dt": f("b_dt", (di,), ("tp",), init="ones", dtype=jnp.float32),
            "w_B": f("w_B", (di, N), ("tp", None)),
            "w_C": f("w_C", (di, N), ("tp", None)),
            "log_A": f("log_A", (di, N), ("tp", None), init="zeros",
                       dtype=jnp.float32),
            "D": f("D", (di,), ("tp",), init="ones", dtype=jnp.float32),
            "w_out": f("w_out", (di, d), ("tp", "fsdp"), fan_in=di),
        }


def mamba_state_specs(cfg: ArchConfig, B: int):
    di, _, N = mamba_dims(cfg)
    return {
        "conv": ((B, cfg.ssm_conv_width - 1, di), jnp.float32, ("dp", None, "tp")),
        "ssm": ((B, di, N), jnp.float32, ("dp", "tp", None)),
    }


def _mamba_inner(cfg, p, xz, conv_in):
    """Shared projections. xz: (B,S,2*di); conv_in: (B, S+w-1, di) padded."""
    di, _, N = mamba_dims(cfg)
    xpart, z = xz[..., :di], xz[..., di:]
    w = p["conv_w"].astype(jnp.float32)                  # (w, di)
    width = cfg.ssm_conv_width
    conv = sum(conv_in[:, j:j + xpart.shape[1], :].astype(jnp.float32) * w[j]
               for j in range(width))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dr->bsr", conv, p["w_dt_down"].astype(jnp.float32))
        @ p["w_dt_up"].astype(jnp.float32) + p["b_dt"])   # (B,S,di)
    Bp = jnp.einsum("bsd,dn->bsn", conv, p["w_B"].astype(jnp.float32))
    Cp = jnp.einsum("bsd,dn->bsn", conv, p["w_C"].astype(jnp.float32))
    return xpart, z, conv, dt, Bp, Cp


def mamba_fullseq(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    di, _, N = mamba_dims(cfg)
    A = -jnp.exp(p["log_A"])                             # (di,N), negative
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xpart = xz[..., :di]
    pad = jnp.zeros((B, cfg.ssm_conv_width - 1, di), xpart.dtype)
    conv_in = jnp.concatenate([pad, xpart], axis=1)
    xpart, z, conv, dt, Bp, Cp = _mamba_inner(cfg, p, xz, conv_in)

    def body(h, xs):
        dt_t, u_t, B_t, C_t = xs                          # (B,di),(B,di),(B,N),(B,N)
        a = jnp.exp(dt_t[..., None] * A[None])            # (B,di,N)
        h = a * h + (dt_t * u_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    _, ys = jax.lax.scan(
        body, h0, (dt.swapaxes(0, 1), conv.swapaxes(0, 1),
                   Bp.swapaxes(0, 1), Cp.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + conv * p["D"]                 # (B,S,di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bsd,de->bse", y.astype(x.dtype), p["w_out"])


def mamba_decode(cfg: ArchConfig, p, x: jax.Array, state: dict):
    B, _, d = x.shape
    di, _, N = mamba_dims(cfg)
    A = -jnp.exp(p["log_A"])
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])          # (B,1,2di)
    xpart = xz[..., :di]
    conv_in = jnp.concatenate([state["conv"].astype(xpart.dtype), xpart], axis=1)
    xpart, z, conv, dt, Bp, Cp = _mamba_inner(cfg, p, xz, conv_in)
    new_conv = conv_in[:, 1:, :].astype(jnp.float32)

    dt_t, u_t, B_t, C_t = dt[:, 0], conv[:, 0], Bp[:, 0], Cp[:, 0]
    a = jnp.exp(dt_t[..., None] * A[None])
    h = a * state["ssm"] + (dt_t * u_t)[..., None] * B_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_t) + u_t * p["D"]
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = jnp.einsum("bd,de->be", y.astype(x.dtype), p["w_out"])[:, None, :]
    return out, {"conv": new_conv, "ssm": h}
