"""Step builders: train / prefill / decode, with input specs + shardings.

These are the units the launcher jits and the dry-run AOT-compiles:

    train_step(state, batch)        -> (state, metrics)
    prefill_step(params, batch)     -> logits (B,1,V)
    decode_step(params, cache, batch) -> (logits, cache)

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation), per the dry-run
contract.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compress import ef_compress_grads, init_residual
from repro.parallel.sharding import (
    Axes, logical_pspec, mesh_context, sharding_profile,
)


def default_opt_cfg(cfg: ArchConfig) -> AdamWConfig:
    # huge models skip the fp32 master copy to fit HBM (see optim/adamw.py)
    big = cfg.name in ("deepseek-v2-236b", "qwen3-32b", "pixtral-12b",
                       "minitron-8b")
    return AdamWConfig(use_master=not big)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs; the dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    d = jnp.dtype(cfg.param_dtype)
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.frontend:
            out["frontend"] = jax.ShapeDtypeStruct((B, cfg.frontend_seq, cfg.d_model), d)
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.frontend:
            out["frontend"] = jax.ShapeDtypeStruct((B, cfg.frontend_seq, cfg.d_model), d)
        return out
    # decode: one new token against a cache of S
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def input_axes(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Axes]:
    if shape.kind == "train":
        out = {"tokens": Axes(("dp", None)), "labels": Axes(("dp", None))}
        if cfg.frontend:
            out["frontend"] = Axes(("dp", None, None))
        return out
    if shape.kind == "prefill":
        out = {"tokens": Axes(("dp", None))}
        if cfg.frontend:
            out["frontend"] = Axes(("dp", None, None))
        return out
    return {"tokens": Axes(("dp", None)), "pos": Axes(("dp",))}


def make_batch(cfg: ArchConfig, shape: ShapeSpec, rng: jax.Array):
    """Concrete synthetic batch matching input_specs (smoke/examples)."""
    specs = input_specs(cfg, shape)
    out: Dict[str, jax.Array] = {}
    for k, sds in specs.items():
        key = jax.random.fold_in(rng, hash(k) % (2 ** 31))
        if sds.dtype == jnp.int32:
            hi = cfg.vocab_size if k in ("tokens", "labels") else max(shape.seq_len, 2)
            out[k] = jax.random.randint(key, sds.shape, 0, min(hi, 2 ** 30),
                                        dtype=jnp.int32)
            if k == "pos":
                out[k] = jnp.full(sds.shape, shape.seq_len - 1, jnp.int32)
        else:
            out[k] = (jax.random.normal(key, sds.shape, jnp.float32) * 0.02
                      ).astype(sds.dtype)
    return out


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------

def init_train_state(cfg: ArchConfig, opt_cfg: AdamWConfig, rng: jax.Array,
                     compress: bool = False) -> Dict[str, Any]:
    params = M.init_params(cfg, rng)
    st = {"params": params, "opt": init_opt_state(params, opt_cfg)}
    if compress:
        st["resid"] = init_residual(params)
    return st


def train_state_specs(cfg: ArchConfig, opt_cfg: AdamWConfig,
                      compress: bool = False) -> Dict[str, Any]:
    p = M.param_specs(cfg)
    def f32(s):
        return jax.ShapeDtypeStruct(s.shape, jnp.float32)
    opt = {"m": jax.tree.map(f32, p), "v": jax.tree.map(f32, p),
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if opt_cfg.use_master:
        opt["master"] = jax.tree.map(f32, p)
    st = {"params": p, "opt": opt}
    if compress:
        st["resid"] = jax.tree.map(f32, p)
    return st


def train_state_axes(cfg: ArchConfig, opt_cfg: AdamWConfig,
                     compress: bool = False) -> Dict[str, Any]:
    ax = M.param_axes(cfg)
    opt = {"m": ax, "v": ax, "step": Axes(())}
    if opt_cfg.use_master:
        opt["master"] = ax
    st = {"params": ax, "opt": opt}
    if compress:
        st["resid"] = ax
    return st


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt_cfg: Optional[AdamWConfig] = None,
                    compress: bool = False):
    opt_cfg = opt_cfg or default_opt_cfg(cfg)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        if compress:
            grads, new_resid = ef_compress_grads(grads, state["resid"])
        new_params, new_opt, metrics = adamw_update(params, grads,
                                                    state["opt"], opt_cfg)
        new_state = {"params": new_params, "opt": new_opt}
        if compress:
            new_state["resid"] = new_resid
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return M.prefill_logits(cfg, params, batch)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, batch):
        return M.decode_forward(cfg, params, cache, batch["tokens"],
                                batch["pos"])
    return decode_step


# ---------------------------------------------------------------------------
# Sharding assembly for AOT lowering
# ---------------------------------------------------------------------------

def _shardings(spec_tree, axes_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, logical_pspec(s.shape, a.axes, mesh)),
        spec_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lowerable(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
              opt_cfg: Optional[AdamWConfig] = None,
              compress: bool = False, profile: str = "megatron"):
    """(jitted_fn, arg_specs) ready for .lower(*arg_specs) under `mesh`.

    The returned callable must be lowered inside
    ``mesh_context(mesh)`` + ``sharding_profile(profile)`` so model-internal
    sharding constraints resolve against the same mesh/profile.
    """
    opt_cfg = opt_cfg or default_opt_cfg(cfg)
    if shape.kind != "train":
        # prefill/decode have no backward: unrolled compiles are cheap and
        # give exact (no trip-count-corrected) HLO cost accounting
        import dataclasses
        cfg = dataclasses.replace(cfg, scan_layers=False)
    with sharding_profile(profile), mesh_context(mesh):
        return _lowerable_inner(cfg, shape, mesh, opt_cfg, compress)


def _lowerable_inner(cfg, shape, mesh, opt_cfg, compress):
    repl = NamedSharding(mesh, P())
    b_specs = input_specs(cfg, shape)
    b_shard = _shardings(b_specs, input_axes(cfg, shape), mesh)

    if shape.kind == "train":
        st_specs = train_state_specs(cfg, opt_cfg, compress)
        st_shard = _shardings(st_specs, train_state_axes(cfg, opt_cfg, compress),
                              mesh)
        metric_shard = {"loss": repl, "grad_norm": repl, "lr": repl}
        fn = jax.jit(make_train_step(cfg, opt_cfg, compress),
                     in_shardings=(st_shard, b_shard),
                     out_shardings=(st_shard, metric_shard),
                     donate_argnums=(0,))
        return fn, (st_specs, b_specs)

    p_specs = M.param_specs(cfg)
    p_shard = _shardings(p_specs, M.param_axes(cfg), mesh)

    if shape.kind == "prefill":
        fn = jax.jit(make_prefill_step(cfg),
                     in_shardings=(p_shard, b_shard),
                     out_shardings=None)
        return fn, (p_specs, b_specs)

    T = max(cfg.cache_len(shape), 1)
    B = shape.global_batch
    c_specs = M.cache_specs(cfg, B, T)
    c_shard = _shardings(c_specs, M.cache_axes(cfg, B, T), mesh)
    fn = jax.jit(make_decode_step(cfg),
                 in_shardings=(p_shard, c_shard, b_shard),
                 out_shardings=(None, c_shard),
                 donate_argnums=(1,))
    return fn, (p_specs, c_specs, b_specs)
