from repro.optim.adamw import (
    AdamWConfig, init_opt_state, adamw_update, cosine_lr, clip_by_global_norm,
)
from repro.optim.compress import (
    compress_int8, decompress_int8, ef_compress_grads, init_residual,
)

__all__ = [
    "AdamWConfig", "init_opt_state", "adamw_update", "cosine_lr",
    "clip_by_global_norm", "compress_int8", "decompress_int8",
    "ef_compress_grads", "init_residual",
]
