"""AdamW (+cosine schedule, global-norm clipping) as pure tree transforms.

Memory policy: m/v are fp32; an optional fp32 master copy of the params is
kept unless ``use_master=False`` (huge models: update bf16 params with fp32
math on the fly — deepseek-v2 / qwen3 configs use this to fit 16 GB/chip).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _distinct_zeros(shape, dtype=jnp.float32):
    """Eager zeros with a guaranteed-unique buffer.

    jnp.zeros may alias identical constants; donated train-state leaves
    (m/v for same-shaped params) must not share buffers or Execute()
    rejects the double donation.
    """
    return jax.device_put(np.zeros(shape, dtype))


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    use_master: bool = True


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


def init_opt_state(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros32(p):
        return _distinct_zeros(p.shape)
    st = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.use_master:
        st["master"] = jax.tree.map(
            lambda p: jnp.asarray(p, jnp.float32) + 0.0, params)
    return st


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = jnp.zeros((), jnp.float32)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    ref = state.get("master", params)

    def upd(p_ref, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh, vh = m / b1c, v / b2c
        p32 = p_ref.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) +
                          cfg.weight_decay * p32)
        return p32, m, v

    flat_ref, tdef = jax.tree.flatten(ref)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(*a) for a in zip(flat_ref, flat_g, flat_m, flat_v)]
    new32 = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    new_params = jax.tree.map(lambda p, n: n.astype(p.dtype), params, new32)
    if cfg.use_master:
        new_state["master"] = new32
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
