"""int8 quantization primitives: gradient compression + per-head KV scales.

Two consumers share the same absmax/127 scheme:

* the cross-pod DP hop (``ef_compress_grads``) — one scale per gradient
  leaf, with an error-feedback residual riding in the train state;
* the quantized KV serving path — the paged engines store int8 KV pages
  with one float32 scale per (page, K/V, kv-head); the fused scatter
  quantizes at write (``headwise_scales`` + ``quantize_int8``) and the
  attention kernels dequantize inside the K/V fetch.  Scales only ever
  *grow* per page (running absmax), so re-quantizing an untouched page
  under its own unchanged scale is exactly lossless (``round(q * 1) ==
  q``) — the rescale-on-grow repack perturbs only pages a new token
  actually extended.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

#: guards divisions by an all-zero slice's scale
SCALE_EPS = 1e-30


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def headwise_scales(x: jax.Array, axis: int = -1) -> jax.Array:
    """absmax/127 over ``axis`` — ``compress_int8``'s scale, one per
    remaining slice instead of one per tensor (the per-(page, head) grain
    the KV pool stores).  Zero slices get scale 0 (they quantize to 0)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis) / 127.0


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize under an externally supplied scale (must broadcast against
    ``x``) — the KV write path computes the page's running-max scale first
    and then quantizes the new tokens under it."""
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, SCALE_EPS))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def ef_compress_grads(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 round-trip: returns (decompressed grads, new residual).

    Simulates the wire format the cross-pod all-reduce would carry; the
    returned grads are what the receiving side reconstructs.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = compress_int8(g32)
        d = decompress_int8(q, s)
        return d.astype(g.dtype), g32 - d

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_residual(grads_like: Any) -> Any:
    from repro.optim.adamw import _distinct_zeros
    return jax.tree.map(lambda g: _distinct_zeros(g.shape), grads_like)
