"""int8 error-feedback gradient compression for the cross-pod DP hop.

Large-fleet trick: the per-step gradient all-reduce across pods rides the
slow DCN link; quantizing to int8 with an error-feedback residual cuts that
traffic 4x (bf16) with negligible convergence impact.  Applied as a tree
transform around the gradient before the optimizer; the residual lives in
the train state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 round-trip: returns (decompressed grads, new residual).

    Simulates the wire format the cross-pod all-reduce would carry; the
    returned grads are what the receiving side reconstructs.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = compress_int8(g32)
        d = decompress_int8(q, s)
        return d.astype(g.dtype), g32 - d

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_residual(grads_like: Any) -> Any:
    from repro.optim.adamw import _distinct_zeros
    return jax.tree.map(lambda g: _distinct_zeros(g.shape), grads_like)
