from repro.parallel.sharding import (
    mesh_context, current_mesh, logical_pspec, shard, named_sharding,
    ParamFactory, LOGICAL_TO_PHYSICAL,
)

__all__ = [
    "mesh_context", "current_mesh", "logical_pspec", "shard",
    "named_sharding", "ParamFactory", "LOGICAL_TO_PHYSICAL",
]
