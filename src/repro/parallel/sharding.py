"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) + param factory.

Model code never names physical mesh axes.  It uses *logical* axes:

  ``dp``    batch dim                -> ("pod", "data")
  ``fsdp``  ZeRO-3 param shard dim   -> ("pod", "data")
  ``tp``    tensor-parallel dim      -> ("model",)   (heads / d_ff / vocab / experts)
  ``sp``    sequence-parallel dim    -> ("model",)   (long KV / scores seq dim)
  ``None``  replicated

The translation is *divisibility-safe*: a logical axis is dropped for a
tensor dim that the mesh axis product does not divide (e.g. hymba's 25 heads
on a 16-way model axis).  This keeps every arch compilable on the fixed
production meshes without per-arch special-casing, at the cost of
replication where the math demands it — exactly what a production framework
must do.

``ParamFactory`` builds a parameter tree once and interprets it twice:
``mode="init"`` materializes jax arrays; ``mode="spec"`` yields
ShapeDtypeStructs and records the PartitionSpec for every leaf (used for the
AOT dry-run and for checkpoint metadata).
"""
from __future__ import annotations

import contextlib
import contextvars
import hashlib
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: Sharding profiles (perf hillclimb lever — see EXPERIMENTS.md §Perf).
#:   megatron: TP over `model` for heads/ff/vocab + ZeRO-3 over `pod,data`
#:             (per-layer activation gathers; the paper-faithful baseline
#:             maps HERO clusters onto the model axis)
#:   fsdp:     pure ZeRO-3 over the whole mesh (no TP): weights gathered
#:             per layer instead of activations — wins when the per-device
#:             batch is large (train_4k)
#:   serve:    TP only, no weight sharding over data: weights resident
#:             per model-shard, zero weight gathers per token — the decode
#:             profile (weights must fit HBM/tp)
PROFILES = {
    "megatron": {
        "dp": ("pod", "data"),
        "fsdp": ("pod", "data"),
        "tp": ("model",),
        "sp": ("model",),
        "ep": ("model",),
    },
    "fsdp": {
        "dp": ("pod", "data", "model"),
        "fsdp": ("pod", "data", "model"),
        "tp": (),
        "sp": (),
        "ep": ("model",),
    },
    "serve": {
        "dp": ("pod", "data"),
        "fsdp": (),
        "tp": ("model",),
        "sp": ("model",),
        "ep": ("model",),
    },
}

LOGICAL_TO_PHYSICAL = PROFILES["megatron"]

_PROFILE: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_profile", default="megatron")

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


@contextlib.contextmanager
def sharding_profile(name: str):
    assert name in PROFILES, (name, list(PROFILES))
    tok = _PROFILE.set(name)
    try:
        yield
    finally:
        _PROFILE.reset(tok)


def current_profile() -> str:
    return _PROFILE.get()


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    tok = _MESH.set(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _MESH.reset(tok)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def _physical_axes(logical: Optional[str], mesh: Mesh) -> Tuple[str, ...]:
    if logical is None:
        return ()
    phys = PROFILES[_PROFILE.get()].get(logical, ())
    return tuple(a for a in phys if a in mesh.shape)


def logical_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  mesh: Mesh) -> P:
    """Divisibility-safe PartitionSpec for `shape` annotated with logical axes."""
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    out: List[Any] = []
    for dim, name in zip(shape, axes):
        phys = tuple(a for a in _physical_axes(name, mesh)
                     if a not in used and mesh.shape[a] > 1)
        # keep only a prefix of the physical axes whose product divides dim
        kept: List[str] = []
        prod = 1
        for a in phys:
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        if kept:
            used.update(kept)
            out.append(tuple(kept) if len(kept) > 1 else kept[0])
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(shape: Sequence[int], axes: Sequence[Optional[str]],
                   mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, logical_pspec(shape, axes, mesh))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the context mesh (no-op without one)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_pspec(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter factory
# ---------------------------------------------------------------------------

def _stable_seed(path: str) -> int:
    return int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")


class Axes:
    """Opaque pytree leaf carrying a logical-axes annotation."""

    __slots__ = ("axes",)

    def __init__(self, axes: Tuple[Optional[str], ...]):
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Axes{self.axes}"


class ParamFactory:
    """Single-definition parameter builder with three interpretations.

    mode="init": returns concrete arrays (normal / zeros / ones).
    mode="spec": returns ShapeDtypeStruct leaves.
    mode="axes": returns `Axes` leaves (tree mirrors the params tree, so no
                 fragile path matching is needed to pair specs with axes).
    """

    def __init__(self, mode: str, dtype: jnp.dtype, rng: Optional[jax.Array] = None):
        assert mode in ("init", "spec", "axes")
        self.mode = mode
        self.dtype = dtype
        self.rng = rng
        self._scope: List[str] = []
        self._stack: List[int] = []   # stacked-layer prefixes
        self.axes_by_path: Dict[str, Tuple[Optional[str], ...]] = {}

    @contextlib.contextmanager
    def scope(self, name: str):
        self._scope.append(name)
        try:
            yield
        finally:
            self._scope.pop()

    @contextlib.contextmanager
    def stacked(self, n: int):
        """Within this context every param gets a leading (n,) stack dim."""
        self._stack.append(n)
        try:
            yield
        finally:
            self._stack.pop()

    def _path(self, name: str) -> str:
        return "/".join(self._scope + [name])

    def __call__(self, name: str, shape: Sequence[int],
                 axes: Sequence[Optional[str]], init: str = "normal",
                 fan_in: Optional[int] = None, dtype=None,
                 fill: float = 0.0) -> Any:
        dtype = dtype or self.dtype
        full_shape = tuple(self._stack) + tuple(shape)
        full_axes = (None,) * len(self._stack) + tuple(axes)
        path = self._path(name)
        self.axes_by_path[path] = full_axes
        if self.mode == "axes":
            return Axes(full_axes)
        if self.mode == "spec":
            return jax.ShapeDtypeStruct(full_shape, dtype)
        # constant inits go through numpy fp32 + on-device cast so every leaf
        # owns a distinct buffer — jnp constants may alias, which breaks
        # donated train state ("attempt to donate the same buffer twice")
        import numpy as _np
        if init in ("zeros", "ones", "fill"):
            val = {"zeros": 0.0, "ones": 1.0, "fill": fill}[init]
            base = jax.device_put(_np.full(full_shape, val, _np.float32))
            return base.astype(dtype)
        key = jax.random.fold_in(self.rng, _stable_seed(path))
        fi = fan_in if fan_in is not None else (shape[0] if shape else 1)
        std = 1.0 / math.sqrt(max(fi, 1))
        return (jax.random.normal(key, full_shape, jnp.float32) * std).astype(dtype)


def is_axes_leaf(x: Any) -> bool:
    return isinstance(x, Axes)


def tree_pspecs(spec_tree: Any, axes_tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree from a (ShapeDtypeStruct tree, Axes tree) pair."""
    return jax.tree.map(
        lambda sds, ax: logical_pspec(sds.shape, ax.axes, mesh),
        spec_tree, axes_tree, is_leaf=lambda x: is_axes_leaf(x))


def tree_shardings(spec_tree: Any, axes_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda sds, ax: NamedSharding(mesh, logical_pspec(sds.shape, ax.axes, mesh)),
        spec_tree, axes_tree, is_leaf=lambda x: is_axes_leaf(x))


# ---------------------------------------------------------------------------
# Sharded paged-serving engine specs (the ClusterMesh ("cluster", "head"))
# ---------------------------------------------------------------------------
#
# The paged engine shards two ways: request lanes (and their KV page shard)
# over ``cluster``, attention heads GQA-aware over ``head``.  Everything
# else — embeddings, norms, MLP/MoE weights, the logits path — is
# replicated so on-device greedy sampling needs no cross-shard collective
# beyond the attention-output psum.

#: attention param leaves sharded over the head axis: leaf name -> the
#: negative index of its head dimension (stacked-layer leading dims make
#: positive indices unstable).  wq/wk/wv: (..., d, H|Kv, hd); wo:
#: (..., H, hd, d); q_norm/k_norm: (..., H|Kv, hd).
_HEAD_DIM_BY_NAME = {"wq": -2, "wk": -2, "wv": -2, "wo": -3,
                     "q_norm": -2, "k_norm": -2}


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", None) or getattr(last, "name", "") or str(last)


def head_param_pspecs(params: Any, head_axis: str = "head") -> Any:
    """PartitionSpec tree sharding attention heads over ``head_axis``.

    GQA-aware by construction: the reference/kernel head layout is
    kv-major (query head ``k*G + g`` attends through kv head ``k``), so
    splitting both the H and Kv dims into equal contiguous blocks keeps
    every query head co-resident with its kv head.  The caller must check
    the axis size divides ``num_kv_heads`` (see
    ``kernels.paged_attention.ops.validate_head_sharding``).
    """
    def spec(path, leaf):
        dim = _HEAD_DIM_BY_NAME.get(_leaf_name(path))
        if dim is None:
            return P()
        out = [None] * leaf.ndim
        out[leaf.ndim + dim] = head_axis
        return P(*out)
    return jax.tree_util.tree_map_with_path(spec, params)


def cluster_engine_specs(params: Any) -> Dict[str, Any]:
    """Spec pieces for ``shard_map``-ing the paged engine steps.

    ``kv`` is the fused (L, C*(P+1), 2, page, Kv, hd) slab — pages sharded
    over ``cluster`` (each cluster's contiguous block includes its own
    trash page), kv heads over ``head``; ``kv_scales`` is its
    (L, C*(P+1), 2, Kv) per-page dequant-scale companion for the int8 KV
    mode, sharded the same two ways; ``lane``/``lane2`` shard lane-indexed
    (B,) / (B, n) arrays' leading batch dim over ``cluster``; ``params`` is
    the head-sharded attention-weight tree.  The engine step returns
    (sampled, kv_pages, kv_scales, new_lens) ->
    (lane, kv, kv_scales, lane).
    """
    return {
        "params": head_param_pspecs(params),
        "kv": P(None, "cluster", None, None, "head", None),
        "kv_scales": P(None, "cluster", None, "head"),
        "lane": P("cluster"),
        "lane2": P("cluster", None),
    }
