"""Analytical capacity planner: predict the bench, then invert it.

Three layers (HERO's lumos-style design-space exploration, applied to
the serving engine):

* :mod:`repro.planner.workload` — :class:`WorkloadSpec` / :class:`SLOSpec`,
  the frozen workload schema shared with ``benchmarks/load_gen.py``;
* :mod:`repro.planner.costs` + :mod:`repro.core.roofline` — what one
  engine iteration costs (measured constant or analytic roofline);
* :mod:`repro.planner.simulator` — a deterministic discrete-event
  replica of the scheduler on a virtual clock, composing step costs
  into a predicted serving report;
* :mod:`repro.planner.capacity` — :func:`plan_capacity`, the search
  that inverts prediction into the cheapest SLO-meeting EngineConfig.

Accuracy is measured (and CI-gated) by ``benchmarks/plan_accuracy.py``
against the real engine's ``BENCH_serve.json``.
"""
from repro.planner.capacity import (
    PlanResult, candidate_grid, config_cost, plan_capacity,
)
from repro.planner.costs import (
    AnalyticCostModel, Calibration, FixedIterationCost,
)
from repro.planner.simulator import IterationStats, simulate
from repro.planner.workload import SampledRequest, SLOSpec, WorkloadSpec

__all__ = [
    "AnalyticCostModel",
    "Calibration",
    "FixedIterationCost",
    "IterationStats",
    "PlanResult",
    "SLOSpec",
    "SampledRequest",
    "WorkloadSpec",
    "candidate_grid",
    "config_cost",
    "plan_capacity",
    "simulate",
]
