"""``plan_capacity(workload, slo) -> EngineConfig`` — the inversion.

The lumos move (SNIPPETS.md: model the design space against budgets,
then ask "what should I build?") applied to the serving engine: span a
deterministic candidate grid over clusters / pages / chunk / spec_k /
kv_dtype, predict every candidate's serving report with the
discrete-event simulator, and return the CHEAPEST candidate whose
prediction meets the SLO, with the predicted report attached.

Cost is resource cost, not latency: each cluster pays its resident
weight bytes plus its KV pool bytes (int8 pools are literally cheaper
bytes), speculation pays a small drafter surcharge.  Candidates are
enumerated in one fixed order and simulated cheapest-first, so:

* the result is deterministic — same (workload, slo, model) inputs
  yield the same ``EngineConfig`` and the same predicted report;
* a tighter SLO can never pick a cheaper config — per-candidate
  predictions are SLO-independent (the feasibility check reads only
  p95 TTFT/TPOT and completion), so tightening the SLO only shrinks
  the feasible set and first-feasible-by-cost can only move later.

No wall clock anywhere: the simulator runs on a
:class:`~repro.runtime.clock.VirtualClock` and the cost model is either
a calibrated constant or the analytic roofline model.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.roofline import kv_bytes_per_token
from repro.planner.costs import (
    AnalyticCostModel, Calibration, FixedIterationCost, IterationStats,
)
from repro.planner.simulator import simulate
from repro.planner.workload import SLOSpec, WorkloadSpec
from repro.runtime.api import CacheConfig, EngineConfig

__all__ = ["plan_capacity", "PlanResult", "candidate_grid", "config_cost"]

#: drafter surcharge per speculative depth step, in cost-bytes — small
#: enough to never outweigh a page, large enough to break ties toward
#: the simpler engine
_SPEC_COST_BYTES = 1024.0


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """What the planner recommends and why."""
    engine: EngineConfig
    predicted: dict             # the winning candidate's simulated report
    cost: float                 # resource cost of the winner
    evaluated: int              # candidates simulated before the winner met
    workload: WorkloadSpec
    slo: SLOSpec


def config_cost(engine: EngineConfig, model_cfg) -> float:
    """Deterministic resource cost of a candidate, in bytes: per
    cluster, the resident weights plus the KV pool (priced at the
    pool's own ``kv_dtype``), plus the speculation surcharge."""
    from repro.core.roofline import param_counts
    cache = engine.cache
    kv_bpt = kv_bytes_per_token(model_cfg, cache.kv_dtype, cache.page_size)
    pool_bytes = cache.num_pages * cache.page_size * kv_bpt
    weight_bytes = param_counts(model_cfg)["total"] * 2.0
    return engine.clusters * (weight_bytes + pool_bytes) \
        + engine.spec_k * _SPEC_COST_BYTES


def candidate_grid(workload: WorkloadSpec, *, page_size: int = 4,
                   max_clusters: int = 8) -> List[EngineConfig]:
    """The fixed search grid: clusters x lanes x pool margin x chunk x
    kv_dtype x spec_k, every candidate sized to admit the workload's
    longest possible request."""
    longest = workload.prompt_max + workload.output_max
    per_seq = -(-longest // page_size) + 1
    spec_ks: Tuple[int, ...] = (0,)
    if workload.spec_acceptance_rate > 0:
        spec_ks = (0, 4)
    out: List[EngineConfig] = []
    clusters = [c for c in (1, 2, 4, 8) if c <= max_clusters]
    for c in clusters:
        for lanes in (2, 4, 8):
            base = per_seq * lanes + 8
            for margin in (1, 2):
                for chunk in (4, 8, 16):
                    for kv in ("int8", "bf16"):
                        for sk in spec_ks:
                            out.append(EngineConfig(
                                cache=CacheConfig(
                                    num_pages=base * margin,
                                    page_size=page_size,
                                    max_pages_per_seq=per_seq,
                                    kv_dtype=kv),
                                max_lanes=lanes, chunk=chunk,
                                clusters=c, spec_k=sk,
                                use_kernel=False))
    return out


def _tiebreak(e: EngineConfig) -> tuple:
    return (e.clusters, e.max_lanes, e.cache.num_pages, e.chunk,
            e.spec_k, e.cache.kv_dtype)


def plan_capacity(workload: WorkloadSpec, slo: SLOSpec, *,
                  model_cfg=None, arch: str = "yi-6b",
                  page_size: int = 4, max_clusters: int = 8,
                  calibration: Optional[Calibration] = None,
                  vocab: int = 32768,
                  candidates: Optional[Sequence[EngineConfig]] = None,
                  ) -> PlanResult:
    """Recommend the cheapest engine config predicted to meet ``slo``.

    ``calibration`` switches iteration pricing from the analytic
    roofline model to the measured constant (the front door's
    ``iter_time_s`` contract) — use it whenever a trace of comparable
    hardware exists.  Raises ``ValueError`` when no candidate in the
    grid meets the SLO (the message carries the best prediction seen,
    so the caller learns how far off the grid was)."""
    if model_cfg is None:
        from repro.configs import get_config
        model_cfg = get_config(arch).smoke()
    arrivals = workload.sample_arrivals(vocab)
    grid = list(candidates) if candidates is not None else \
        candidate_grid(workload, page_size=page_size,
                       max_clusters=max_clusters)
    ranked = sorted(((config_cost(e, model_cfg), _tiebreak(e), e)
                     for e in grid), key=lambda t: (t[0], t[1]))
    best_miss: Optional[dict] = None
    for n, (cost, _tb, engine) in enumerate(ranked, start=1):
        if calibration is not None:
            iter_cost = FixedIterationCost(calibration.iter_time_s)
        else:
            iter_cost = AnalyticCostModel.for_engine(model_cfg, engine)
        report = simulate(
            arrivals, engine, iteration_cost=iter_cost,
            spec_acceptance=workload.spec_acceptance_rate,
            slo_ttft_s=slo.ttft_p95_s, slo_tpot_s=slo.tpot_p95_s)
        if slo.met_by(report):
            return PlanResult(engine=engine, predicted=report, cost=cost,
                              evaluated=n, workload=workload, slo=slo)
        if best_miss is None or (report["ttft_p95_s"], report["tpot_p95_s"]) \
                < (best_miss["ttft_p95_s"], best_miss["tpot_p95_s"]):
            best_miss = report
    raise ValueError(
        "no candidate in the grid meets the SLO "
        f"(ttft_p95<={slo.ttft_p95_s}, tpot_p95<={slo.tpot_p95_s}); "
        f"best prediction: ttft_p95={best_miss['ttft_p95_s']}, "
        f"tpot_p95={best_miss['tpot_p95_s']}" if best_miss else
        "no candidates to evaluate")


# re-exported for callers that price their own iterations
IterationStats = IterationStats
