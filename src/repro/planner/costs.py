"""Iteration cost models: what one engine iteration costs in seconds.

Two ways to price the simulator's iterations:

* :class:`FixedIterationCost` — a constant per iteration.  This is the
  front door's own accounting contract (``FrontDoor(iter_time_s=...)``
  charges every iteration the same virtual quantum), so replaying a
  bench workload with the bench's ``iter_time_s`` predicts its latency
  report on exactly the bench's own terms.  Build one from a measured
  trace via :class:`Calibration`.

* :class:`AnalyticCostModel` — first-principles pricing from the
  roofline byte/FLOP terms (:mod:`repro.core.roofline`): an iteration
  that feeds ``P`` prompt tokens, advances ``D`` decode lanes and
  verifies ``S`` speculative positions costs
  ``max(compute, memory)`` seconds where

  - compute = 2 * N_active * (P + D + S) / (devices * PEAK_FLOPS)
  - memory  = (weights/head-shard + context KV bytes / clusters) / HBM_BW

  with the KV term priced by the engine's OWN page geometry via
  :func:`repro.core.roofline.kv_bytes_per_token` — so ``kv_dtype="int8"``
  halves the decode-side memory term exactly as the quantized engine's
  ``bytes_per_token`` does.  This is what ``plan_capacity`` uses to
  compare configs it has never run.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from repro.core.roofline import kv_bytes_per_token, param_counts
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.planner.simulator import IterationStats

__all__ = ["Calibration", "FixedIterationCost", "AnalyticCostModel"]


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured per-iteration timing, the planner's calibration input.

    ``iter_time_s`` is the virtual quantum each engine iteration costs
    (the front door's knob, or a wall measurement divided by the
    iteration count); the iteration-domain service/queue split comes
    from a recorded trace via
    :func:`repro.core.analysis.layer2_calibration`."""
    iter_time_s: float
    mean_service_iters: float = 0.0
    mean_queue_delay_iters: float = 0.0

    def __post_init__(self):
        if self.iter_time_s < 0:
            raise ValueError("iter_time_s must be >= 0")

    @classmethod
    def from_trace(cls, events: Iterable, *,
                   iter_time_s: float) -> "Calibration":
        """Build from a recorded trace-event stream: the per-request
        queue-delay / service split measured in engine iterations."""
        from repro.core.analysis import layer2_calibration
        cal = layer2_calibration(events, iter_time_s=iter_time_s)
        return cls(iter_time_s=iter_time_s,
                   mean_service_iters=cal["mean_service_iters"],
                   mean_queue_delay_iters=cal["mean_queue_delay_iters"])

    def cost(self) -> "FixedIterationCost":
        return FixedIterationCost(self.iter_time_s)


@dataclasses.dataclass(frozen=True)
class FixedIterationCost:
    """Constant seconds per iteration (the FrontDoor contract)."""
    iter_time_s: float

    def __call__(self, st: IterationStats) -> float:
        return self.iter_time_s


@dataclasses.dataclass(frozen=True)
class AnalyticCostModel:
    """Roofline-derived iteration pricing for a concrete engine spec."""
    n_active: float             # active parameters (MoE-aware)
    n_total: float              # total parameters
    kv_bytes_token: float       # KV bytes per resident token, all layers
    clusters: int = 1
    heads: int = 1
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    overhead_s: float = 0.0     # fixed per-iteration dispatch overhead

    @classmethod
    def for_engine(cls, model_cfg, engine_cfg, *,
                   overhead_s: float = 0.0,
                   peak_flops: Optional[float] = None,
                   hbm_bw: Optional[float] = None) -> "AnalyticCostModel":
        counts = param_counts(model_cfg)
        cache = engine_cfg.cache
        return cls(
            n_active=counts["active"], n_total=counts["total"],
            kv_bytes_token=kv_bytes_per_token(
                model_cfg, cache.kv_dtype, cache.page_size),
            clusters=engine_cfg.clusters, heads=engine_cfg.heads,
            peak_flops=peak_flops or PEAK_FLOPS_BF16,
            hbm_bw=hbm_bw or HBM_BW,
            overhead_s=overhead_s)

    def __call__(self, st: IterationStats) -> float:
        devices = self.clusters * self.heads
        tokens = st.prefill_tokens + st.decode_lanes + st.spec_tokens
        t_comp = 2.0 * self.n_active * tokens / (devices * self.peak_flops)
        # weights stream once per iteration per head shard (serve
        # profile: replicated over clusters); each cluster reads only
        # its own lanes' resident KV
        w_bytes = self.n_total * 2.0 / self.heads
        kv_bytes = st.context_tokens * self.kv_bytes_token / self.clusters
        t_mem = (w_bytes + kv_bytes) / self.hbm_bw
        return max(t_comp, t_mem) + self.overhead_s
