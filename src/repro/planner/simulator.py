"""Deterministic discrete-event simulator of the paged serving engine.

The planner's centerpiece: a token-free replica of the scheduler state
machine in ``runtime/server.py`` / ``runtime/sharded_server.py`` /
``runtime/frontdoor.py``.  It admits, chunks, decodes, speculates,
preempts nothing it should not, and charges per-iteration time on a
real :class:`~repro.runtime.clock.VirtualClock` — but never touches a
model, a device array or a wall clock, so simulating a config costs
microseconds instead of an engine run.

What is mirrored EXACTLY (same branch structure as the engine):

* the front-door serve loop — submit due arrivals, one ``step()``,
  charge ``iteration time`` only when an iteration ran, stamp
  first-token/finish at the post-charge clock, jump to the next arrival
  when idle;
* ``step()`` — admission before the iteration, lane-ordered active set,
  policy-planned prefill chunking with the forced-progress rule, one
  token per decode lane, first token emitted in the same iteration the
  final prompt chunk is fed, finish frees the lane within the
  iteration;
* admission — FIFO within priority, page-fit against
  ``available() >= need + cached_hits`` with reservation accounting,
  the CoW donor budget, cache-affine least-loaded cluster scoring
  ``(usable, available, -cluster)``, and the no-hit fallback plan;
* the page pool — lazy per-token page allocation, full-prompt-page
  prefix registration, refcounted sharing, cached-free LRU parking and
  eviction, host/disk demotion with capacity caps, and asynchronous
  promotion latency (``promote_latency_s * ceil(pages /
  prefetch_depth)``) gating the admitted lane on the virtual clock.

What is a MODEL (documented divergences from the engine):

* speculation — the drafter is assumed to always have a proposal and
  to hit ``WorkloadSpec.spec_acceptance_rate`` via a deterministic
  per-lane acceptance accumulator; the real n-gram drafter proposes
  only on history matches, so predicted speculative iteration counts
  are approximate (reported, not gated);
* priorities — bench workloads are single-priority, where the engine
  never preempts; the simulator models that case (a head that does not
  fit waits) and does not model cross-priority preemption.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.runtime.api import EngineConfig, FINISH_LENGTH
from repro.runtime.clock import VirtualClock
from repro.runtime.frontdoor import (
    GreedyChunkPolicy, RequestRecord, latency_report,
)
from repro.planner.workload import SampledRequest

__all__ = ["IterationStats", "simulate", "SimReport"]


# ===========================================================================
# iteration cost interface
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class IterationStats:
    """What one engine iteration did — the cost model's pricing input."""
    prefill_tokens: int         # prompt tokens fed this iteration
    decode_lanes: int           # lanes that advanced one token
    spec_tokens: int            # draft+bonus positions verified
    context_tokens: int         # KV tokens resident across active lanes
    active_clusters: int


#: seconds charged for one iteration
IterationCost = Callable[[IterationStats], float]


# ===========================================================================
# pool / tier model
# ===========================================================================

class _Page:
    __slots__ = ("key", "refs")

    def __init__(self):
        self.key: Optional[tuple] = None
        self.refs = 0


class _SimTiers:
    """Host -> disk cache spill, LRU per tier, capacity-capped."""

    def __init__(self, host_pages: int, disk_pages: int):
        self.host_cap = host_pages
        self.disk_cap = disk_pages
        self.host: "OrderedDict[tuple, None]" = OrderedDict()
        self.disk: "OrderedDict[tuple, None]" = OrderedDict()
        self.dropped = 0

    def __contains__(self, key) -> bool:
        return key in self.host or key in self.disk

    def tier_of(self, key) -> str:
        return "host" if key in self.host else "disk"

    def demote(self, key):
        if len(self.host) >= self.host_cap:
            old, _ = self.host.popitem(last=False)
            if self.disk_cap and len(self.disk) < self.disk_cap:
                self.disk[old] = None
            elif self.disk_cap:
                self.disk.popitem(last=False)
                self.disk[old] = None
                self.dropped += 1
            else:
                self.dropped += 1
        self.host[key] = None

    def promote(self, key) -> str:
        tier = self.tier_of(key)
        if key in self.host:
            del self.host[key]
        else:
            del self.disk[key]
        return tier


class _SimPool:
    """Refcounted page pool: free counter + cached-free LRU + prefix
    index, with admission-time reservations — the allocator semantics
    of ``core.rab.PagedKVPool`` without payloads."""

    def __init__(self, num_pages: int, page_size: int,
                 tiers: Optional[_SimTiers]):
        self.num_pages = num_pages
        self.page_size = page_size
        self.free = num_pages
        self.cached_free: "OrderedDict[tuple, _Page]" = OrderedDict()
        self.index: Dict[tuple, _Page] = {}
        self.reserved: Dict[int, int] = {}
        self.tiers = tiers
        self.stats = {"evictions": 0, "demoted": 0, "promoted": 0,
                      "cow": 0, "prefix_hit_tokens": 0}

    def available(self) -> int:
        return self.free + len(self.cached_free) \
            - sum(self.reserved.values())

    def occupancy(self) -> int:
        return self.num_pages - self.free - len(self.cached_free)

    def _take_page(self) -> _Page:
        if self.free > 0:
            self.free -= 1
            return _Page()
        if self.cached_free:
            key, pg = self.cached_free.popitem(last=False)
            del self.index[key]
            if self.tiers is not None:
                self.tiers.demote(key)
                self.stats["demoted"] += 1
            self.stats["evictions"] += 1
            pg.key = None
            pg.refs = 0
            return pg
        raise MemoryError("sim KV pool exhausted")

    def _draw_reservation(self, rid: int):
        if self.reserved.get(rid, 0) > 0:
            self.reserved[rid] -= 1
        elif self.available() < 1:
            raise MemoryError("sim KV pool exhausted (reserved)")

    def alloc_page(self, rid: int) -> _Page:
        self._draw_reservation(rid)
        pg = self._take_page()
        pg.refs = 1
        return pg

    def share_page(self, key: tuple) -> _Page:
        pg = self.index[key]
        if key in self.cached_free:
            del self.cached_free[key]
        pg.refs += 1
        return pg

    def drop_ref(self, pg: _Page):
        pg.refs -= 1
        if pg.refs == 0:
            if pg.key is not None and self.index.get(pg.key) is pg:
                self.cached_free[pg.key] = pg
                self.cached_free.move_to_end(pg.key)
            else:
                self.free += 1

    def release(self, rid: int, pages: List[_Page]):
        for pg in pages:
            self.drop_ref(pg)
        self.reserved.pop(rid, None)

    def register(self, pg: _Page, key: tuple):
        if pg.key is None and key not in self.index and \
                (self.tiers is None or key not in self.tiers):
            pg.key = key
            self.index[key] = pg

    def unregister(self, pg: _Page):
        if pg.key is not None and self.index.get(pg.key) is pg:
            del self.index[pg.key]
        pg.key = None

    def match_prefix(self, page_keys: Sequence[tuple]
                     ) -> List[Tuple[str, tuple]]:
        hits: List[Tuple[str, tuple]] = []
        for key in page_keys:
            if key in self.index:
                hits.append(("device", key))
            elif self.tiers is not None and key in self.tiers:
                hits.append(("spilled", key))
            else:
                break
        return hits


# ===========================================================================
# sequence state
# ===========================================================================

class _SimSeq:
    def __init__(self, req: SampledRequest, arrival: int, page_size: int):
        self.rid = req.rid
        self.prompt = req.prompt
        self.plen = len(req.prompt)
        self.max_new = req.max_new
        self.arrival = arrival
        ps = page_size
        self.page_keys = [tuple(req.prompt[:(i + 1) * ps])
                          for i in range(self.plen // ps)]
        self.fed = 0
        self.out = 0
        self.written = 0
        self.lane = -1
        self.cluster = -1
        self.pages: List[_Page] = []
        self.promoting = False
        self.promote_due = 0.0
        self.done = False
        self.prefix_hit_tokens = 0
        self.spec_k_cur = 0
        self.spec_credit = 0.0

    @property
    def remaining(self) -> int:
        return self.max_new - self.out


# ===========================================================================
# the engine replica
# ===========================================================================

class _SimEngine:
    def __init__(self, engine: EngineConfig, *, spec_acceptance: float):
        cache = engine.cache
        self.clusters = engine.clusters
        self.lanes_per_cluster = engine.max_lanes
        self.max_lanes = engine.max_lanes * engine.clusters
        self.chunk = engine.chunk
        self.page_size = cache.page_size
        self.enable_prefix_cache = cache.enable_prefix_cache
        self.spec_k = engine.spec_k
        self.spec_acceptance = spec_acceptance
        self.policy = engine.scheduler_policy or GreedyChunkPolicy()
        self.prefetch_depth = cache.prefetch_depth
        self.promote_latency_s = cache.promote_latency_s
        tiers = None
        if cache.host_tier_pages > 0:
            tiers = [_SimTiers(cache.host_tier_pages, cache.disk_tier_pages)
                     for _ in range(self.clusters)]
        self.tiers = tiers
        self.pools = [_SimPool(cache.num_pages, cache.page_size,
                               tiers[c] if tiers else None)
                      for c in range(self.clusters)]
        self.lanes: List[Optional[_SimSeq]] = [None] * self.max_lanes
        self.queue: List[_SimSeq] = []
        self.clock = VirtualClock()
        self.iterations = 0
        self.peak_pages = [0] * self.clusters
        self.prefill_tokens = 0
        self.generated_tokens = 0
        self.hit_pages = {"device": 0, "host": 0, "disk": 0}
        self.spec_iterations = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.iteration_log: List[IterationStats] = []
        self._events: List[Tuple[int, int, Optional[str]]] = []
        self._arrival = 0

    # ----------------------------------------------------------- lifecycle --
    def submit(self, req: SampledRequest):
        seq = _SimSeq(req, self._arrival, self.page_size)
        self._arrival += 1
        if self.spec_k:
            seq.spec_k_cur = self.spec_k
        self.queue.append(seq)

    def _pages_needed(self, seq: _SimSeq) -> int:
        total = seq.plen + seq.max_new - 1
        return -(-total // self.page_size)

    def _cow_budget(self, seq: _SimSeq) -> int:
        return 1 if (self.enable_prefix_cache and seq.max_new > 1
                     and seq.plen % self.page_size) else 0

    # ------------------------------------------------------------ admission --
    def _plan(self, seq: _SimSeq, cluster: int) -> dict:
        pool = self.pools[cluster]
        total = self._pages_needed(seq) + self._cow_budget(seq)
        ps = self.page_size
        usable, hits = 0, []
        if self.enable_prefix_cache and seq.plen > 1:
            entries = pool.match_prefix(seq.page_keys)
            usable = min(len(entries) * ps, seq.plen - 1)
            hits = entries[:-(-usable // ps)] if usable else []
        full = usable // ps
        dev_full = sum(1 for i, (kind, _k) in enumerate(hits)
                       if kind == "device" and i < full)
        need = total - dev_full
        cached = sum(1 for kind, k in hits
                     if kind == "device" and k in pool.cached_free)
        plan = {"hits": hits, "usable": usable, "need": need,
                "cached_hits": cached, "cluster": cluster}
        if hits and not self._fits(plan):
            fallback = {"hits": [], "usable": 0, "need": total,
                        "cached_hits": 0, "cluster": cluster}
            if self._fits(fallback):
                return fallback
        return plan

    def _fits(self, plan: dict) -> bool:
        return self.pools[plan["cluster"]].available() >= \
            plan["need"] + plan["cached_hits"]

    def _free_lane(self, cluster: int) -> Optional[int]:
        lo = cluster * self.lanes_per_cluster
        for i in range(lo, lo + self.lanes_per_cluster):
            if self.lanes[i] is None:
                return i
        return None

    def _admit(self):
        while self.queue:
            self.queue.sort(key=lambda r: r.arrival)
            head = self.queue[0]
            best = None
            for c in range(self.clusters):
                lane = self._free_lane(c)
                if lane is None:
                    continue
                plan = self._plan(head, c)
                if not self._fits(plan):
                    continue
                score = (plan["usable"], self.pools[c].available(), -c)
                if best is None or score > best[0]:
                    best = (score, lane, plan)
            if best is None:
                break           # single-priority: no preemption, wait
            self.queue.pop(0)
            self._place(head, best[1], best[2])

    def _place(self, seq: _SimSeq, lane: int, plan: dict):
        c = plan["cluster"]
        pool = self.pools[c]
        seq.lane = lane
        seq.cluster = c
        self.lanes[lane] = seq
        if plan["need"] > 0:
            pool.reserved[seq.rid] = \
                pool.reserved.get(seq.rid, 0) + plan["need"]
        if plan["usable"]:
            promo = 0
            for kind, key in plan["hits"]:
                if kind == "device":
                    seq.pages.append(pool.share_page(key))
                    self.hit_pages["device"] += 1
                else:
                    tier = pool.tiers.promote(key)
                    pg = pool.alloc_page(seq.rid)
                    pg.key = key
                    pool.index[key] = pg
                    seq.pages.append(pg)
                    self.hit_pages[tier] += 1
                    pool.stats["promoted"] += 1
                    promo += 1
            seq.fed = plan["usable"]
            seq.written = plan["usable"]
            seq.prefix_hit_tokens = plan["usable"]
            pool.stats["prefix_hit_tokens"] += plan["usable"]
            if promo and self.promote_latency_s > 0:
                seq.promoting = True
                seq.promote_due = self.clock.now() + \
                    self.promote_latency_s * (-(-promo //
                                                self.prefetch_depth))

    def _land_promotions(self):
        now = self.clock.now()
        for seq in self.lanes:
            if seq is not None and seq.promoting and \
                    seq.promote_due <= now:
                seq.promoting = False

    def _runnable(self) -> List[_SimSeq]:
        return [r for r in self.lanes if r is not None and not r.promoting]

    def _promoting(self) -> List[_SimSeq]:
        return [r for r in self.lanes if r is not None and r.promoting]

    # ----------------------------------------------------------- appending --
    def _append_tokens(self, seq: _SimSeq, n: int):
        """Account ``n`` KV writes, page-granular, CoW/unregister-aware."""
        pool = self.pools[seq.cluster]
        ps = self.page_size
        for _ in range(n):
            lpage = seq.written // ps
            if lpage == len(seq.pages):
                seq.pages.append(pool.alloc_page(seq.rid))
            else:
                pg = seq.pages[lpage]
                if pg.refs > 1:
                    # appending into a shared page: copy-on-write
                    new = pool.alloc_page(seq.rid)
                    pool.drop_ref(pg)
                    seq.pages[lpage] = new
                    pool.stats["cow"] += 1
                elif pg.key is not None:
                    pool.unregister(pg)   # content diverges from index
            seq.written += 1

    def _register_prompt_pages(self, seq: _SimSeq):
        if not self.enable_prefix_cache:
            return
        pool = self.pools[seq.cluster]
        ps = self.page_size
        full = min(seq.fed, seq.plen) // ps
        for i in range(full):
            pool.register(seq.pages[i], seq.page_keys[i])

    def _emit(self, seq: _SimSeq, n: int) -> Optional[str]:
        seq.out += n
        self.generated_tokens += n
        reason = FINISH_LENGTH if seq.out >= seq.max_new else None
        self._events.append((seq.rid, n, reason))
        return reason

    def _finish(self, seq: _SimSeq):
        seq.done = True
        self.pools[seq.cluster].release(seq.rid, seq.pages)
        self.lanes[seq.lane] = None

    # ----------------------------------------------------------- iteration --
    def _spec_wanted(self, active: List[_SimSeq]) -> bool:
        return bool(self.spec_k) and not self.queue and \
            all(r.fed >= r.plen for r in active)

    def _spec_iteration(self, active: List[_SimSeq]) -> bool:
        """Expected-acceptance speculative verify; returns False when no
        lane has draft headroom (the engine falls back to plain decode)."""
        lanes_k = [(r, min(r.spec_k_cur, r.remaining - 1, self.spec_k))
                   for r in active]
        if all(k <= 0 for _r, k in lanes_k):
            return False
        self.spec_iterations += 1
        n_spec = 0
        n_ctx = sum(r.written for r in active)
        for r, k in lanes_k:
            if k <= 0:
                adv = 1
            else:
                self.spec_proposed += k
                r.spec_credit += self.spec_acceptance * k
                acc = min(k, int(r.spec_credit))
                r.spec_credit -= acc
                self.spec_accepted += acc
                adv = acc + 1
                if acc == k:
                    r.spec_k_cur += 1
                elif acc == 0:
                    r.spec_k_cur = max(1, r.spec_k_cur // 2)
                n_spec += k + 1
            self._append_tokens(r, adv)
            reason = self._emit(r, adv)
            if reason:
                self._finish(r)
        self.iteration_log.append(IterationStats(
            prefill_tokens=0, decode_lanes=len(active),
            spec_tokens=n_spec, context_tokens=n_ctx,
            active_clusters=len({r.cluster for r in active})))
        return True

    def _update_peaks(self, occ0: List[int]):
        for c, pool in enumerate(self.pools):
            self.peak_pages[c] = max(self.peak_pages[c], occ0[c],
                                     pool.occupancy())

    def step(self) -> bool:
        occ0 = [p.occupancy() for p in self.pools]
        self._land_promotions()
        self._admit()
        self._land_promotions()
        active = self._runnable()
        if not active and self._promoting():
            self.clock.hold_until(
                min(r.promote_due for r in self._promoting()))
            self._land_promotions()
            self._admit()
            active = self._runnable()
        if not active:
            return bool(self.queue) or bool(self._promoting())
        self.iterations += 1

        if self._spec_wanted(active) and self._spec_iteration(active):
            self._update_peaks(occ0)
            return True

        C = self.chunk
        prefill = [(r.lane, r.plen - r.fed) for r in active
                   if r.fed < r.plen]
        alloc: dict = {}
        if prefill:
            alloc = dict(self.policy.plan(
                tuple(prefill), len(active) - len(prefill), C))
            if len(prefill) == len(active) and \
                    not any(alloc.get(ln, rem) for ln, rem in prefill):
                alloc[prefill[0][0]] = min(C, prefill[0][1])
        n_prefill = 0
        n_decode = 0
        n_ctx = sum(r.written for r in active)
        for r in list(active):
            if r.fed < r.plen:
                n = min(C, r.plen - r.fed)
                n = max(0, min(n, int(alloc.get(r.lane, n))))
                if n:
                    self._append_tokens(r, n)
                    r.fed += n
                    self.prefill_tokens += n
                    n_prefill += n
                    self._register_prompt_pages(r)
                if r.fed == r.plen:
                    reason = self._emit(r, 1)
                    if reason:
                        self._finish(r)
            else:
                self._append_tokens(r, 1)
                n_decode += 1
                reason = self._emit(r, 1)
                if reason:
                    self._finish(r)
        self.iteration_log.append(IterationStats(
            prefill_tokens=n_prefill, decode_lanes=n_decode,
            spec_tokens=0, context_tokens=n_ctx,
            active_clusters=len({r.cluster for r in active
                                 if r.cluster >= 0})))
        self._update_peaks(occ0)
        return True


# ===========================================================================
# the serve loop + report
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class SimReport:
    """Predicted serving report: the latency summary plus the capacity
    metrics the bench publishes."""
    report: dict

    def __getitem__(self, k):
        return self.report[k]


def simulate(arrivals: Sequence[SampledRequest], engine: EngineConfig, *,
             iteration_cost: IterationCost,
             spec_acceptance: float = 0.0,
             slo_ttft_s: float = 0.25, slo_tpot_s: float = 0.05,
             max_iters: int = 100_000) -> dict:
    """Replay ``arrivals`` through the simulated engine and return the
    predicted report (latency percentiles, iterations, virtual
    duration, throughput, peak page occupancy, speculation counters).

    ``iteration_cost`` prices each iteration in virtual seconds —
    a constant (the front door's ``iter_time_s`` contract) or an
    analytic roofline model (see ``repro.planner.costs``)."""
    sim = _SimEngine(engine, spec_acceptance=spec_acceptance)
    clock = sim.clock
    records: Dict[int, RequestRecord] = {}
    pending = sorted(arrivals, key=lambda a: (a.t, a.rid))
    for a in pending:
        if a.rid in records:
            raise ValueError(f"duplicate rid {a.rid}")
        records[a.rid] = RequestRecord(rid=a.rid, arrive_t=a.t)
    pending = list(pending)
    it = 0
    while True:
        now = clock.now()
        while pending and pending[0].t <= now:
            a = pending.pop(0)
            records[a.rid].submit_t = now
            sim.submit(a)
        before = sim.iterations
        busy = sim.step()
        if sim.iterations > before:
            dt = iteration_cost(sim.iteration_log[-1])
            if dt:
                clock.advance(dt)
        now = clock.now()
        for rid, n, reason in sim._events:
            rec = records[rid]
            if n and rec.first_token_t is None:
                rec.first_token_t = now
            rec.tokens += n
            if reason is not None:
                rec.finish_t = now
                rec.finish_reason = reason
        sim._events.clear()
        if not busy:
            if not pending:
                break
            clock.hold_until(pending[0].t)
            continue
        it += 1
        if it >= max_iters:
            break

    rep = latency_report(records, slo_ttft_s=slo_ttft_s,
                         slo_tpot_s=slo_tpot_s)
    duration = round(clock.now(), 9)
    rep["iterations"] = sim.iterations
    rep["virtual_duration_s"] = duration
    rep["throughput_rps"] = round(rep["completed"] / duration, 9) \
        if duration > 0 else 0.0
    rep["generated_tokens"] = sim.generated_tokens
    rep["prefill_tokens"] = sim.prefill_tokens
    rep["prefix_hit_tokens"] = sum(p.stats["prefix_hit_tokens"]
                                   for p in sim.pools)
    rep["iters_per_generated_token"] = (
        sim.iterations / sim.generated_tokens
        if sim.generated_tokens else 0.0)
    for c in range(sim.clusters):
        occ = sim.pools[c].occupancy()
        sim.peak_pages[c] = max(sim.peak_pages[c], occ)
    rep["peak_pages_per_cluster"] = _peaks(sim)
    rep["hits_device_pages"] = sim.hit_pages["device"]
    rep["hits_host_pages"] = sim.hit_pages["host"]
    rep["hits_disk_pages"] = sim.hit_pages["disk"]
    rep["demoted_pages"] = sum(p.stats["demoted"] for p in sim.pools)
    rep["promoted_pages"] = sum(p.stats["promoted"] for p in sim.pools)
    rep["spec_iterations"] = sim.spec_iterations
    rep["spec_proposed"] = sim.spec_proposed
    rep["spec_accepted"] = sim.spec_accepted
    return rep


def _peaks(sim: _SimEngine) -> List[int]:
    return list(sim.peak_pages)
