"""Workload and SLO schema shared by the load generator and the planner.

:class:`WorkloadSpec` is THE description of an open-loop serving
workload: Poisson arrivals at ``rate_rps``, uniform prompt/output
length distributions, an optional shared-prefix fraction and an
expected speculative acceptance rate.  ``benchmarks/load_gen.py``
builds its arrival schedule from this spec and the planner's simulator
replays the *same* schedule analytically — one schema, two consumers,
so a prediction and a measurement always describe the same traffic.

Determinism contract: :meth:`WorkloadSpec.sample_arrivals` draws from
``numpy.random.default_rng(seed)`` in a fixed per-request order
(interarrival gap, prompt length, output budget, prompt tokens), which
for ``prefix_share_ratio == 0`` is bit-for-bit the order the historical
``load_gen.make_arrivals`` used — same seed, same schedule, byte-
identical ``--selfcheck`` reports.  A non-zero ``prefix_share_ratio``
adds draws (one shared-prefix block up front, one uniform per request)
without disturbing the zero-ratio stream.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["WorkloadSpec", "SLOSpec", "SampledRequest"]


@dataclasses.dataclass(frozen=True)
class SampledRequest:
    """One sampled arrival: everything the engine-independent schedule
    knows about a request."""
    rid: int
    t: float                      # arrival time, virtual seconds
    prompt: Tuple[int, ...]
    max_new: int


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Frozen open-loop workload description.

    ``prefix_share_ratio`` is the fraction of requests whose prompt
    begins with one shared block of ``prompt_min`` tokens (a system-
    prompt population for the prefix cache); ``spec_acceptance_rate``
    is the drafter acceptance probability the speculation model should
    assume.  Both default to 0 — the plain load-gen workload."""
    rate_rps: float
    requests: int
    prompt_min: int
    prompt_max: int
    output_min: int
    output_max: int
    prefix_share_ratio: float = 0.0
    spec_acceptance_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.rate_rps <= 0:
            raise ValueError("arrival rate must be > 0")
        if self.requests < 1:
            raise ValueError("need at least one request")
        if not (1 <= self.prompt_min <= self.prompt_max):
            raise ValueError("need 1 <= prompt_min <= prompt_max")
        if not (1 <= self.output_min <= self.output_max):
            raise ValueError("need 1 <= output_min <= output_max")
        if not (0.0 <= self.prefix_share_ratio <= 1.0):
            raise ValueError("prefix_share_ratio must be in [0, 1]")
        if not (0.0 <= self.spec_acceptance_rate <= 1.0):
            raise ValueError("spec_acceptance_rate must be in [0, 1]")

    # ------------------------------------------------------------ sampling --
    def sample_arrivals(self, vocab: int) -> List[SampledRequest]:
        """Seeded arrival schedule (see the module docstring for the
        draw-order contract)."""
        if vocab < 2:
            raise ValueError("vocab must be >= 2")
        rng = np.random.default_rng(self.seed)
        shared: Tuple[int, ...] = ()
        if self.prefix_share_ratio > 0:
            shared = tuple(int(x) for x in
                           rng.integers(1, vocab, size=self.prompt_min))
        out: List[SampledRequest] = []
        t = 0.0
        for rid in range(self.requests):
            t += float(rng.exponential(1.0 / self.rate_rps))
            plen = int(rng.integers(self.prompt_min, self.prompt_max + 1))
            max_new = int(rng.integers(self.output_min, self.output_max + 1))
            if shared and float(rng.random()) < self.prefix_share_ratio:
                head = shared[:min(plen, len(shared))]
                tail = tuple(int(x) for x in
                             rng.integers(1, vocab, size=plen - len(head)))
                prompt = head + tail
            else:
                prompt = tuple(int(x) for x in
                               rng.integers(1, vocab, size=plen))
            out.append(SampledRequest(rid=rid, t=round(t, 9),
                                      prompt=prompt, max_new=max_new))
        return out

    # -------------------------------------------------------- serialization --
    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "WorkloadSpec":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown WorkloadSpec fields: {sorted(unknown)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Serving service-level objective the planner inverts against.

    ``plan_capacity`` judges a candidate config by its *predicted*
    p95 TTFT/TPOT (and completion of every offered request) — both
    SLO-independent metrics of the simulated report, so tightening the
    SLO can only shrink the feasible set, never reorder it."""
    ttft_p95_s: float
    tpot_p95_s: float

    def __post_init__(self):
        if self.ttft_p95_s <= 0 or self.tpot_p95_s <= 0:
            raise ValueError("SLO bounds must be > 0")

    def met_by(self, report: dict) -> bool:
        return (report["completed"] == report["requests"]
                and report["ttft_p95_s"] <= self.ttft_p95_s
                and report["tpot_p95_s"] <= self.tpot_p95_s)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "SLOSpec":
        return cls(**d)
