from repro.runtime.trainer import Trainer, TrainerConfig, FailureInjector
from repro.runtime.server import PagedServer, Request
from repro.runtime.sharded_server import ShardedPagedServer
from repro.runtime.speculative import (
    Drafter, NGramDrafter, DraftModelDrafter,
)

__all__ = ["Trainer", "TrainerConfig", "FailureInjector", "PagedServer",
           "Request", "ShardedPagedServer", "Drafter", "NGramDrafter",
           "DraftModelDrafter"]
