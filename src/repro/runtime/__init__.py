from repro.runtime.trainer import Trainer, TrainerConfig, FailureInjector
from repro.runtime.api import (
    CacheConfig, CacheStats, EngineConfig, GenerationRequest,
    GenerationResult, SamplingParams, TokenDelta, make_engine,
    FINISH_STOP, FINISH_LENGTH, FINISH_ABORTED,
    FINISH_TIMEOUT, FINISH_ERROR, FINISH_SHED,
)
from repro.runtime.clock import Clock, MonotonicClock, VirtualClock
from repro.runtime.faults import FaultInjector, FaultSpec
from repro.runtime.frontdoor import (
    Arrival, FrontDoor, GreedyChunkPolicy, RequestRecord, SchedulerPolicy,
    TokenBudgetPolicy, latency_report,
)
from repro.runtime.server import PagedServer
from repro.runtime.sharded_server import ShardedPagedServer
from repro.runtime.speculative import (
    Drafter, NGramDrafter, DraftModelDrafter,
)

__all__ = ["Trainer", "TrainerConfig", "FailureInjector", "PagedServer",
           "ShardedPagedServer", "Drafter", "NGramDrafter",
           "DraftModelDrafter", "CacheConfig", "CacheStats",
           "EngineConfig", "GenerationRequest",
           "GenerationResult", "SamplingParams", "TokenDelta",
           "make_engine", "FINISH_STOP", "FINISH_LENGTH",
           "FINISH_ABORTED", "FINISH_TIMEOUT", "FINISH_ERROR",
           "FINISH_SHED", "FaultInjector", "FaultSpec",
           "Clock", "MonotonicClock", "VirtualClock",
           "Arrival", "FrontDoor", "RequestRecord", "SchedulerPolicy",
           "GreedyChunkPolicy", "TokenBudgetPolicy", "latency_report"]
