from repro.runtime.trainer import Trainer, TrainerConfig, FailureInjector
from repro.runtime.server import PagedServer, Request

__all__ = ["Trainer", "TrainerConfig", "FailureInjector", "PagedServer",
           "Request"]
