"""Unified generation API for the paged serving engines.

HERO's value is a *platform*: a stable host-side API over a configurable
PMCA, so new workloads run without touching engine internals (§2.2; HEROv2
doubles down on exactly this full-stack programmability).  This module is
the serving-side front door in that spirit — every knob and every request
flows through four small frozen dataclasses plus one factory:

* :class:`EngineConfig` — every pool / scheduler / kernel / speculation /
  mesh knob in one spec.  Both :class:`~repro.runtime.PagedServer` and
  :class:`~repro.runtime.ShardedPagedServer` consume it (the pre-API
  keyword sprawl and the ``Request`` shim are gone — old kwargs now
  raise ``TypeError``), and :func:`make_engine` picks the engine class
  from the spec.  The spec also names the engine's *time source*
  (``clock`` — a :class:`~repro.runtime.clock.Clock`; virtual in
  tests/benchmarks so deadlines, retry backoff and latency metrics
  replay exactly) and the chunked-prefill/decode interleave
  (``scheduler_policy`` — a
  :class:`~repro.runtime.frontdoor.SchedulerPolicy`).
* :class:`SamplingParams` — per-request decoding policy: temperature,
  top-k, top-p nucleus truncation, PRNG seed, stop tokens and the token
  budget.  ``temperature == 0`` is exact greedy argmax (byte-identical to
  the pre-sampling engine); ``temperature > 0`` samples **on device**
  inside the jitted steps, with a per-lane PRNG key folded by absolute
  sequence position — so a request's stream is reproducible from its seed
  alone, independent of chunking, scheduling, preemption or sharding.
* :class:`GenerationRequest` / :class:`GenerationResult` — the immutable
  user-facing request/result pair.  Results carry a ``finish_reason``
  (``"stop"`` / ``"length"`` / ``"aborted"``); scheduler-internal mutable
  state lives in the private ``SeqState`` and never leaks to callers.
* :class:`TokenDelta` — the streaming unit: ``engine.generate(requests)``
  yields one delta per request-visible step (new tokens, prefix-cache
  hits, preemptions, speculation verdicts), and the concatenation of a
  request's token deltas is exactly its final result's token tuple.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

from repro.core.rab import RABConfig

__all__ = [
    "EngineConfig", "CacheConfig", "CacheStats", "SamplingParams",
    "GenerationRequest", "GenerationResult", "TokenDelta", "make_engine",
    "FINISH_STOP", "FINISH_LENGTH", "FINISH_ABORTED",
    "FINISH_TIMEOUT", "FINISH_ERROR", "FINISH_SHED",
]

#: finish reasons a GenerationResult can carry
FINISH_STOP = "stop"          # a stop token was emitted
FINISH_LENGTH = "length"      # max_new tokens generated
FINISH_ABORTED = "aborted"    # run() iteration cap, or engine.cancel(rid)
FINISH_TIMEOUT = "timeout"    # deadline_iters / deadline_s exceeded
FINISH_ERROR = "error"        # per-request fault demotion (engine survives)
FINISH_SHED = "shed"          # rejected at admission under overload


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy, applied on device.

    ``temperature == 0`` selects exact greedy argmax — the historical
    engine behaviour, byte-identical, and the only mode speculative
    drafting engages for (greedy verification is what makes the PR 4
    parity guarantee structural).  ``temperature > 0`` divides the logits
    by the temperature, applies top-k then top-p truncation, and samples
    with a per-lane PRNG key derived as
    ``fold_in(PRNGKey(seed), position)`` — deterministic per (seed,
    position) no matter how the scheduler interleaves, chunks, preempts
    or shards the request.
    """
    temperature: float = 0.0    # 0 = greedy argmax
    top_k: int = 0              # 0 disables top-k truncation
    top_p: float = 1.0          # 1.0 disables nucleus truncation
    seed: int = 0               # per-request PRNG seed
    stop_tokens: Tuple[int, ...] = ()   # any of these ends the request
    max_new: int = 16           # generated-token budget

    def __post_init__(self):
        object.__setattr__(self, "stop_tokens", tuple(self.stop_tokens))
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """What a caller submits: prompt + policy.  Immutable — the engine
    keeps its mutable bookkeeping in a private ``SeqState``.

    ``deadline_iters`` bounds the request's lifetime in *engine
    iterations* from submission (deterministic; benchmark-friendly);
    ``deadline_s`` bounds it in wall-clock seconds.  Either expiring
    finishes the request with ``finish_reason="timeout"`` — its pages are
    released through the same refcount/CoW/reservation-aware path as
    preemption, and tokens generated so far are kept."""
    rid: int
    prompt: Tuple[int, ...]
    sampling: SamplingParams = SamplingParams()
    priority: int = 0           # scheduler class; higher preempts lower
    deadline_iters: Optional[int] = None    # engine-iteration budget
    deadline_s: Optional[float] = None      # wall-clock budget (seconds)

    def __post_init__(self):
        object.__setattr__(self, "prompt", tuple(self.prompt))
        if self.deadline_iters is not None and self.deadline_iters < 1:
            raise ValueError("deadline_iters must be >= 1")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0")


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    """What a caller gets back: tokens + why generation ended + the
    request's scheduler/speculation statistics."""
    rid: int
    prompt: Tuple[int, ...]
    tokens: Tuple[int, ...]
    finish_reason: str          # one of the FINISH_* constants
    prefix_hit_tokens: int = 0
    preemptions: int = 0
    cluster: int = 0
    spec_proposed: int = 0
    spec_accepted: int = 0
    spec_rejected: int = 0
    spec_k_final: int = 0       # adaptive draft depth when the request ended
    error: Optional[str] = None  # diagnostic for FINISH_ERROR / FINISH_TIMEOUT

    @property
    def out(self):
        """Token list, matching the old mutable ``Request.out`` shape."""
        return list(self.tokens)


@dataclasses.dataclass(frozen=True)
class TokenDelta:
    """One streamed increment from ``engine.generate()``.

    ``event`` is ``"token"`` (plain decode/prefill emission), ``"spec"``
    (a draft-verify iteration; ``data`` = accepted draft count),
    ``"prefix_hit"`` (``data`` = prompt tokens served from the cache),
    ``"preempt"`` (``data`` = pages swapped out), or one of the
    terminal failure events — ``"abort"`` (iteration cap), ``"cancel"``
    (user ``engine.cancel(rid)``), ``"timeout"`` (deadline), ``"error"``
    (fault demotion) and ``"shed"`` (admission-time overload rejection).
    ``finish_reason`` is set on the delta that ends the request; the
    concatenation of a request's ``tokens`` across its deltas equals the
    final :class:`GenerationResult.tokens`.
    """
    rid: int
    tokens: Tuple[int, ...] = ()
    event: str = "token"
    data: int = 0
    finish_reason: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Paged KV-cache spec: the device pool plus the tiered spill hierarchy
    (HERO SVM: scratchpad -> host DRAM -> storage, each level larger and
    slower).  Lives at :attr:`EngineConfig.cache`.

    ``host_tier_pages > 0`` turns spill on: prefix-index entries evicted
    from the device pool demote their payload to a host tier (and, under
    host pressure, to a ``disk_tier_pages``-capped disk tier under
    ``disk_dir``) instead of vanishing, and an admission-time hit on a
    spilled entry promotes it back.  Promotion completes asynchronously on
    the engine clock: a batch of ``prefetch_depth`` pages costs one
    ``promote_latency_s`` quantum, during which the admitted request waits
    (other lanes keep decoding) — under a ``VirtualClock`` the schedule
    replays byte-identically.

    ``kv_dtype`` selects the page representation: ``"bf16"`` stores pages
    in the model's parameter dtype (exact), ``"int8"`` stores quantized
    pages with one float32 scale per (page, K/V, kv-head) riding beside
    the pool — the fused scatter quantizes at write, the attention kernels
    dequantize inside the K/V fetch, and attention math stays fp32.  The
    quantized form flows through CoW, speculative trim, preemption swap
    and tier demote/promote unchanged (spilled payloads carry page bytes +
    scales under one checksum)."""
    num_pages: int = 64             # device pool capacity (per cluster)
    page_size: int = 8              # tokens per KV page
    max_pages_per_seq: int = 16     # logical address space per sequence
    enable_prefix_cache: bool = True
    kv_dtype: str = "bf16"          # "bf16" (exact) | "int8" (quantized)
    host_tier_pages: int = 0        # 0 = spill off (entries drop on evict)
    disk_tier_pages: int = 0        # 0 = no disk tier below the host tier
    disk_dir: Optional[str] = None  # None -> store-owned temp dir
    prefetch_depth: int = 4         # pages promoted per latency quantum
    promote_latency_s: float = 0.0  # modeled H2D promotion quantum

    def __post_init__(self):
        if min(self.num_pages, self.page_size, self.max_pages_per_seq) < 1:
            raise ValueError("num_pages, page_size and max_pages_per_seq "
                             "must all be >= 1")
        if self.host_tier_pages < 0 or self.disk_tier_pages < 0:
            raise ValueError("tier capacities must be >= 0")
        if self.disk_tier_pages and not self.host_tier_pages:
            raise ValueError("disk_tier_pages requires host_tier_pages > 0 "
                             "(the disk tier hangs below the host tier)")
        if self.prefetch_depth < 1:
            raise ValueError("prefetch_depth must be >= 1")
        if self.promote_latency_s < 0:
            raise ValueError("promote_latency_s must be >= 0")
        if self.kv_dtype not in ("bf16", "int8"):
            raise ValueError(
                f"kv_dtype must be 'bf16' or 'int8', got {self.kv_dtype!r}")

    @property
    def spill_enabled(self) -> bool:
        return self.host_tier_pages > 0


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """A frozen snapshot of the cache hierarchy, from
    ``engine.cache_stats()`` — the public replacement for poking
    ``pool.cached_free`` / backing-store internals.

    Hit counts are in *pages served at admission*, split by the tier the
    page was resident in when the request hit it; ``miss_pages`` counts
    prompt pages that had to prefill fresh.  Byte counters measure payload
    traffic crossing tier boundaries in each direction.
    ``bytes_per_token`` is the KV-cache footprint of one resident token
    across all layers (page bytes plus the amortized per-page scale slab
    in int8 mode) — the quantization win reads directly off the ratio of
    two engines' values."""
    device_pages: int = 0           # device pool capacity (all clusters)
    device_indexed: int = 0         # prefix entries resident on device
    device_cached_free: int = 0     # ... of which parked on the LRU
    host_pages: int = 0             # cache entries resident in host tier
    disk_pages: int = 0             # cache entries resident in disk tier
    hits_device_pages: int = 0
    hits_host_pages: int = 0
    hits_disk_pages: int = 0
    miss_pages: int = 0
    prefix_hit_tokens: int = 0      # prompt tokens served from any tier
    promotions_in_flight: int = 0   # scheduled, not yet landed
    demoted_pages: int = 0          # device -> down-tier parks
    promoted_pages: int = 0         # down-tier -> device restores
    dropped_entries: int = 0        # lost off the bottom tier / fetch fault
    bytes_demoted: int = 0
    bytes_promoted: int = 0
    evictions: int = 0              # device LRU evictions (spill or drop)
    bytes_per_token: float = 0.0    # KV bytes/resident token, all layers


#: EngineConfig fields that moved into CacheConfig (PR 8); accepted flat
#: for one release behind a DeprecationWarning.
_CACHE_FLAT = ("num_pages", "page_size", "max_pages_per_seq",
               "enable_prefix_cache")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every engine knob in one spec (HERO: one platform configuration
    drives the whole PMCA instantiation).

    ``clusters`` / ``heads`` / ``mesh`` / ``sharded`` select the engine
    class through :func:`make_engine`: any multi-cluster, head-sharded or
    explicitly ``sharded`` spec builds a ``ShardedPagedServer`` (where
    ``cache.num_pages`` and ``max_lanes`` are per cluster), everything
    else the plain ``PagedServer``.

    Cache knobs live in the nested frozen :class:`CacheConfig` at
    ``cache``.  The old flat spellings (``num_pages``, ``page_size``,
    ``max_pages_per_seq``, ``enable_prefix_cache``) are accepted for one
    release: a flat value that differs from ``cache``'s emits a
    ``DeprecationWarning`` and is folded in; after normalization the flat
    fields mirror ``cache`` so legacy readers keep working and
    ``dataclasses.replace`` round-trips silently."""
    # pool / cache hierarchy (flat fields are the deprecated spellings)
    num_pages: Optional[int] = None             # DEPRECATED -> cache
    page_size: Optional[int] = None             # DEPRECATED -> cache
    max_pages_per_seq: Optional[int] = None     # DEPRECATED -> cache
    rab_cfg: RABConfig = RABConfig(l1_entries=8, l2_entries=32,
                                   l2_assoc=4, l2_banks=2)
    enable_prefix_cache: Optional[bool] = None  # DEPRECATED -> cache
    cache: Optional[CacheConfig] = None         # None -> CacheConfig()
    # scheduler
    max_lanes: int = 4
    chunk: int = 16
    clock: Optional[object] = None      # runtime.clock.Clock; None -> the
    #                                     wall MonotonicClock.  Every
    #                                     scheduler timestamp (deadline_s,
    #                                     retry backoff, straggler EMA)
    #                                     reads this source
    scheduler_policy: Optional[object] = None   # frontdoor.SchedulerPolicy;
    #                                     None -> GreedyChunkPolicy (the
    #                                     historical prefill interleave)
    # kernels
    use_kernel: bool = True
    pages_per_step: int = 2
    # speculation
    spec_k: int = 0
    drafter: Optional[object] = None    # runtime.speculative.Drafter
    # mesh (sharded engine only)
    clusters: int = 1
    heads: int = 1
    mesh: Optional[object] = None       # launch.mesh.ClusterMesh
    sharded: bool = False               # force ShardedPagedServer at C=H=1
    # fault tolerance
    fault_injector: Optional[object] = None  # runtime.faults.FaultInjector
    swap_retries: int = 3               # retry budget for transient faults
    retry_backoff_s: float = 0.0        # 0 -> transient swap-in faults
    #                                     retry immediately (in-place);
    #                                     > 0 -> the resume is DEFERRED on
    #                                     the engine clock (base delay,
    #                                     doubled per attempt) while other
    #                                     lanes keep decoding — the engine
    #                                     loop never sleeps
    max_queue_depth: int = 0            # 0 = unbounded; else shed overload
    watchdog_iters: int = 0             # 0 = off; abort lanes stalled
    #                                     this many iterations
    straggler_factor: float = 0.0       # 0 = off; EMA multiple that flags
    #                                     a straggler engine iteration

    def __post_init__(self):
        cache = self.cache if self.cache is not None else CacheConfig()
        legacy = {}
        for f in _CACHE_FLAT:
            v = getattr(self, f)
            if v is not None and v != getattr(cache, f):
                legacy[f] = v
        if legacy:
            warnings.warn(
                "EngineConfig(%s): flat cache knobs are deprecated; pass "
                "EngineConfig(cache=CacheConfig(...)) instead"
                % ", ".join(sorted(legacy)),
                DeprecationWarning, stacklevel=3)
            cache = dataclasses.replace(cache, **legacy)
        object.__setattr__(self, "cache", cache)
        # mirror back: legacy readers see one consistent spec, and
        # dataclasses.replace() (which re-passes the mirrored values next
        # to `cache`) round-trips without re-warning
        for f in _CACHE_FLAT:
            object.__setattr__(self, f, getattr(cache, f))

    @property
    def wants_sharded(self) -> bool:
        return (self.sharded or self.clusters > 1 or self.heads > 1
                or self.mesh is not None)


def make_engine(cfg, params, engine_cfg: Optional[EngineConfig] = None, *,
                tracer=None):
    """Build the right engine for ``engine_cfg`` (default spec if None).

    One factory, both engines: a spec with ``clusters > 1``, ``heads > 1``,
    an explicit ``mesh`` or ``sharded=True`` returns a
    ``ShardedPagedServer``; anything else the unsharded ``PagedServer``.
    """
    from repro.runtime.server import PagedServer
    from repro.runtime.sharded_server import ShardedPagedServer

    engine_cfg = engine_cfg or EngineConfig()
    cls = ShardedPagedServer if engine_cfg.wants_sharded else PagedServer
    return cls(cfg, params, engine_cfg, tracer=tracer)
