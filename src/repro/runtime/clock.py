"""Injectable engine clock: one time source for the whole scheduler.

HERO separates the stable host-side driver/runtime from the accelerator
engine; the host side owns *time* — deadlines, retry backoff, arrival
processes.  This module makes that time source explicit and injectable:
every scheduler-visible timestamp (``deadline_s`` binding, swap-retry
backoff deadlines, straggler EMA deltas, the front door's arrival clock)
flows through one :class:`Clock` object instead of raw ``time.monotonic()``
/ ``time.sleep()`` calls scattered through the tick path.

Two implementations:

* :class:`MonotonicClock` — production wall clock.  ``now()`` is
  ``time.monotonic()``; ``hold_until`` really waits (it is only ever
  called when the engine has nothing else to do — no active lane may be
  stalled behind it).
* :class:`VirtualClock` — deterministic test/bench clock.  Time moves
  only when somebody calls :meth:`VirtualClock.advance` (the front door
  charges a fixed ``iter_time_s`` per engine iteration) or
  ``hold_until`` jumps it forward.  Two runs with the same schedule of
  advances see byte-identical timestamps, so wall-clock-shaped metrics
  (TTFT, TPOT, deadline sweeps, retry backoff) replay exactly.
"""
from __future__ import annotations

import time

__all__ = ["Clock", "MonotonicClock", "VirtualClock"]


class Clock:
    """Engine time source.  ``now()`` returns seconds on an arbitrary
    monotonic axis; ``hold_until(t)`` parks the *caller* until ``now()``
    reaches ``t`` — the engine only calls it when fully idle (no active
    lane, every waiter deferred), so a hold can never stall live work."""

    def now(self) -> float:
        raise NotImplementedError

    def hold_until(self, t: float) -> None:
        raise NotImplementedError


class MonotonicClock(Clock):
    """Real wall clock (``time.monotonic``).  The default when an
    :class:`~repro.runtime.EngineConfig` names no clock."""

    #: cap a single hold so a wildly future deadline cannot wedge the
    #: process; the engine re-polls and holds again if still idle
    max_hold_s = 0.05

    def now(self) -> float:
        return time.monotonic()

    def hold_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(min(dt, self.max_hold_s))


class VirtualClock(Clock):
    """Deterministic clock for tests and benchmarks: time is a number
    this object owns, moved only by :meth:`advance` / :meth:`hold_until`.
    Never moves backwards."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds and return the new time."""
        if dt < 0:
            raise ValueError("a clock cannot move backwards")
        self._t += dt
        return self._t

    def hold_until(self, t: float) -> None:
        if t > self._t:
            self._t = t
