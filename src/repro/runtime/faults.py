"""Seeded, deterministic fault injection for the serving stack.

HERO's validation story (§1, §3.4) is that a heterogeneous platform is
only trustworthy when its run-time behavior can be *perturbed and
re-tested* through fully automated runs — the same tracing that explains a
healthy run must explain a faulted one.  This module is the perturbation
half: a :class:`FaultInjector` hooks into the host backing store's swap
path (``core.offload.HostBackingStore``) and injects three fault kinds

* ``"io"``       — the swap op raises a :class:`BackingStoreError`
                   (transient unless the site is marked persistent, so the
                   engine's bounded retry+backoff can recover it);
* ``"corrupt"``  — the parked payload is silently bit-flipped *after* the
                   store checksums it; the damage surfaces at swap-in as a
                   checksum mismatch (always persistent: retrying cannot
                   un-rot host DRAM);
* ``"stall"``    — the op completes, but only after a configurable sleep
                   (a slow store; exercises deadline/watchdog paths).

Determinism contract: fault decisions are a pure function of the injector
seed and the *order* of backing-store operations.  The engine is
single-threaded and schedules deterministically, so a seeded fault storm
is exactly reproducible — the property the fault-storm benchmark's
survivor-parity check relies on.  Persistent faults are keyed by
``(op, rid, lpage)`` so every retry of the same swap op keeps failing.

Two planning modes compose:

* **rate mode** — each op draws from a seeded ``numpy`` Generator and
  fires one of ``kinds`` with probability ``rate``;
* **plan mode** — an explicit ``{op_index: FaultSpec}`` map pins faults to
  exact operations (unit tests; regression-exact storms).

Every injected fault is traced as ``EventType.FAULT_INJECT`` with
``a0 = rid`` and ``a1 = kind code (1=io, 2=corrupt, 3=stall) + 8 if
persistent``, so ``core.analysis.layer2_fault_recovery`` can stitch the
full injected-vs-recovered story from the trace alone.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.offload import BackingStoreError
from repro.core.tracing import EventType, TraceBuffer

FAULT_IO = "io"
FAULT_CORRUPT = "corrupt"
FAULT_STALL = "stall"

KIND_CODES = {FAULT_IO: 1, FAULT_CORRUPT: 2, FAULT_STALL: 3}
CODE_KINDS = {v: k for k, v in KIND_CODES.items()}
PERSISTENT_FLAG = 8


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One plannable fault.  ``op`` restricts it to ``"put"``/``"pop"``
    (``"any"`` matches both); ``persistent`` pins the fault to its
    (op, rid, lpage) site so retries keep failing."""
    kind: str = FAULT_IO
    op: str = "any"
    persistent: bool = False
    stall_s: float = 0.0

    def __post_init__(self):
        if self.kind not in KIND_CODES:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.op not in ("put", "pop", "any"):
            raise ValueError(f"unknown fault op {self.op!r}")


class FaultInjector:
    """Deterministic fault plan over the backing store's swap ops.

    The store calls :meth:`before` ahead of every ``put``/``pop``; the
    injector either returns ``None`` (op proceeds), returns the
    :class:`FaultSpec` (corruption: the store mangles the payload after
    checksumming), sleeps (stall) or raises :class:`BackingStoreError`
    (I/O fault).  ``max_faults`` bounds a storm; counters and the
    optional ``tracer`` make every decision observable."""

    def __init__(self, *, seed: int = 0, rate: float = 0.0,
                 kinds: Tuple[FaultSpec, ...] = (FaultSpec(),),
                 plan: Optional[Dict[int, FaultSpec]] = None,
                 tracer: Optional[TraceBuffer] = None,
                 max_faults: Optional[int] = None):
        if not 0.0 <= rate <= 1.0:
            raise ValueError("rate must be within [0, 1]")
        self.rng = np.random.default_rng(seed)
        self.rate = rate
        self.kinds = tuple(kinds)
        self.plan = dict(plan or {})
        self.tracer = tracer
        self.max_faults = max_faults
        self.ops = 0                       # backing-store ops observed
        self.injected = 0                  # faults actually fired
        self.by_kind = {k: 0 for k in KIND_CODES}
        self._persistent: Dict[Tuple[str, int, int], FaultSpec] = {}

    # ------------------------------------------------------------------
    def _draw(self, idx: int, op: str) -> Optional[FaultSpec]:
        spec = self.plan.get(idx)
        if spec is None and self.rate and self.kinds:
            # both draws happen unconditionally so the rng stream depends
            # only on the op count, not on which faults fired before
            u = self.rng.random()
            j = int(self.rng.integers(len(self.kinds)))
            if u < self.rate:
                spec = self.kinds[j]
        if spec is None:
            return None
        if spec.op not in ("any", op):
            return None
        if spec.kind == FAULT_CORRUPT and op != "put":
            # corruption is a park-time phenomenon; on the restore side the
            # equivalent disruption is an I/O fault of the same persistence
            spec = FaultSpec(FAULT_IO, op, persistent=spec.persistent)
        return spec

    def before(self, op: str, rid: int, lpage: int) -> Optional[FaultSpec]:
        """Fault decision for one swap op.  Returns the spec for faults the
        *store* must apply (corruption), ``None`` for clean ops and stalls
        (which sleep here), and raises for I/O faults."""
        idx = self.ops
        self.ops += 1
        site = (op, rid, lpage)
        spec = self._persistent.get(site)
        if spec is None:
            if self.max_faults is not None and \
                    self.injected >= self.max_faults:
                return None
            spec = self._draw(idx, op)
            if spec is None:
                return None
            if spec.persistent:
                self._persistent[site] = spec
        self.injected += 1
        self.by_kind[spec.kind] += 1
        if self.tracer is not None:
            code = KIND_CODES[spec.kind] + \
                (PERSISTENT_FLAG if spec.persistent else 0)
            self.tracer.record_host(EventType.FAULT_INJECT, rid, code)
        if spec.kind == FAULT_STALL:
            if spec.stall_s > 0:
                time.sleep(spec.stall_s)
            return None
        if spec.kind == FAULT_CORRUPT:
            return spec
        raise BackingStoreError(rid, lpage, op, FAULT_IO,
                                transient=not spec.persistent,
                                detail="injected I/O fault")

    # ------------------------------------------------------------------
    def report(self) -> dict:
        return {
            "ops": self.ops,
            "injected": self.injected,
            "by_kind": dict(self.by_kind),
            "persistent_sites": len(self._persistent),
        }
