"""Host-side serving front door: continuous batching under live traffic.

HERO's software stack keeps a stable host driver/runtime in front of the
accelerator engine (§2.2) — applications talk to the host side, which
feeds the PMCA a continuous stream of work.  This module is that front
door for the paged serving engine: requests *arrive* over time instead of
being handed over as one closed batch, and the engine admits them
per-iteration through its mid-loop ``submit()`` while already-running
lanes keep streaming ``TokenDelta``\\ s.

Three pieces:

* **Scheduler policies** (:class:`SchedulerPolicy`) — the chunked-prefill
  / decode interleave as an explicit object.  Per engine iteration the
  engine asks the policy how many prompt tokens each prefill-phase lane
  may feed; decode lanes always advance exactly one token (the decode
  step force-feeds every active lane, so a policy cannot starve one).
  :class:`GreedyChunkPolicy` reproduces the historical behaviour
  (every prefill lane takes ``min(chunk, remaining)``);
  :class:`TokenBudgetPolicy` caps the *total* tokens fed per iteration,
  decode-first — under prefill pressure running lanes keep their
  time-per-output-token while prompt chunks squeeze into the leftover
  budget (possibly 0 tokens for a starved prefill lane that iteration).
* **FrontDoor** — drives ``engine.step()`` against a schedule of timed
  arrivals on the engine's injected :class:`~repro.runtime.clock.Clock`:
  due requests are submitted, one engine iteration runs, a
  :class:`~repro.runtime.clock.VirtualClock` is charged a fixed
  ``iter_time_s``, and the delta stream is folded into per-request
  latency records (arrival, admission, first token, finish).
* **Latency accounting** — :func:`latency_report` turns the records into
  the serving-latency summary the benchmark publishes: p50/p95/p99 TTFT
  (time to first token, from *arrival*) and TPOT (time per output token
  after the first), plus **SLO goodput** — the fraction of all offered
  requests that completed normally (``stop``/``length``) within BOTH the
  TTFT and TPOT service-level objectives.  On a virtual clock every
  number is a pure function of (workload seed, engine config), so two
  same-seed runs are byte-identical.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.runtime.api import (
    FINISH_LENGTH, FINISH_STOP, GenerationRequest,
)

__all__ = [
    "SchedulerPolicy", "GreedyChunkPolicy", "TokenBudgetPolicy",
    "Arrival", "RequestRecord", "FrontDoor", "latency_report",
]


# ===========================================================================
# scheduler policies: the prefill/decode interleave as an object
# ===========================================================================

class SchedulerPolicy:
    """Per-iteration prefill token allocation.

    ``plan(prefill, n_decode, chunk)`` receives the prefill-phase lanes as
    ``(lane, remaining_prompt_tokens)`` pairs (admission order), the count
    of decode-phase lanes (each of which always advances one token), and
    the engine's chunk size; it returns ``{lane: tokens}``.  The engine
    clips every entry to ``[0, min(chunk, remaining)]``, treats a missing
    lane as ``min(chunk, remaining)``, and guarantees forward progress
    when every active lane is prefill-phase and the policy allocated
    nothing."""

    def plan(self, prefill: Sequence[Tuple[int, int]], n_decode: int,
             chunk: int) -> Dict[int, int]:
        raise NotImplementedError


class GreedyChunkPolicy(SchedulerPolicy):
    """The historical interleave, unchanged: every prefill lane consumes
    ``min(chunk, remaining)`` — prefill and decode are not budget-coupled,
    so a prefill burst can lengthen running lanes' token cadence."""

    def plan(self, prefill, n_decode, chunk):
        return {lane: min(chunk, rem) for lane, rem in prefill}


class TokenBudgetPolicy(SchedulerPolicy):
    """Token-budget interleave: at most ``budget`` tokens are fed per
    engine iteration, decode lanes first (one token each — their latency
    is the SLO), then prompt chunks in admission order from whatever is
    left.  A prefill lane may receive 0 tokens this iteration; it simply
    resumes when budget frees up."""

    def __init__(self, budget: int):
        if budget < 1:
            raise ValueError("token budget must be >= 1")
        self.budget = budget

    def plan(self, prefill, n_decode, chunk):
        left = max(0, self.budget - n_decode)
        out: Dict[int, int] = {}
        for lane, rem in prefill:
            n = min(chunk, rem, left)
            out[lane] = n
            left -= n
        return out


# ===========================================================================
# the front door: timed arrivals -> per-iteration admission -> latency
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request due at clock time ``t``."""
    t: float
    request: GenerationRequest


@dataclasses.dataclass
class RequestRecord:
    """Per-request latency lifecycle, on the engine clock's axis."""
    rid: int
    arrive_t: float
    submit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    tokens: int = 0
    finish_reason: Optional[str] = None

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token, measured from *arrival* (queueing counts)."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.arrive_t

    @property
    def tpot_s(self) -> Optional[float]:
        """Time per output token after the first (0.0 for 1-token
        outputs — a single token has no inter-token cadence)."""
        if self.first_token_t is None or self.finish_t is None:
            return None
        if self.tokens <= 1:
            return 0.0
        return (self.finish_t - self.first_token_t) / (self.tokens - 1)


class FrontDoor:
    """Drive an engine against a live arrival schedule.

    ``engine`` is a built ``PagedServer``/``ShardedPagedServer`` whose
    :class:`~repro.runtime.EngineConfig` carries the clock this front
    door reads; on a :class:`~repro.runtime.clock.VirtualClock` each
    engine iteration is charged ``iter_time_s`` virtual seconds (a real
    :class:`~repro.runtime.clock.MonotonicClock` flows by itself and
    ``iter_time_s`` is ignored).  ``serve(arrivals)`` submits each
    request when its arrival time comes due, steps the engine, folds the
    delta stream into :class:`RequestRecord` timings and returns them by
    rid.  When the engine idles before the next arrival the clock jumps
    straight to it — no busy-waiting, real or virtual."""

    def __init__(self, engine, *, iter_time_s: float = 0.0):
        self.engine = engine
        self.clock = engine.clock
        self.iter_time_s = float(iter_time_s)
        self.records: Dict[int, RequestRecord] = {}

    def _charge_iteration(self):
        advance = getattr(self.clock, "advance", None)
        if advance is not None and self.iter_time_s:
            advance(self.iter_time_s)

    def _fold_deltas(self):
        now = self.clock.now()
        for d in self.engine.poll_deltas():
            rec = self.records.get(d.rid)
            if rec is None:
                continue
            if d.tokens:
                if rec.first_token_t is None:
                    rec.first_token_t = now
                rec.tokens += len(d.tokens)
            if d.finish_reason is not None:
                rec.finish_t = now
                rec.finish_reason = d.finish_reason

    def serve(self, arrivals: Iterable[Arrival],
              max_iters: int = 100_000) -> Dict[int, RequestRecord]:
        pending = deque(sorted(arrivals, key=lambda a: (a.t, a.request.rid)))
        for a in pending:
            if a.request.rid in self.records:
                raise ValueError(f"duplicate rid {a.request.rid}")
            self.records[a.request.rid] = RequestRecord(
                rid=a.request.rid, arrive_t=a.t)
        it = 0
        while True:
            now = self.clock.now()
            while pending and pending[0].t <= now:
                a = pending.popleft()
                self.records[a.request.rid].submit_t = now
                self.engine.submit(a.request)
            before = self.engine.iterations
            busy = self.engine.step()
            if self.engine.iterations > before:
                self._charge_iteration()
            self._fold_deltas()
            if not busy:
                if not pending:
                    return self.records
                # idle until the next arrival: jump, don't spin
                self.clock.hold_until(pending[0].t)
                continue
            it += 1
            if it >= max_iters:
                self.engine._abort_all()
                self._fold_deltas()
                return self.records


def _percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile on a sorted copy — pure-Python and
    platform-independent, so reports replay byte-identically."""
    if not xs:
        return 0.0
    s = sorted(xs)
    rank = -(-int(q) * len(s) // 100)           # ceil(q * n / 100)
    return s[max(0, min(len(s), rank) - 1)]


def latency_report(records: Dict[int, RequestRecord], *,
                   slo_ttft_s: float, slo_tpot_s: float,
                   ndigits: int = 9) -> dict:
    """Aggregate per-request records into the serving-latency summary.

    TTFT percentiles cover every request that produced a first token;
    TPOT percentiles cover every request that finished with at least one
    token.  ``slo_goodput`` divides by ALL offered requests: a shed,
    timed-out or errored request counts against goodput even though it
    has no latency sample — load you failed to serve is not neutral."""
    ttfts = sorted(round(r.ttft_s, ndigits) for r in records.values()
                   if r.ttft_s is not None)
    tpots = sorted(round(r.tpot_s, ndigits) for r in records.values()
                   if r.tpot_s is not None)
    good = sum(
        1 for r in records.values()
        if r.finish_reason in (FINISH_STOP, FINISH_LENGTH)
        and r.ttft_s is not None and r.ttft_s <= slo_ttft_s
        and r.tpot_s is not None and r.tpot_s <= slo_tpot_s)
    n = len(records)
    completed = sum(1 for r in records.values()
                    if r.finish_reason in (FINISH_STOP, FINISH_LENGTH))
    out = {
        "requests": n,
        "completed": completed,
        "slo": {"ttft_s": slo_ttft_s, "tpot_s": slo_tpot_s},
        "slo_goodput": round(good / n, ndigits) if n else 0.0,
    }
    for name, xs in (("ttft", ttfts), ("tpot", tpots)):
        for q in (50, 95, 99):
            out[f"{name}_p{q}_s"] = round(_percentile(xs, q), ndigits)
    return out
