"""Paged continuous-batching serving engine on the RAB + paged KV pool.

This is the serving-side integration of HERO's C1/C2: the host scheduler and
the accelerator share the *logical token address space* (SVM); the RAB
translates logical pages to physical KV pool slots; the decode kernel
(`kernels/paged_attention`) performs the translation on-device through the
scalar-prefetched block table; page allocation happens on the RAB miss path;
admit/finish/alloc/release are all traced (C4) so Fig.6-style timelines can
be reconstructed from a run.

Demo-scale engine for plain-GQA transformer archs (yi/minitron/qwen3/olmoe
smoke configs); prompts are prefilled through the decode path token-by-token
(a production engine would batch-prefill — noted simplification).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.rab import RAB, RABConfig, PagedKVPool
from repro.core.tracing import EventType, TraceBuffer
from repro.models import layers as L
from repro.models import model as M
from repro.models.layers import rope, rms_head_norm
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 8
    out: List[int] = dataclasses.field(default_factory=list)
    fed: int = 0                      # prompt tokens already consumed
    lane: int = -1
    done: bool = False


class PagedServer:
    def __init__(self, cfg: ArchConfig, params, *, num_pages: int = 64,
                 page_size: int = 8, max_lanes: int = 4,
                 max_pages_per_seq: int = 16,
                 rab_cfg: RABConfig = RABConfig(l1_entries=8, l2_entries=32,
                                                l2_assoc=4, l2_banks=2),
                 tracer: Optional[TraceBuffer] = None,
                 use_kernel: bool = True):
        assert cfg.block_kind == "transformer" and cfg.attention_kind == "gqa" \
            and not cfg.local_global_period, \
            "paged engine supports plain-GQA transformer archs"
        self.cfg, self.params = cfg, params
        self.page_size, self.max_lanes = page_size, max_lanes
        self.max_pages = max_pages_per_seq
        self.tracer = tracer or TraceBuffer()
        self.rab = RAB(rab_cfg, self.tracer)
        self.pool = PagedKVPool(num_pages, page_size, max_pages_per_seq,
                                self.rab)
        L_, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.param_dtype)
        self.k_pages = jnp.zeros((L_, num_pages, page_size, kv, hd), dt)
        self.v_pages = jnp.zeros((L_, num_pages, page_size, kv, hd), dt)
        self.use_kernel = use_kernel
        self._step = jax.jit(functools.partial(
            _paged_decode_step, cfg, use_kernel))
        self.lanes: List[Optional[Request]] = [None] * max_lanes
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._rid_seq: Dict[int, int] = {}

    # ------------------------------------------------------------- admin --
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.max_lanes):
            if self.lanes[i] is None and self.queue:
                need = -(-len(self.queue[0].prompt) // self.page_size) + 1
                if not self.pool.can_alloc(need):
                    break
                req = self.queue.pop(0)
                req.lane = i
                self.lanes[i] = req
                self._rid_seq[req.rid] = req.rid
                self.tracer.record_host(EventType.REQUEST_ADMIT, req.rid, i)

    def _finish(self, req: Request):
        req.done = True
        self.tracer.record_host(EventType.REQUEST_FINISH, req.rid,
                                len(req.out))
        self.pool.release(req.rid)
        self.tracer.record_host(EventType.PAGE_RELEASE, req.rid, 0)
        self.lanes[req.lane] = None
        self.finished.append(req)

    # --------------------------------------------------------------- step --
    def step(self) -> bool:
        """One engine iteration.  Returns False when fully idle."""
        self._admit()
        active = [r for r in self.lanes if r is not None]
        if not active:
            return bool(self.queue)

        B = len(active)
        tokens = np.zeros((B, 1), np.int32)
        write_page = np.zeros((B,), np.int32)
        write_slot = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        for j, r in enumerate(active):
            nxt = r.prompt[r.fed] if r.fed < len(r.prompt) else r.out[-1]
            tokens[j, 0] = nxt
            t = self.pool.seq_len.get(r.rid, 0)
            pos[j] = t
            lpage, slot = self.pool.append_token(r.rid)
            if slot == 0:
                self.tracer.record_host(EventType.PAGE_ALLOC, r.rid, lpage)
            # RAB translation for the *write* path (miss -> handler -> retry)
            write_page[j] = self.pool.translate(r.rid, lpage)
            write_slot[j] = slot

        bt = self.pool.block_table([r.rid for r in active])
        lengths = self.pool.lengths([r.rid for r in active])

        logits, self.k_pages, self.v_pages = self._step(
            self.params, self.k_pages, self.v_pages,
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(bt),
            jnp.asarray(lengths), jnp.asarray(write_page),
            jnp.asarray(write_slot))
        nxt_tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))

        for j, r in enumerate(active):
            if r.fed < len(r.prompt):
                r.fed += 1
                if r.fed == len(r.prompt):
                    r.out.append(int(nxt_tok[j]))
            else:
                r.out.append(int(nxt_tok[j]))
            if len(r.out) >= r.max_new:
                self._finish(r)
        return True

    def run(self, max_iters: int = 10_000):
        it = 0
        while self.step():
            it += 1
            if it >= max_iters:
                break
        return self.finished


# ===========================================================================
# jitted paged decode step
# ===========================================================================

def _paged_decode_step(cfg: ArchConfig, use_kernel: bool, params,
                       k_pages, v_pages, tokens, pos, block_table, lengths,
                       write_page, write_slot):
    """One token for B lanes against the paged pool.

    k/v_pages: (L, P, page, kv, hd); block_table: (B, n_pages);
    write_page/slot: physical coordinates for this token's K/V.
    """
    B = tokens.shape[0]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    lanes = jnp.arange(B)
    attend = paged_attention if use_kernel else paged_attention_ref

    for i in range(cfg.num_layers):
        lp = M._sub(params["layers"], i)
        h = L.norm_forward(cfg, lp["ln1"], x)
        ap = lp["attn"]
        q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, ap["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, ap["wv"])
        if cfg.use_qk_norm:
            q = rms_head_norm(ap["q_norm"], q, cfg.norm_eps)
            k = rms_head_norm(ap["k_norm"], k, cfg.norm_eps)
        if cfg.use_rope:
            q = rope(q, pos[:, None], cfg.rope_theta)
            k = rope(k, pos[:, None], cfg.rope_theta)
        # write this token's K/V into its physical page slot
        k_pages = k_pages.at[i, write_page, write_slot].set(k[:, 0])
        v_pages = v_pages.at[i, write_page, write_slot].set(v[:, 0])
        a = attend(q[:, 0], k_pages[i], v_pages[i], block_table, lengths)
        x = x + jnp.einsum("bhk,hkd->bd", a, ap["wo"])[:, None, :]
        h = L.norm_forward(cfg, lp["ln2"], x)
        if "moe" in lp:
            from repro.models import moe as MOE
            x = x + MOE.moe_forward(cfg, lp["moe"], h)
        else:
            x = x + L.mlp_forward(cfg, lp["mlp"], h)

    x = L.norm_forward(cfg, params["final_norm"], x)
    logits = L.logits_from_hidden(cfg, params["embed"], x)
    return logits, k_pages, v_pages
