"""Paged continuous-batching serving engine on the RAB + paged KV pool.

This is the serving-side integration of HERO's C1/C2: the host scheduler and
the accelerator share the *logical token address space* (SVM); the RAB
translates logical pages to physical KV pool slots; the attention kernels
(`kernels/paged_attention`) perform the translation on-device through the
scalar-prefetched block table; page allocation happens on the RAB miss path;
admit/finish/alloc/release are all traced (C4) so Fig.6-style timelines can
be reconstructed from a run.

The hot path follows HERO's "keep the accelerator fed" discipline (Fig. 5 —
DMA double-buffering + zero-copy SVM so the host never serializes on the
data path):

* prompts are admitted through a *chunked prefill* step that consumes up to
  ``chunk`` tokens per engine iteration in one ``paged_prefill`` kernel
  launch (not token-by-token through the decode path);
* the decode step runs entirely from device-resident state — block tables,
  lengths, the active-lane mask, and the previously sampled token all live
  on device, greedy sampling is on-device, and the only per-iteration
  transfer is a single device->host pull of the sampled tokens;
* K and V for all new tokens of all lanes are written into the fused
  ``(L, P+1, 2, page, Kv, hd)`` pool with ONE scatter per layer (invalid
  slots are routed to a trash page, index ``P``, so no masking pass is
  needed);
* the device block table is repeat-padded (entries past the last mapped
  page repeat it) and updated incrementally — one small host->device row
  write per page allocation, amortized to ``<= 1/page_size`` per token.

Host<->device transfer events on this path are traced (``EventType.H2D`` /
``D2H``) so ``benchmarks/serve_throughput.py`` can count them.

On top of the hot path sit HERO's SVM page *sharing* and *reclamation*
(§2.2, §3.4), serving-side:

* **shared-prefix KV caching** — admission consults the pool's prefix
  index; pages already holding the request's prompt prefix are mapped into
  its block table (refcount bumped, RAB entries installed) and their
  prefill is skipped — only the tail chunk runs the prefill kernel.  A
  lane appending into a still-shared partial page is copy-on-written onto
  a private page through the ordinary allocation path;
* **preemptive scheduling** — admission is priority-ordered; when the pool
  (or lane set) is exhausted, the lowest-priority running lane is
  preempted: its pages swap out D2H to a ``HostBackingStore`` (non-shared
  pages are thereby reclaimed; shared ones drop this lane's refcount, the
  host copy making re-admission independent of the sharers' lifetimes)
  and swap back H2D on re-admission, with all traffic traced as
  SWAP_OUT/SWAP_IN plus the underlying H2D/D2H events.

**Speculative decoding** (``spec_k > 0``) is the host/accelerator split
itself: a cheap host-side drafter (``runtime.speculative``) proposes up to
K tokens per decode lane, the pool appends all K+1 candidate positions
(pages allocated, CoW applied — exactly the plain append path), and ONE
chunked verify step (``_paged_spec_step``, the chunk kernel re-used with
the drafts as the feed) greedily scores every position, counts the
accepted prefix on device and advances lengths by ``accepted + 1``.  The
host then *rolls back* the rejected tail: ``PagedKVPool.trim`` unmaps
pages wholly beyond the kept length (respecting refcounts, CoW copies and
the prefix index) and re-credits them to the request's reservation.
Greedy parity is structural — the accepted prefix plus the bonus token is
the exact greedy continuation.  Per-lane K adapts to recent acceptance
(full accept grows it, zero accept halves it) and drafting is disabled
while any request is queued (preemption pressure: waiting work beats
wider verification).  Proposals, acceptances and rollbacks are traced as
SPEC_PROPOSE / SPEC_ACCEPT / SPEC_ROLLBACK.

Demo-scale engine for plain-GQA transformer archs (yi/minitron/qwen3/olmoe
smoke configs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.offload import HostBackingStore
from repro.core.rab import RAB, RABConfig, PagedKVPool
from repro.core.tracing import EventType, TraceBuffer
from repro.models import layers as L
from repro.models import model as M
from repro.models.layers import rope, rms_head_norm
from repro.kernels.paged_attention.ops import (
    paged_prefill_fused, page_counts_for,
)
from repro.kernels.paged_attention.ref import paged_prefill_ref
from repro.runtime.speculative import Drafter, NGramDrafter


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 8
    priority: int = 0                 # scheduler class; higher preempts lower
    out: List[int] = dataclasses.field(default_factory=list)
    fed: int = 0                      # prompt tokens already consumed
    lane: int = -1
    done: bool = False
    prefix_hit_tokens: int = 0        # prompt tokens reused from the cache
    preemptions: int = 0
    arrival: int = -1                 # FIFO tiebreak, assigned by submit()
    cluster: int = 0                  # owning PMCA cluster (sharded engine)
    reg_pages: int = 0                # prompt pages published to the index
    swapped: Optional[List[int]] = None   # lpages parked in the backing store
    spec_k_cur: int = 0               # adaptive per-lane draft depth
    spec_proposed: int = 0            # drafted tokens sent to verification
    spec_accepted: int = 0            # drafted tokens the target confirmed
    spec_rejected: int = 0            # drafted tokens rolled back


class PagedServer:
    def __init__(self, cfg: ArchConfig, params, *, num_pages: int = 64,
                 page_size: int = 8, max_lanes: int = 4,
                 max_pages_per_seq: int = 16, chunk: int = 16,
                 pages_per_step: int = 2,
                 rab_cfg: RABConfig = RABConfig(l1_entries=8, l2_entries=32,
                                                l2_assoc=4, l2_banks=2),
                 tracer: Optional[TraceBuffer] = None,
                 use_kernel: bool = True,
                 enable_prefix_cache: bool = True,
                 spec_k: int = 0,
                 drafter: Optional[Drafter] = None):
        assert cfg.block_kind == "transformer" and cfg.attention_kind == "gqa" \
            and not cfg.local_global_period, \
            "paged engine supports plain-GQA transformer archs"
        self.cfg, self.params = cfg, params
        self.page_size, self.max_lanes = page_size, max_lanes
        self.max_pages = max_pages_per_seq
        self.chunk = max(1, chunk)
        self.tracer = tracer or TraceBuffer()
        self.use_kernel = use_kernel
        # speculative decoding: drafter proposes, the verify step disposes
        self.spec_k = max(0, spec_k)
        self.drafter = drafter if drafter is not None else \
            (NGramDrafter() if self.spec_k else None)
        # overridable construction hooks: the sharded subclass substitutes
        # per-cluster pools and mesh-sharded device state here instead of
        # allocating the unsharded versions only to discard them
        self._build_pool(num_pages, rab_cfg)
        self._build_device_state(num_pages, pages_per_step)
        self._bt_host = np.zeros((self.max_lanes, max_pages_per_seq),
                                 np.int32)
        self.lanes: List[Optional[Request]] = [None] * max_lanes
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.iterations = 0
        self.prefill_tokens = 0       # prompt tokens run through prefill
        self.h2d_events = 0
        self.d2h_events = 0
        # shared-prefix caching + preemption (HERO SVM page sharing and
        # reclamation on the serving path)
        self.enable_prefix_cache = enable_prefix_cache
        self.backing = HostBackingStore()
        self.preemptions = 0
        self._dirty: set = set()      # lane rows to push before the kernel
        self._arrival = 0
        self.spec_iterations = 0      # engine iterations that verified drafts
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_rejected = 0

    # --------------------------------------------------------------- trace --
    def _h2d(self, n: int = 1):
        self.h2d_events += n
        self.tracer.record_host(EventType.H2D, n, 0)

    def _d2h(self, n: int = 1):
        self.d2h_events += n
        self.tracer.record_host(EventType.D2H, n, 0)

    # ------------------------------------------------------ construction --
    def _build_pool(self, num_pages: int, rab_cfg: RABConfig):
        self.rab = RAB(rab_cfg, self.tracer)
        self.pool = PagedKVPool(num_pages, self.page_size, self.max_pages,
                                self.rab)

    def _build_device_state(self, num_pages: int, pages_per_step: int):
        cfg = self.cfg
        L_, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.param_dtype)
        # fused K/V pool; the extra page (index num_pages) is the trash page
        # masked writes are routed to
        self.kv_pages = jnp.zeros(
            (L_, num_pages + 1, 2, self.page_size, kv, hd), dt)
        itp = jax.default_backend() != "tpu"
        self._chunk_step = jax.jit(functools.partial(
            _paged_chunk_step, cfg, self.use_kernel, pages_per_step, itp,
            num_pages))
        self._decode_step = jax.jit(functools.partial(
            _paged_decode_step, cfg, self.use_kernel, pages_per_step, itp,
            num_pages))
        if self.spec_k:
            self._spec_step = jax.jit(functools.partial(
                _paged_spec_step, cfg, self.use_kernel, pages_per_step, itp,
                num_pages))
        # device-resident engine state (HERO SVM: the scheduler and the
        # model share these without per-iteration re-uploads)
        self.bt_dev = jnp.zeros((self.max_lanes, self.max_pages), jnp.int32)
        self.len_dev = jnp.zeros((self.max_lanes,), jnp.int32)
        self.active_dev = jnp.zeros((self.max_lanes,), jnp.int32)
        self.last_tok = jnp.zeros((self.max_lanes,), jnp.int32)

    # ---------------------------------------------------------- pool seam --
    # Every pool access for a placed request routes through these, so the
    # sharded subclass can substitute cluster-local pools and translate
    # local physical page ids into the fused device slab's global indices.
    def _pool_of(self, cluster: int) -> PagedKVPool:
        return self.pool

    def _pool(self, req: Request) -> PagedKVPool:
        return self._pool_of(req.cluster)

    def _capacity_pages(self) -> int:
        """Page capacity one request can draw from (per cluster)."""
        return self.pool.num_pages

    def _gpage(self, req: Request, p: int) -> int:
        """Pool-local physical page -> index into self.kv_pages."""
        return p

    # ------------------------------------------------------------- admin --
    def submit(self, req: Request):
        # real exceptions, not asserts: an unplaceable request at the queue
        # head would otherwise spin _admit forever (and -O strips asserts)
        if not req.prompt:
            # an empty prompt would enter decode seeded by whatever token
            # the lane's previous occupant left in last_tok
            raise ValueError("empty prompt")
        if len(req.prompt) + req.max_new - 1 > \
                self.max_pages * self.page_size:
            raise ValueError("request exceeds max_pages_per_seq")
        if self._pages_needed(req) + self._cow_budget(req) > \
                self._capacity_pages():
            raise ValueError("request exceeds KV pool capacity")
        req.arrival = self._arrival
        self._arrival += 1
        if self.spec_k and req.spec_k_cur <= 0:
            req.spec_k_cur = self.spec_k
        self.queue.append(req)

    def _pages_needed(self, req: Request) -> int:
        # every token the engine will *write* K/V for: the prompt plus all
        # generated tokens except the last (sampled but never fed back)
        total = len(req.prompt) + req.max_new - 1
        return int(page_counts_for(total, self.page_size))

    # --------------------------------------------------------- scheduler --
    def _cow_budget(self, req: Request) -> int:
        """One extra reserved page for a request whose prompt tail is
        partial: once that tail is *registered* in the prefix index, a
        later admission may share it, and this request's own next append
        then copy-on-writes — a page its plain per-page reservation never
        counted (the donor side of CoW must be budgeted too, or an
        admitted request could hit pool exhaustion mid-stream)."""
        return 1 if (self.enable_prefix_cache and req.max_new > 1
                     and len(req.prompt) % self.page_size) else 0

    def _plan(self, req: Request, cluster: int = 0) -> dict:
        """Admission plan against ``cluster``'s pool: which prefix-cache
        pages to map and how many pages to reserve.  ``need`` excludes only
        *stable* shared pages (fully written, never appended again); a
        shared partial tail keeps one reserved page as the sharer's
        copy-on-write budget, the donor-side CoW is budgeted by
        ``_cow_budget``, and a resuming request budgets every page it must
        restore or still allocate."""
        pool = self._pool_of(cluster)
        total = self._pages_needed(req) + self._cow_budget(req)
        ps = self.page_size
        if req.swapped is not None:            # resuming after preemption
            # preemption dropped every mapping, so the whole lifetime page
            # budget (restores + future allocations) is needed again
            return {"resume": True, "hit_pages": [], "usable": 0,
                    "need": total, "cached_hits": 0, "cluster": cluster}
        usable, hits = 0, []
        if self.enable_prefix_cache and len(req.prompt) > 1:
            pages, n = pool.match_prefix(req.prompt)
            # the final prompt token always runs through the model (it
            # produces the first sampled token), so it is never reused
            usable = min(n, len(req.prompt) - 1)
            hits = pages[:-(-usable // ps)] if usable else []
        need = total - usable // ps
        cached = sum(1 for p in hits if p in pool.cached_free)
        plan = {"resume": False, "hit_pages": hits, "usable": usable,
                "need": need, "cached_hits": cached, "cluster": cluster}
        if hits and not self._fits(plan):
            # hits sitting on cached-free pages cost evictable capacity a
            # no-sharing admission would simply reuse — never let the cache
            # starve a request that fits without it
            fallback = {"resume": False, "hit_pages": [], "usable": 0,
                        "need": total, "cached_hits": 0, "cluster": cluster}
            if self._fits(fallback):
                return fallback
        return plan

    def _fits(self, plan: dict) -> bool:
        # reviving cached-free hit pages consumes them from the evictable
        # set, so they are budgeted on top of the reservation
        return self._pool_of(plan["cluster"]).available() >= \
            plan["need"] + plan["cached_hits"]

    def _victim(self, head: Request) -> Optional[Request]:
        """Lowest-priority running request (youngest within a class) —
        preemptable only by a strictly higher-priority waiter, so equal
        classes never churn each other."""
        running = [r for r in self.lanes if r is not None]
        if not running:
            return None
        v = min(running, key=lambda r: (r.priority, -r.arrival))
        return v if v.priority < head.priority else None

    def _admit(self):
        while self.queue:
            # re-sort every round: _preempt re-enqueues its victim, which
            # must keep its priority rank over lower-priority waiters
            self.queue.sort(key=lambda r: (-r.priority, r.arrival))
            head = self.queue[0]
            lane = next((i for i in range(self.max_lanes)
                         if self.lanes[i] is None), None)
            plan = self._plan(head)
            if lane is None or not self._fits(plan):
                victim = self._victim(head)
                if victim is None:
                    break
                self._preempt(victim)
                continue                  # pool/lane state changed: re-plan
            self.queue.pop(0)
            self._place(head, lane, plan)

    def _place(self, req: Request, lane: int, plan: dict):
        rid = req.rid
        req.lane = lane
        req.cluster = plan["cluster"]
        pool = self._pool(req)
        self.lanes[lane] = req
        if plan["need"] > 0:
            # reserve the request's remaining lifetime page budget so
            # chunked prefill / restore can never hit exhaustion mid-stream
            pool.reserve(rid, plan["need"])
        if plan["resume"]:
            self._swap_in(req)
        elif plan["usable"]:
            # prefix-cache hit: map the cached pages, skip their prefill
            for lp, p in enumerate(plan["hit_pages"]):
                pool.share_page(rid, lp, p)
            pool.seq_len[rid] = plan["usable"]
            pool.stats["prefix_hit_tokens"] += plan["usable"]
            req.fed = plan["usable"]
            req.prefix_hit_tokens = plan["usable"]
            req.reg_pages = plan["usable"] // self.page_size
            self.tracer.record_host(EventType.PREFIX_HIT, rid,
                                    plan["usable"])
        self._refresh_row(lane, req)
        self.active_dev = self.active_dev.at[lane].set(1)
        self.len_dev = self.len_dev.at[lane].set(
            pool.seq_len.get(rid, 0))
        if plan["resume"] and req.fed >= len(req.prompt) and req.out:
            # mid-decode resume: re-seed the device-resident last sample
            self.last_tok = self.last_tok.at[lane].set(req.out[-1])
        self._h2d(1)
        self.tracer.record_host(EventType.REQUEST_ADMIT, rid, lane)

    def _preempt(self, req: Request):
        """Reclaim a running lane: every mapped page's payload goes D2H
        into the host backing store and the mapping drops.  Non-shared
        pages are thereby freed immediately; shared pages merely lose this
        request's refcount (they live on under their other owners or on
        the cached-free list), but checkpointing their payload too makes
        re-admission independent of those owners' lifetimes — so a full
        preemption sweep always reclaims everything a victim held and the
        scheduler can never pin the pool behind preempted sequences."""
        rid, i = req.rid, req.lane
        pool = self._pool(req)
        mapped = pool.seq_pages(rid)
        if mapped:
            idx = jnp.asarray([self._gpage(req, p) for _, p in mapped])
            payload = np.asarray(self.kv_pages[:, idx])
            self._d2h(len(mapped))    # one gather, len(mapped) pages pulled
            for j, (lp, _p) in enumerate(mapped):
                self.backing.put(rid, lp, payload[:, j])
                pool.unmap_page(rid, lp)
        req.swapped = [lp for lp, _ in mapped]
        pool.reserved.pop(rid, None)
        req.lane = -1
        req.preemptions += 1
        self.preemptions += 1
        self.lanes[i] = None
        self.active_dev = self.active_dev.at[i].set(0)
        self.len_dev = self.len_dev.at[i].set(0)
        self._h2d(1)
        pool.stats["swapped_out"] += len(mapped)
        self.tracer.record_host(EventType.SWAP_OUT, rid, len(mapped))
        self.tracer.record_host(EventType.REQUEST_PREEMPT, rid, len(mapped))
        self.queue.append(req)

    def preempt(self, rid: int) -> bool:
        """Forcibly preempt a running request (test/benchmark hook; pool
        pressure drives the same path through the scheduler)."""
        for r in self.lanes:
            if r is not None and r.rid == rid:
                self._preempt(r)
                return True
        return False

    def _swap_in(self, req: Request):
        """Restore a preempted request's swapped pages: fresh physical
        pages, one batched H2D payload upload, mappings re-established."""
        rid = req.rid
        pool = self._pool(req)
        lps, req.swapped = req.swapped, None
        if not lps:
            return
        phys = [self._gpage(req, pool.alloc_page(rid, lp)) for lp in lps]
        payload = jnp.stack(
            [jnp.asarray(self.backing.pop(rid, lp)) for lp in lps], axis=1)
        self.kv_pages = self.kv_pages.at[:, jnp.asarray(phys)].set(
            payload.astype(self.kv_pages.dtype))
        self._h2d(len(lps))
        pool.stats["swapped_in"] += len(lps)
        self.tracer.record_host(EventType.SWAP_IN, rid, len(lps))

    def _refresh_row(self, lane: int, req: Request):
        """Rebuild a lane's repeat-padded host block-table row from the
        pool (through the RAB translate path) and mark it for upload."""
        pool, rid = self._pool(req), req.rid
        n = pool.seq_len.get(rid, 0)
        n_pages = -(-n // self.page_size) if n else 0
        last = 0
        for lp in range(n_pages):
            last = pool.translate(rid, lp)
            self._bt_host[lane, lp] = last
        self._bt_host[lane, n_pages:] = last
        self._dirty.add(lane)

    def _register_prompt_pages(self, active: List[Request],
                               n_new: np.ndarray):
        """Publish prompt-prefix pages completed this iteration into the
        prefix index (full pages as they fill; the partial tail page once
        the whole prompt is pool-resident).  Decode-phase pages are never
        indexed — generated tokens are request-private."""
        if not self.enable_prefix_cache:
            return
        ps = self.page_size
        for r in active:
            if n_new[r.lane] == 0 or r.fed >= len(r.prompt):
                continue
            pool = self._pool(r)
            written = min(pool.seq_len.get(r.rid, 0), len(r.prompt))
            for lp in range(r.reg_pages, written // ps):
                pool.register_page(r.rid, lp, r.prompt)
            r.reg_pages = max(r.reg_pages, written // ps)
            if written == len(r.prompt) and written % ps:
                pool.register_page(r.rid, written // ps, r.prompt)

    def _finish(self, req: Request):
        req.done = True
        self.tracer.record_host(EventType.REQUEST_FINISH, req.rid,
                                len(req.out))
        self._pool(req).release(req.rid)
        self.tracer.record_host(EventType.PAGE_RELEASE, req.rid, 0)
        self.lanes[req.lane] = None
        self.active_dev = self.active_dev.at[req.lane].set(0)
        self.len_dev = self.len_dev.at[req.lane].set(0)
        self._h2d(1)
        self.finished.append(req)

    # --------------------------------------------------------------- step --
    def _account_appends(self, active: List[Request], n_new: np.ndarray):
        """Host-side page accounting for this iteration's candidate writes:
        allocate (through the RAB translate path) every page the new tokens
        touch, apply any copy-on-write remaps, and push only the dirty
        repeat-padded block-table rows."""
        dirty, self._dirty = self._dirty, set()
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for r in active:
            i = r.lane
            pool = self._pool(r)
            for _ in range(int(n_new[i])):
                lpage, slot = pool.append_token(r.rid)
                if slot == 0:
                    phys = pool.translate(r.rid, lpage)
                    self.tracer.record_host(EventType.PAGE_ALLOC, r.rid, phys)
                    self._bt_host[i, lpage:] = phys
                    dirty.add(i)
                for (s, lp, src, dst) in pool.drain_cow():
                    # the writer was remapped off a shared page: patch its
                    # row and queue the device-side payload copy (slab
                    # indices are global; the block table stays pool-local)
                    cow_src.append(self._gpage(r, src))
                    cow_dst.append(self._gpage(r, dst))
                    self._bt_host[i, lp:] = dst
                    dirty.add(i)
                    self.tracer.record_host(EventType.PAGE_COW, s, dst)
        if cow_src:
            # one batched on-device page copy, applied before this step's
            # K/V scatter so the write lands in the private copy
            self.kv_pages = self.kv_pages.at[:, jnp.asarray(cow_dst)].set(
                self.kv_pages[:, jnp.asarray(cow_src)])
        self._register_prompt_pages(active, n_new)
        if dirty:
            rows = sorted(dirty)
            self.bt_dev = self.bt_dev.at[jnp.asarray(rows)].set(
                jnp.asarray(self._bt_host[rows]))
            self._h2d(len(rows))    # one dispatch, len(rows) rows uploaded

    def step(self) -> bool:
        """One engine iteration.  Returns False when fully idle."""
        self._admit()
        active = [r for r in self.lanes if r is not None]
        if not active:
            return bool(self.queue)
        self.iterations += 1

        if self._spec_wanted(active):
            drafts, n_spec = self._propose(active)
            if drafts is not None:
                self._spec_iteration(active, drafts, n_spec)
                return True

        B, C = self.max_lanes, self.chunk
        n_new = np.zeros((B,), np.int32)
        feed = np.zeros((B, C), np.int32)
        use_last = np.zeros((B,), np.int32)
        decode_only = True
        for r in active:
            i = r.lane
            if r.fed < len(r.prompt):
                n = min(C, len(r.prompt) - r.fed)
                feed[i, :n] = r.prompt[r.fed:r.fed + n]
                n_new[i] = n
                self.prefill_tokens += n
                decode_only = False
            else:
                n_new[i] = 1
                use_last[i] = 1     # token is device-resident; no upload

        self._account_appends(active, n_new)

        if decode_only:
            # sync-free: every input already lives on device
            self.last_tok, self.kv_pages, self.len_dev = self._decode_step(
                self.params, self.kv_pages, self.bt_dev, self.len_dev,
                self.active_dev, self.last_tok)
        else:
            self._h2d(1)            # the prompt-chunk feed bundle
            self.last_tok, self.kv_pages, self.len_dev = self._chunk_step(
                self.params, self.kv_pages, self.bt_dev, self.len_dev,
                jnp.asarray(n_new), jnp.asarray(feed), self.last_tok,
                jnp.asarray(use_last))

        tok = np.asarray(self.last_tok)     # one pull per iteration
        self._d2h(1)

        for r in list(active):
            i = r.lane
            if r.fed < len(r.prompt):
                r.fed += int(n_new[i])
                if r.fed == len(r.prompt):
                    r.out.append(int(tok[i]))
            else:
                r.out.append(int(tok[i]))
            if len(r.out) >= r.max_new:
                self._finish(r)
        return True

    # -------------------------------------------------------- speculation --
    def _spec_wanted(self, active: List[Request]) -> bool:
        """Draft this iteration?  Only when speculation is configured,
        every active lane is in the decode phase (mixed prefill iterations
        keep the plain chunk path), and nothing is waiting for admission —
        a non-empty queue is preemption pressure: lanes should not widen
        their verify window while other work is starved."""
        return (self.spec_k > 0 and not self.queue
                and all(r.fed >= len(r.prompt) for r in active))

    def _propose(self, active: List[Request]):
        """Collect per-lane draft proposals into a fixed-width (B, spec_k)
        matrix (fixed so the verify step compiles once).  A lane's draft
        depth is its adaptive ``spec_k_cur`` capped by the tokens it still
        owes (``accepted + 1 <= remaining`` must hold, so at most
        ``remaining - 1`` drafts).  Returns (None, None) when no lane
        proposed anything — the plain decode step is strictly cheaper."""
        drafts = np.zeros((self.max_lanes, self.spec_k), np.int32)
        n_spec = np.zeros((self.max_lanes,), np.int32)
        any_draft = False
        for r in active:
            rem = r.max_new - len(r.out)
            cap = min(r.spec_k_cur, rem - 1, self.spec_k)
            if cap <= 0:
                continue
            d = self.drafter.propose(r.prompt + r.out, cap)[:cap]
            if not d:
                continue
            drafts[r.lane, :len(d)] = d
            n_spec[r.lane] = len(d)
            any_draft = True
        return (drafts, n_spec) if any_draft else (None, None)

    def _spec_iteration(self, active: List[Request], drafts: np.ndarray,
                        n_spec: np.ndarray):
        """One draft-verify-rollback engine iteration.

        The pool appends all K+1 candidate positions per lane (pages
        allocated, CoW applied — the ordinary append path), the verify
        step scores every position and counts the accepted prefix on
        device, and the host trims each lane back to ``accepted + 1``
        kept tokens: pages wholly beyond the kept length are unmapped and
        re-credited to the reservation.  Device lengths and the last
        sampled token are updated inside the jitted step from the
        acceptance itself, so the only pull is the one verdict array."""
        self.spec_iterations += 1
        lens0 = {r.rid: self._pool(r).seq_len[r.rid] for r in active}
        n_new = np.zeros((self.max_lanes,), np.int32)
        for r in active:
            k_i = int(n_spec[r.lane])
            n_new[r.lane] = k_i + 1
            if k_i:
                self.tracer.record_host(EventType.SPEC_PROPOSE, r.rid, k_i)
                self.spec_proposed += k_i
                r.spec_proposed += k_i
        self._account_appends(active, n_new)

        self._h2d(1)                # the draft feed bundle
        verdict, self.kv_pages, self.last_tok, self.len_dev = \
            self._spec_step(self.params, self.kv_pages, self.bt_dev,
                            self.len_dev, self.active_dev, self.last_tok,
                            jnp.asarray(drafts), jnp.asarray(n_spec))
        v = np.asarray(verdict)     # one pull per iteration
        self._d2h(1)

        K = drafts.shape[1]
        for r in list(active):
            i = r.lane
            k_i = int(n_spec[i])
            a = int(v[i, K + 1])
            emitted = [int(t) for t in drafts[i, :a]] + [int(v[i, a])]
            freed = self._pool(r).trim(r.rid, lens0[r.rid] + a + 1)
            r.out.extend(emitted)
            if k_i:
                self.tracer.record_host(EventType.SPEC_ACCEPT, r.rid, a)
                self.spec_accepted += a
                r.spec_accepted += a
                rej = k_i - a
                if rej:
                    self.spec_rejected += rej
                    r.spec_rejected += rej
                    self.tracer.record_host(EventType.SPEC_ROLLBACK,
                                            r.rid, rej)
                # adaptive depth: full acceptance earns a wider window,
                # total rejection halves it (never below 1)
                if a == k_i:
                    r.spec_k_cur = min(self.spec_k, r.spec_k_cur + 1)
                elif a == 0:
                    r.spec_k_cur = max(1, r.spec_k_cur // 2)
            if freed:
                self._refresh_row(i, r)
            if len(r.out) >= r.max_new:
                self._finish(r)

    def run(self, max_iters: int = 10_000):
        it = 0
        while self.step():
            it += 1
            if it >= max_iters:
                break
        return self.finished


# ===========================================================================
# jitted engine steps
# ===========================================================================

def _write_coords(bt, lens, n_new, C, page_size, trash):
    """Physical (page, slot) for the C candidate token writes of each lane.

    Invalid slots (beyond a lane's n_new) are routed to the trash page so a
    single unmasked scatter covers every lane."""
    n_pages = bt.shape[1]
    pos = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B,C)
    lp = jnp.minimum(pos // page_size, n_pages - 1)
    sl = pos % page_size
    phys = jnp.take_along_axis(bt, lp, axis=1)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_new[:, None]
    return jnp.where(valid, phys, trash), sl


def _layer_qkv(cfg, lp, x, pos):
    h = L.norm_forward(cfg, lp["ln1"], x)
    ap = lp["attn"]
    q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, ap["wv"])
    if cfg.use_qk_norm:
        q = rms_head_norm(ap["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(ap["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def _layer_mlp(cfg, lp, x):
    h = L.norm_forward(cfg, lp["ln2"], x)
    if "moe" in lp:
        from repro.models import moe as MOE
        return x + MOE.moe_forward(cfg, lp["moe"], h)
    return x + L.mlp_forward(cfg, lp["mlp"], h)


def _paged_forward_greedy(cfg: ArchConfig, use_kernel: bool,
                          pages_per_step: int, interpret: bool,
                          num_pages: int, params, kv_pages, bt, lens, n_new,
                          feed, last_tok, use_last, *, axis_name=None):
    """Shared forward for the chunk / decode / spec-verify steps: consume up
    to C tokens per lane (prompt chunks from ``feed``; lanes with
    ``use_last`` take the device-resident previous sample at position 0)
    and return the greedy next token at EVERY fed position.

    kv_pages: (L, P+1, 2, page, kv, hd); bt: (B, n_pages) repeat-padded.
    Returns (greedy (B, C), kv_pages).

    ``axis_name`` names the tensor-parallel head mesh axis when this runs
    as a ``shard_map`` body (sharded engine): q/k/v/o weights and the pool's
    kv-head dim arrive pre-sliced, so the only collective is one psum of the
    attention output per layer — everything else is replicated compute."""
    B, C = feed.shape
    page = kv_pages.shape[3]
    n_pages = bt.shape[1]
    tokens = feed.at[:, 0].set(jnp.where(use_last == 1, last_tok, feed[:, 0]))
    x = L.embed_tokens(cfg, params["embed"], tokens)        # (B,C,d)
    pos = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    new_lens = lens + n_new
    counts = page_counts_for(new_lens, page)
    phys, sl = _write_coords(bt, lens, n_new, C, page, num_pages)
    if not use_kernel:      # the -1-marked table form the oracle expects
        idx = jnp.arange(n_pages, dtype=jnp.int32)[None, :]
        bt_masked = jnp.where(idx < counts[:, None], bt, -1)

    for i in range(cfg.num_layers):
        lp = M._sub(params["layers"], i)
        q, k, v = _layer_qkv(cfg, lp, x, pos)
        # one fused scatter writes K AND V for all lanes' chunk tokens
        kv_pages = kv_pages.at[i, phys, :, sl].set(jnp.stack([k, v], axis=2))
        if use_kernel:
            a = paged_prefill_fused(q, kv_pages[i], bt, counts, new_lens,
                                    lens, interpret=interpret,
                                    pages_per_step=pages_per_step)
        else:
            a = paged_prefill_ref(q, kv_pages[i, :, 0], kv_pages[i, :, 1],
                                  bt_masked, new_lens, lens)
        attn_out = jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"])
        if axis_name is not None:
            # each head shard holds a partial sum over its heads
            attn_out = jax.lax.psum(attn_out, axis_name)
        x = x + attn_out
        x = _layer_mlp(cfg, lp, x)

    x = L.norm_forward(cfg, params["final_norm"], x)
    logits = L.logits_from_hidden(cfg, params["embed"], x)  # (B,C,V)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), kv_pages


def _paged_chunk_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                      interpret: bool, num_pages: int, params, kv_pages,
                      bt, lens, n_new, feed, last_tok, use_last, *,
                      axis_name=None):
    """Consume up to C tokens per lane: prompt chunks from ``feed``, decode
    lanes (``use_last``) from the device-resident previous sample.

    Returns (sampled_tokens (B,), kv_pages, new_lens)."""
    greedy, kv_pages = _paged_forward_greedy(
        cfg, use_kernel, pages_per_step, interpret, num_pages, params,
        kv_pages, bt, lens, n_new, feed, last_tok, use_last,
        axis_name=axis_name)
    row = jnp.maximum(n_new - 1, 0)
    nxt = jnp.take_along_axis(greedy, row[:, None], axis=1)[:, 0]
    nxt = jnp.where(n_new > 0, nxt, last_tok)   # idle lanes keep their token
    return nxt, kv_pages, lens + n_new


def _paged_spec_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                     interpret: bool, num_pages: int, params, kv_pages,
                     bt, lens, active, last_tok, drafts, n_spec, *,
                     axis_name=None):
    """Speculative verify step: score all K+1 candidate positions of every
    lane in ONE chunked forward and count the accepted draft prefix.

    The feed is ``[x0, d_1 .. d_K]`` where x0 is the device-resident
    previous sample and d_j are host drafts; lane b uses ``n_spec[b]`` of
    them (the rest are dead weight routed to the trash page by the write
    coords).  Greedy verification: draft d_{j+1} is accepted iff every
    earlier draft was and d_{j+1} equals the greedy token after position j
    — so the accepted prefix plus the bonus token ``greedy[accepted]`` is
    exactly the plain greedy continuation (parity by construction).
    Lengths advance by ``accepted + 1`` on device; the host applies the
    same trim to the pool.

    Returns (verdict (B, K+2), kv_pages, last_tok, new_lens) where
    ``verdict[:, :K+1]`` is the greedy token at each position and
    ``verdict[:, K+1]`` the accepted count."""
    B, K = drafts.shape
    feed = jnp.concatenate([jnp.zeros((B, 1), jnp.int32), drafts], axis=1)
    n_new = jnp.where(active == 1, n_spec + 1, 0)
    greedy, kv_pages = _paged_forward_greedy(
        cfg, use_kernel, pages_per_step, interpret, num_pages, params,
        kv_pages, bt, lens, n_new, feed, last_tok, active,
        axis_name=axis_name)
    idx = jnp.arange(K, dtype=jnp.int32)[None, :]
    ok = (drafts == greedy[:, :K]) & (idx < n_spec[:, None])
    accepted = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
    new_lens = lens + jnp.where(active == 1, accepted + 1, 0)
    last = jnp.take_along_axis(greedy, accepted[:, None], axis=1)[:, 0]
    last = jnp.where(active == 1, last, last_tok)
    verdict = jnp.concatenate([greedy, accepted[:, None]], axis=1)
    return verdict, kv_pages, last, new_lens


def _paged_decode_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                       interpret: bool, num_pages: int, params, kv_pages,
                       bt, lens, active, last_tok, *, axis_name=None):
    """One decode token for every active lane, entirely from device state —
    the C=1 case of the chunk step (mirroring paged_decode_fwd, which is the
    C=1 case of the prefill kernel), with every lane fed its device-resident
    previous sample.

    Returns (sampled_tokens (B,), kv_pages, new_lens)."""
    B = lens.shape[0]
    return _paged_chunk_step(
        cfg, use_kernel, pages_per_step, interpret, num_pages, params,
        kv_pages, bt, lens, active, jnp.zeros((B, 1), jnp.int32), last_tok,
        jnp.ones((B,), jnp.int32), axis_name=axis_name)
