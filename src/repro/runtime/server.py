"""Paged continuous-batching serving engine on the RAB + paged KV pool.

This is the serving-side integration of HERO's C1/C2: the host scheduler and
the accelerator share the *logical token address space* (SVM); the RAB
translates logical pages to physical KV pool slots; the attention kernels
(`kernels/paged_attention`) perform the translation on-device through the
scalar-prefetched block table; page allocation happens on the RAB miss path;
admit/finish/alloc/release are all traced (C4) so Fig.6-style timelines can
be reconstructed from a run.

The hot path follows HERO's "keep the accelerator fed" discipline (Fig. 5 —
DMA double-buffering + zero-copy SVM so the host never serializes on the
data path):

* prompts are admitted through a *chunked prefill* step that consumes up to
  ``chunk`` tokens per engine iteration in one ``paged_prefill`` kernel
  launch (not token-by-token through the decode path);
* the decode step runs entirely from device-resident state — block tables,
  lengths, the active-lane mask, and the previously sampled token all live
  on device, greedy sampling is on-device, and the only per-iteration
  transfer is a single device->host pull of the sampled tokens;
* K and V for all new tokens of all lanes are written into the fused
  ``(L, P+1, 2, page, Kv, hd)`` pool with ONE scatter per layer (invalid
  slots are routed to a trash page, index ``P``, so no masking pass is
  needed);
* the device block table is repeat-padded (entries past the last mapped
  page repeat it) and updated incrementally — one small host->device row
  write per page allocation, amortized to ``<= 1/page_size`` per token.

Host<->device transfer events on this path are traced (``EventType.H2D`` /
``D2H``) so ``benchmarks/serve_throughput.py`` can count them.

Demo-scale engine for plain-GQA transformer archs (yi/minitron/qwen3/olmoe
smoke configs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.rab import RAB, RABConfig, PagedKVPool
from repro.core.tracing import EventType, TraceBuffer
from repro.models import layers as L
from repro.models import model as M
from repro.models.layers import rope, rms_head_norm
from repro.kernels.paged_attention.ops import (
    paged_prefill_fused, page_counts_for,
)
from repro.kernels.paged_attention.ref import paged_prefill_ref


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 8
    out: List[int] = dataclasses.field(default_factory=list)
    fed: int = 0                      # prompt tokens already consumed
    lane: int = -1
    done: bool = False


class PagedServer:
    def __init__(self, cfg: ArchConfig, params, *, num_pages: int = 64,
                 page_size: int = 8, max_lanes: int = 4,
                 max_pages_per_seq: int = 16, chunk: int = 16,
                 pages_per_step: int = 2,
                 rab_cfg: RABConfig = RABConfig(l1_entries=8, l2_entries=32,
                                                l2_assoc=4, l2_banks=2),
                 tracer: Optional[TraceBuffer] = None,
                 use_kernel: bool = True):
        assert cfg.block_kind == "transformer" and cfg.attention_kind == "gqa" \
            and not cfg.local_global_period, \
            "paged engine supports plain-GQA transformer archs"
        self.cfg, self.params = cfg, params
        self.page_size, self.max_lanes = page_size, max_lanes
        self.max_pages = max_pages_per_seq
        self.chunk = max(1, chunk)
        self.tracer = tracer or TraceBuffer()
        self.rab = RAB(rab_cfg, self.tracer)
        self.pool = PagedKVPool(num_pages, page_size, max_pages_per_seq,
                                self.rab)
        L_, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.param_dtype)
        # fused K/V pool; the extra page (index num_pages) is the trash page
        # masked writes are routed to
        self.kv_pages = jnp.zeros((L_, num_pages + 1, 2, page_size, kv, hd),
                                  dt)
        self.use_kernel = use_kernel
        itp = jax.default_backend() != "tpu"
        self._chunk_step = jax.jit(functools.partial(
            _paged_chunk_step, cfg, use_kernel, pages_per_step, itp,
            num_pages))
        self._decode_step = jax.jit(functools.partial(
            _paged_decode_step, cfg, use_kernel, pages_per_step, itp,
            num_pages))
        # device-resident engine state (HERO SVM: the scheduler and the
        # model share these without per-iteration re-uploads)
        self.bt_dev = jnp.zeros((max_lanes, max_pages_per_seq), jnp.int32)
        self.len_dev = jnp.zeros((max_lanes,), jnp.int32)
        self.active_dev = jnp.zeros((max_lanes,), jnp.int32)
        self.last_tok = jnp.zeros((max_lanes,), jnp.int32)
        self._bt_host = np.zeros((max_lanes, max_pages_per_seq), np.int32)
        self.lanes: List[Optional[Request]] = [None] * max_lanes
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.iterations = 0
        self.h2d_events = 0
        self.d2h_events = 0

    # --------------------------------------------------------------- trace --
    def _h2d(self, n: int = 1):
        self.h2d_events += n
        self.tracer.record_host(EventType.H2D, n, 0)

    def _d2h(self, n: int = 1):
        self.d2h_events += n
        self.tracer.record_host(EventType.D2H, n, 0)

    # ------------------------------------------------------------- admin --
    def submit(self, req: Request):
        # real exceptions, not asserts: an unplaceable request at the queue
        # head would otherwise spin _admit forever (and -O strips asserts)
        if not req.prompt:
            # an empty prompt would enter decode seeded by whatever token
            # the lane's previous occupant left in last_tok
            raise ValueError("empty prompt")
        if len(req.prompt) + req.max_new - 1 > \
                self.max_pages * self.page_size:
            raise ValueError("request exceeds max_pages_per_seq")
        if self._pages_needed(req) > self.pool.num_pages:
            raise ValueError("request exceeds KV pool capacity")
        self.queue.append(req)

    def _pages_needed(self, req: Request) -> int:
        # every token the engine will *write* K/V for: the prompt plus all
        # generated tokens except the last (sampled but never fed back)
        total = len(req.prompt) + req.max_new - 1
        return int(page_counts_for(total, self.page_size))

    def _admit(self):
        for i in range(self.max_lanes):
            if self.lanes[i] is None and self.queue:
                need = self._pages_needed(self.queue[0])
                if not self.pool.can_alloc(need):
                    break
                req = self.queue.pop(0)
                req.lane = i
                self.lanes[i] = req
                # reserve the request's full lifetime page budget so chunked
                # prefill can never hit pool exhaustion mid-stream
                self.pool.reserve(req.rid, need)
                self.active_dev = self.active_dev.at[i].set(1)
                self.len_dev = self.len_dev.at[i].set(0)
                self._h2d(1)
                self.tracer.record_host(EventType.REQUEST_ADMIT, req.rid, i)

    def _finish(self, req: Request):
        req.done = True
        self.tracer.record_host(EventType.REQUEST_FINISH, req.rid,
                                len(req.out))
        self.pool.release(req.rid)
        self.tracer.record_host(EventType.PAGE_RELEASE, req.rid, 0)
        self.lanes[req.lane] = None
        self.active_dev = self.active_dev.at[req.lane].set(0)
        self.len_dev = self.len_dev.at[req.lane].set(0)
        self._h2d(1)
        self.finished.append(req)

    # --------------------------------------------------------------- step --
    def step(self) -> bool:
        """One engine iteration.  Returns False when fully idle."""
        self._admit()
        active = [r for r in self.lanes if r is not None]
        if not active:
            return bool(self.queue)
        self.iterations += 1

        B, C = self.max_lanes, self.chunk
        n_new = np.zeros((B,), np.int32)
        feed = np.zeros((B, C), np.int32)
        use_last = np.zeros((B,), np.int32)
        decode_only = True
        for r in active:
            i = r.lane
            if r.fed < len(r.prompt):
                n = min(C, len(r.prompt) - r.fed)
                feed[i, :n] = r.prompt[r.fed:r.fed + n]
                n_new[i] = n
                decode_only = False
            else:
                n_new[i] = 1
                use_last[i] = 1     # token is device-resident; no upload

        # host-side page accounting: allocate (through the RAB translate
        # path) every page the new tokens touch, and push only the dirty
        # repeat-padded block-table rows to the device
        dirty = set()
        for r in active:
            i = r.lane
            for _ in range(int(n_new[i])):
                lpage, slot = self.pool.append_token(r.rid)
                if slot == 0:
                    phys = self.pool.translate(r.rid, lpage)
                    self.tracer.record_host(EventType.PAGE_ALLOC, r.rid, phys)
                    self._bt_host[i, lpage:] = phys
                    dirty.add(i)
        if dirty:
            rows = sorted(dirty)
            self.bt_dev = self.bt_dev.at[jnp.asarray(rows)].set(
                jnp.asarray(self._bt_host[rows]))
            self._h2d(len(rows))    # one dispatch, len(rows) rows uploaded

        if decode_only:
            # sync-free: every input already lives on device
            self.last_tok, self.kv_pages, self.len_dev = self._decode_step(
                self.params, self.kv_pages, self.bt_dev, self.len_dev,
                self.active_dev, self.last_tok)
        else:
            self._h2d(1)            # the prompt-chunk feed bundle
            self.last_tok, self.kv_pages, self.len_dev = self._chunk_step(
                self.params, self.kv_pages, self.bt_dev, self.len_dev,
                jnp.asarray(n_new), jnp.asarray(feed), self.last_tok,
                jnp.asarray(use_last))

        tok = np.asarray(self.last_tok)     # one pull per iteration
        self._d2h(1)

        for r in list(active):
            i = r.lane
            if r.fed < len(r.prompt):
                r.fed += int(n_new[i])
                if r.fed == len(r.prompt):
                    r.out.append(int(tok[i]))
            else:
                r.out.append(int(tok[i]))
            if len(r.out) >= r.max_new:
                self._finish(r)
        return True

    def run(self, max_iters: int = 10_000):
        it = 0
        while self.step():
            it += 1
            if it >= max_iters:
                break
        return self.finished


# ===========================================================================
# jitted engine steps
# ===========================================================================

def _write_coords(bt, lens, n_new, C, page_size, trash):
    """Physical (page, slot) for the C candidate token writes of each lane.

    Invalid slots (beyond a lane's n_new) are routed to the trash page so a
    single unmasked scatter covers every lane."""
    n_pages = bt.shape[1]
    pos = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B,C)
    lp = jnp.minimum(pos // page_size, n_pages - 1)
    sl = pos % page_size
    phys = jnp.take_along_axis(bt, lp, axis=1)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_new[:, None]
    return jnp.where(valid, phys, trash), sl


def _layer_qkv(cfg, lp, x, pos):
    h = L.norm_forward(cfg, lp["ln1"], x)
    ap = lp["attn"]
    q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, ap["wv"])
    if cfg.use_qk_norm:
        q = rms_head_norm(ap["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(ap["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def _layer_mlp(cfg, lp, x):
    h = L.norm_forward(cfg, lp["ln2"], x)
    if "moe" in lp:
        from repro.models import moe as MOE
        return x + MOE.moe_forward(cfg, lp["moe"], h)
    return x + L.mlp_forward(cfg, lp["mlp"], h)


def _paged_chunk_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                      interpret: bool, num_pages: int, params, kv_pages,
                      bt, lens, n_new, feed, last_tok, use_last):
    """Consume up to C tokens per lane: prompt chunks from ``feed``, decode
    lanes (``use_last``) from the device-resident previous sample.

    kv_pages: (L, P+1, 2, page, kv, hd); bt: (B, n_pages) repeat-padded.
    Returns (sampled_tokens (B,), kv_pages, new_lens)."""
    B, C = feed.shape
    page = kv_pages.shape[3]
    n_pages = bt.shape[1]
    tokens = feed.at[:, 0].set(jnp.where(use_last == 1, last_tok, feed[:, 0]))
    x = L.embed_tokens(cfg, params["embed"], tokens)        # (B,C,d)
    pos = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    new_lens = lens + n_new
    counts = page_counts_for(new_lens, page)
    phys, sl = _write_coords(bt, lens, n_new, C, page, num_pages)
    if not use_kernel:      # the -1-marked table form the oracle expects
        idx = jnp.arange(n_pages, dtype=jnp.int32)[None, :]
        bt_masked = jnp.where(idx < counts[:, None], bt, -1)

    for i in range(cfg.num_layers):
        lp = M._sub(params["layers"], i)
        q, k, v = _layer_qkv(cfg, lp, x, pos)
        # one fused scatter writes K AND V for all lanes' chunk tokens
        kv_pages = kv_pages.at[i, phys, :, sl].set(jnp.stack([k, v], axis=2))
        if use_kernel:
            a = paged_prefill_fused(q, kv_pages[i], bt, counts, new_lens,
                                    lens, interpret=interpret,
                                    pages_per_step=pages_per_step)
        else:
            a = paged_prefill_ref(q, kv_pages[i, :, 0], kv_pages[i, :, 1],
                                  bt_masked, new_lens, lens)
        x = x + jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"])
        x = _layer_mlp(cfg, lp, x)

    x = L.norm_forward(cfg, params["final_norm"], x)
    logits = L.logits_from_hidden(cfg, params["embed"], x)  # (B,C,V)
    row = jnp.maximum(n_new - 1, 0)
    last_logits = jnp.take_along_axis(logits, row[:, None, None],
                                      axis=1)[:, 0]
    nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    nxt = jnp.where(n_new > 0, nxt, last_tok)   # idle lanes keep their token
    return nxt, kv_pages, new_lens


def _paged_decode_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                       interpret: bool, num_pages: int, params, kv_pages,
                       bt, lens, active, last_tok):
    """One decode token for every active lane, entirely from device state —
    the C=1 case of the chunk step (mirroring paged_decode_fwd, which is the
    C=1 case of the prefill kernel), with every lane fed its device-resident
    previous sample.

    Returns (sampled_tokens (B,), kv_pages, new_lens)."""
    B = lens.shape[0]
    return _paged_chunk_step(
        cfg, use_kernel, pages_per_step, interpret, num_pages, params,
        kv_pages, bt, lens, active, jnp.zeros((B, 1), jnp.int32), last_tok,
        jnp.ones((B,), jnp.int32))
