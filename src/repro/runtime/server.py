"""Paged continuous-batching serving engine on the RAB + paged KV pool.

This is the serving-side integration of HERO's C1/C2: the host scheduler and
the accelerator share the *logical token address space* (SVM); the RAB
translates logical pages to physical KV pool slots; the attention kernels
(`kernels/paged_attention`) perform the translation on-device through the
scalar-prefetched block table; page allocation happens on the RAB miss path;
admit/finish/alloc/release are all traced (C4) so Fig.6-style timelines can
be reconstructed from a run.

The engine is driven through the unified generation API (``runtime.api``):
callers build an :class:`~repro.runtime.EngineConfig` (one spec for every
pool/scheduler/kernel/speculation knob — ``make_engine`` picks this class
or the sharded one from it) and submit frozen
:class:`~repro.runtime.GenerationRequest` objects whose
:class:`~repro.runtime.SamplingParams` carry the per-request decoding
policy.  Scheduler-internal mutable state (``fed``, ``lane``, ``swapped``,
``spec_*``) lives in the private :class:`SeqState`; what comes back is a
frozen :class:`~repro.runtime.GenerationResult` with a ``finish_reason``
(``stop`` / ``length`` / ``aborted`` / ``timeout`` / ``error`` /
``shed``).  ``engine.generate(requests)`` streams
:class:`~repro.runtime.TokenDelta` increments per iteration — ``run()``
is just the drained generator, and when its iteration cap is hit it
*aborts* (and surfaces) all still-queued/running work instead of
silently dropping it.

**Failure semantics** (HERO: run-time behavior must be *validatable* —
traced, perturbed, re-tested): every exceptional exit funnels through one
``_terminate`` path that releases pages with the same
refcount/CoW/reservation discipline as preemption.  Requests carry
optional deadlines (``timeout``), callers can ``cancel(rid)`` from the
streaming loop body (``aborted``), transient backing-store faults are
retried under a bounded budget — with ``retry_backoff_s > 0`` a failed
swap-in is *deferred* on the engine clock (the lane is released and other
lanes keep decoding; the resume retries when the backoff expires) rather
than sleeping in the tick — while persistent ones demote the *request* to
``error`` — never the engine; a drafter exception merely
disables speculation for its lane; a watchdog aborts lanes that stop
advancing; and when the queue exceeds ``max_queue_depth`` the
lowest-priority waiter is ``shed`` at admission.  All of it is traced
(``FAULT_INJECT`` / ``REQUEST_TIMEOUT`` / ``REQUEST_SHED`` / ``DEGRADE``)
so ``core.analysis.layer2_fault_recovery`` can audit a faulted run.

The hot path follows HERO's "keep the accelerator fed" discipline (Fig. 5 —
DMA double-buffering + zero-copy SVM so the host never serializes on the
data path):

* prompts are admitted through a *chunked prefill* step that consumes up to
  ``chunk`` tokens per engine iteration in one ``paged_prefill`` kernel
  launch (not token-by-token through the decode path);
* the decode step runs entirely from device-resident state — block tables,
  lengths, the active-lane mask, the previously sampled token AND the
  per-lane sampling policy (temperature / top-k / top-p / PRNG seed) all
  live on device; token selection is on-device (exact greedy argmax for
  ``temperature == 0`` lanes, batched temperature/top-k/top-p sampling
  otherwise, each lane's PRNG key folded by absolute sequence position so
  a request's stream is reproducible from its seed alone, independent of
  chunking, scheduling, preemption or sharding); the only per-iteration
  transfer is a single device->host pull of the sampled tokens;
* K and V for all new tokens of all lanes are written into the fused
  ``(L, P+1, 2, page, Kv, hd)`` pool with ONE scatter per layer (invalid
  slots are routed to a trash page, index ``P``, so no masking pass is
  needed);
* the device block table is repeat-padded (entries past the last mapped
  page repeat it) and updated incrementally — one small host->device row
  write per page allocation, amortized to ``<= 1/page_size`` per token.

Host<->device transfer events on this path are traced (``EventType.H2D`` /
``D2H``) so ``benchmarks/serve_throughput.py`` can count them.

On top of the hot path sit HERO's SVM page *sharing* and *reclamation*
(§2.2, §3.4), serving-side:

* **shared-prefix KV caching** — admission consults the pool's prefix
  index; pages already holding the request's prompt prefix are mapped into
  its block table (refcount bumped, RAB entries installed) and their
  prefill is skipped — only the tail chunk runs the prefill kernel.  A
  lane appending into a still-shared partial page is copy-on-written onto
  a private page through the ordinary allocation path;
* **preemptive scheduling** — admission is priority-ordered; when the pool
  (or lane set) is exhausted, the lowest-priority running lane is
  preempted: its pages swap out D2H to a ``HostBackingStore`` (non-shared
  pages are thereby reclaimed; shared ones drop this lane's refcount, the
  host copy making re-admission independent of the sharers' lifetimes)
  and swap back H2D on re-admission, with all traffic traced as
  SWAP_OUT/SWAP_IN plus the underlying H2D/D2H events.

**Speculative decoding** (``spec_k > 0``) is the host/accelerator split
itself: a cheap host-side drafter (``runtime.speculative``) proposes up to
K tokens per decode lane, the pool appends all K+1 candidate positions
(pages allocated, CoW applied — exactly the plain append path), and ONE
chunked verify step (``_paged_spec_step``, the chunk kernel re-used with
the drafts as the feed) greedily scores every position, counts the
accepted prefix on device and advances lengths by ``accepted + 1``.  The
host then *rolls back* the rejected tail: ``PagedKVPool.trim`` unmaps
pages wholly beyond the kept length (respecting refcounts, CoW copies and
the prefix index) and re-credits them to the request's reservation.
Greedy parity is structural — the accepted prefix plus the bonus token is
the exact greedy continuation — and therefore drafting is auto-restricted
to ``temperature == 0`` lanes: sampled lanes never propose drafts, but
they ride along in a verify iteration (their bonus token is drawn by the
same position-folded sampler the plain decode step uses, so their stream
is unchanged).  Per-lane K adapts to recent acceptance (full accept grows
it, zero accept halves it) and drafting is disabled while any request is
queued (preemption pressure: waiting work beats wider verification).
Proposals, acceptances and rollbacks are traced as SPEC_PROPOSE /
SPEC_ACCEPT / SPEC_ROLLBACK.

Demo-scale engine for plain-GQA transformer archs (yi/minitron/qwen3/olmoe
smoke configs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.offload import (
    BackingStoreError, DiskTier, HostBackingStore,
    TIER_CODES, TIER_DEVICE, TIER_HOST,
)
from repro.core.rab import RAB, RABConfig, PagedKVPool
from repro.core.tracing import EventType, TraceBuffer
from repro.models import layers as L
from repro.models import model as M
from repro.models.layers import rope, rms_head_norm
from repro.kernels.paged_attention.ops import (
    paged_prefill_fused, page_counts_for,
)
from repro.kernels.paged_attention.ref import paged_prefill_ref
from repro.optim.compress import SCALE_EPS, headwise_scales, quantize_int8
from repro.runtime.api import (
    CacheStats, EngineConfig, GenerationRequest, GenerationResult,
    SamplingParams, TokenDelta, FINISH_ABORTED, FINISH_ERROR, FINISH_LENGTH,
    FINISH_SHED, FINISH_STOP, FINISH_TIMEOUT,
)
from repro.runtime.clock import MonotonicClock
from repro.runtime.frontdoor import GreedyChunkPolicy
from repro.runtime.speculative import NGramDrafter


@dataclasses.dataclass
class SeqState:
    """Scheduler-internal mutable state for one admitted request.

    This is deliberately NOT part of the public API: callers see the
    frozen ``GenerationRequest`` going in and the frozen
    ``GenerationResult`` coming out; everything the scheduler mutates
    mid-flight (``fed``, ``lane``, ``swapped``, the ``spec_*`` counters)
    stays private to the engine."""
    rid: int
    prompt: List[int]
    sampling: SamplingParams
    priority: int = 0                 # scheduler class; higher preempts lower
    out: List[int] = dataclasses.field(default_factory=list)
    fed: int = 0                      # prompt tokens already consumed
    lane: int = -1
    done: bool = False
    finish_reason: Optional[str] = None
    prefix_hit_tokens: int = 0        # prompt tokens reused from the cache
    preemptions: int = 0
    arrival: int = -1                 # FIFO tiebreak, assigned by submit()
    cluster: int = 0                  # owning PMCA cluster (sharded engine)
    reg_pages: int = 0                # prompt pages published to the index
    swapped: Optional[List[int]] = None   # lpages parked in the backing store
    promoting: bool = False           # admitted, gated on an in-flight
    #                                   prefix-page promotion (the lane
    #                                   feeds nothing until it lands)
    deadline_iter: Optional[int] = None   # absolute engine-iteration bound
    deadline_t: Optional[float] = None    # absolute engine-clock bound
    not_before: float = 0.0           # engine-clock time before which this
    #                                   queued request may not be placed
    #                                   (deferred swap-in retry backoff)
    retry_attempt: int = 0            # deferred swap-in retries consumed
    error: Optional[str] = None       # diagnostic for error/timeout finishes
    progress_marker: Tuple[int, int] = (-1, -1)   # (fed, len(out)) watermark
    progress_iter: int = 0            # iteration the marker last advanced
    spec_k_cur: int = 0               # adaptive per-lane draft depth
    spec_proposed: int = 0            # drafted tokens sent to verification
    spec_accepted: int = 0            # drafted tokens the target confirmed
    spec_rejected: int = 0            # drafted tokens rolled back

    @property
    def max_new(self) -> int:
        return self.sampling.max_new


def _pack_kv_page(pages: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Fuse one page's int8 payload + f32 scales into a single 1-D uint8
    blob — the backing store's park/put contract is one ndarray per page
    (one CRC32 covers both, so a corrupted scale fails the checksum the
    same way corrupted page bytes do)."""
    return np.concatenate([
        np.ascontiguousarray(pages).view(np.uint8).reshape(-1),
        np.ascontiguousarray(scales).view(np.uint8).reshape(-1)])


def _unpack_kv_page(blob: np.ndarray, page_shape: tuple,
                    scale_shape: tuple) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of ``_pack_kv_page``."""
    split = int(np.prod(page_shape))
    blob = np.asarray(blob).view(np.uint8).reshape(-1)
    pages = blob[:split].view(np.int8).reshape(page_shape)
    scales = blob[split:].view(np.float32).reshape(scale_shape)
    return pages, scales


class PagedServer:
    def __init__(self, cfg: ArchConfig, params,
                 engine: Optional[EngineConfig] = None, *,
                 tracer: Optional[TraceBuffer] = None):
        if engine is None:
            engine = EngineConfig()
        assert cfg.block_kind == "transformer" and cfg.attention_kind == "gqa" \
            and not cfg.local_global_period, \
            "paged engine supports plain-GQA transformer archs"
        self.engine_cfg = engine
        self.cfg, self.params = cfg, params
        # EngineConfig.__post_init__ folded any legacy flat knobs into the
        # grouped CacheConfig and mirrored them back, so `engine.cache` is
        # always the authoritative spelling here
        self.cache_cfg = engine.cache
        # quantized KV serving: int8 pages + per-(page, K/V, head) scales.
        # Attention math stays fp32/bf16 — only residency/traffic shrink.
        self.quant_kv = self.cache_cfg.kv_dtype == "int8"
        self.page_size, self.max_lanes = self.cache_cfg.page_size, \
            engine.max_lanes
        self.max_pages = self.cache_cfg.max_pages_per_seq
        self.chunk = max(1, engine.chunk)
        self.tracer = tracer or TraceBuffer()
        self.use_kernel = engine.use_kernel
        # one time source for every scheduler timestamp (deadline_s
        # binding, retry backoff, straggler EMA): inject a VirtualClock
        # and the whole tick path replays deterministically
        self.clock = engine.clock if engine.clock is not None \
            else MonotonicClock()
        # the chunked-prefill/decode interleave as an explicit object
        self.policy = engine.scheduler_policy \
            if engine.scheduler_policy is not None else GreedyChunkPolicy()
        # speculative decoding: drafter proposes, the verify step disposes
        self.spec_k = max(0, engine.spec_k)
        self.drafter = engine.drafter if engine.drafter is not None else \
            (NGramDrafter() if self.spec_k else None)
        # overridable construction hooks: the sharded subclass substitutes
        # per-cluster pools and mesh-sharded device state here instead of
        # allocating the unsharded versions only to discard them
        self._build_pool(self.cache_cfg.num_pages, engine.rab_cfg)
        self._build_device_state(self.cache_cfg.num_pages,
                                 engine.pages_per_step)
        self._bt_host = np.zeros((self.max_lanes, self.max_pages),
                                 np.int32)
        self.lanes: List[Optional[SeqState]] = [None] * self.max_lanes
        self.queue: List[SeqState] = []
        self.finished: List[GenerationResult] = []
        self.iterations = 0
        self.prefill_tokens = 0       # prompt tokens run through prefill
        self.h2d_events = 0
        self.d2h_events = 0
        # shared-prefix caching + preemption (HERO SVM page sharing and
        # reclamation on the serving path)
        self.enable_prefix_cache = self.cache_cfg.enable_prefix_cache
        # fault tolerance: the injector (if any) perturbs the swap path;
        # it traces every decision through THIS engine's tracer so the
        # injected-vs-recovered story reads from one event stream
        self.faults = engine.fault_injector
        if self.faults is not None and self.faults.tracer is None:
            self.faults.tracer = self.tracer
        # hierarchical prefix cache (HERO SVM ladder: scratchpad -> host
        # DRAM -> storage).  Swap traffic and demoted cache entries share
        # one tier chain; with no host tier configured the store degrades
        # to the flat host-dict it always was.
        self.backing = self._build_backing_store()
        if self.cache_cfg.spill_enabled and self.enable_prefix_cache:
            for p in self._all_pools():
                p.spill_enabled = True
        self._promotions: List[dict] = []   # in-flight H2D prefetches
        self.cache_hit_pages = {"device": 0, "host": 0, "disk": 0}
        self.cache_miss_pages = 0
        self.swap_retries = max(0, engine.swap_retries)
        self.retry_backoff_s = max(0.0, engine.retry_backoff_s)
        self.max_queue_depth = max(0, engine.max_queue_depth)
        self.watchdog_iters = max(0, engine.watchdog_iters)
        self.straggler_factor = max(0.0, engine.straggler_factor)
        self.fault_retries = 0        # transient-fault retries attempted
        self.recovered_faults = 0     # ops that succeeded after retrying
        self.timeouts = 0
        self.cancelled = 0
        self.errors = 0               # per-request "error" demotions
        self.shed_count = 0
        self.degrades = 0             # DEGRADE events emitted
        self.straggler_steps = 0      # iterations the EMA watchdog flagged
        self._ema_step_s: Optional[float] = None
        self.preemptions = 0
        self._dirty: set = set()      # lane rows to push before the kernel
        self._arrival = 0
        self._deltas: List[TokenDelta] = []   # streamed by generate()
        self.spec_iterations = 0      # engine iterations that verified drafts
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_rejected = 0

    # --------------------------------------------------------------- trace --
    def _h2d(self, n: int = 1):
        self.h2d_events += n
        self.tracer.record_host(EventType.H2D, n, 0)

    def _d2h(self, n: int = 1):
        self.d2h_events += n
        self.tracer.record_host(EventType.D2H, n, 0)

    def _delta(self, rid: int, tokens=(), event: str = "token", data: int = 0,
               reason: Optional[str] = None):
        self._deltas.append(TokenDelta(rid=rid, tokens=tuple(tokens),
                                       event=event, data=data,
                                       finish_reason=reason))

    # ------------------------------------------------------ construction --
    def _build_pool(self, num_pages: int, rab_cfg: RABConfig):
        self.rab = RAB(rab_cfg, self.tracer)
        self.pool = PagedKVPool(num_pages, self.page_size, self.max_pages,
                                self.rab)

    def _build_device_state(self, num_pages: int, pages_per_step: int):
        cfg = self.cfg
        L_, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.int8 if self.quant_kv else jnp.dtype(cfg.param_dtype)
        # fused K/V pool; the extra page (index num_pages) is the trash page
        # masked writes are routed to
        self.kv_pages = jnp.zeros(
            (L_, num_pages + 1, 2, self.page_size, kv, hd), dt)
        # per-(page, K/V, kv-head) dequant scales riding with the pool.
        # Allocated in both modes so every step has one signature; in bf16
        # mode the quant=False trace never reads it and jit DCEs the input.
        self.kv_scales = jnp.zeros((L_, num_pages + 1, 2, kv), jnp.float32)
        itp = jax.default_backend() != "tpu"

        # two variants per step, keyed by "does any active lane sample?":
        # the all-greedy variant compiles without the sampler (no per-lane
        # sorts/softmax whose results a where() would discard), so the
        # historical greedy hot path pays nothing for the sampling API;
        # jit is lazy, so greedy-only workloads never compile the other
        def mk(step_fn):
            return {s: jax.jit(functools.partial(
                step_fn, cfg, self.use_kernel, pages_per_step, itp,
                num_pages, quant=self.quant_kv, sample=s))
                for s in (False, True)}

        self._chunk_step = mk(_paged_chunk_step)
        self._decode_step = mk(_paged_decode_step)
        if self.spec_k:
            self._spec_step = mk(_paged_spec_step)
        # device-resident engine state (HERO SVM: the scheduler and the
        # model share these without per-iteration re-uploads); the four
        # sampling-policy rows ride with the lane like lengths do
        self.bt_dev = jnp.zeros((self.max_lanes, self.max_pages), jnp.int32)
        self.len_dev = jnp.zeros((self.max_lanes,), jnp.int32)
        self.active_dev = jnp.zeros((self.max_lanes,), jnp.int32)
        self.last_tok = jnp.zeros((self.max_lanes,), jnp.int32)
        self.seed_dev = jnp.zeros((self.max_lanes,), jnp.uint32)
        self.temp_dev = jnp.zeros((self.max_lanes,), jnp.float32)
        self.topk_dev = jnp.zeros((self.max_lanes,), jnp.int32)
        self.topp_dev = jnp.ones((self.max_lanes,), jnp.float32)

    def _build_backing_store(self) -> HostBackingStore:
        cc = self.cache_cfg
        disk = DiskTier(cc.disk_tier_pages, cc.disk_dir) \
            if cc.disk_tier_pages else None
        return HostBackingStore(self.faults, host_pages=cc.host_tier_pages,
                                disk_tier=disk)

    # ---------------------------------------------------------- pool seam --
    # Every pool access for a placed request routes through these, so the
    # sharded subclass can substitute cluster-local pools and translate
    # local physical page ids into the fused device slab's global indices.
    def _pool_of(self, cluster: int) -> PagedKVPool:
        return self.pool

    def _pool(self, req: SeqState) -> PagedKVPool:
        return self._pool_of(req.cluster)

    def _all_pools(self) -> List[PagedKVPool]:
        """Every pool, indexed by cluster (one for the base engine)."""
        return [self.pool]

    def _capacity_pages(self) -> int:
        """Page capacity one request can draw from (per cluster)."""
        return self.pool.num_pages

    def _gpage(self, req: SeqState, p: int) -> int:
        """Pool-local physical page -> index into self.kv_pages."""
        return p

    def _gpage_c(self, cluster: int, p: int) -> int:
        """Cluster-local physical page -> index into self.kv_pages."""
        return p

    # ---------------------------------------------------- cache tier seam --
    def _cache_store_of(self, cluster: int) -> HostBackingStore:
        """Tier store carrying ``cluster``'s demoted prefix-cache pages
        (the sharded engine keeps one per cluster; swap traffic stays on
        ``self.backing`` regardless)."""
        return self.backing

    def _cache_stores(self) -> List[HostBackingStore]:
        return [self.backing]

    def close(self):
        """Release tier resources (disk-tier files and directories).
        Idempotent; the engine stays usable for stats reads afterwards."""
        stores = {id(s): s for s in self._cache_stores()}
        stores.setdefault(id(self.backing), self.backing)
        for st in stores.values():
            st.close()

    # ------------------------------------------------------------- admin --
    def submit(self, req: GenerationRequest):
        # real exceptions, not asserts: an unplaceable request at the queue
        # head would otherwise spin _admit forever (and -O strips asserts)
        if not req.prompt:
            # an empty prompt would enter decode seeded by whatever token
            # the lane's previous occupant left in last_tok
            raise ValueError("empty prompt")
        sp = req.sampling
        if len(req.prompt) + sp.max_new - 1 > \
                self.max_pages * self.page_size:
            raise ValueError("request exceeds max_pages_per_seq")
        seq = SeqState(rid=req.rid, prompt=list(req.prompt), sampling=sp,
                       priority=req.priority)
        if self._pages_needed(seq) + self._cow_budget(seq) > \
                self._capacity_pages():
            raise ValueError("request exceeds KV pool capacity")
        seq.arrival = self._arrival
        self._arrival += 1
        if req.deadline_iters is not None:
            seq.deadline_iter = self.iterations + req.deadline_iters
        if req.deadline_s is not None:
            # bound on the injected clock, not raw time.monotonic(): under
            # a VirtualClock the request times out at an exact, testable
            # tick; under the wall clock behaviour is unchanged
            seq.deadline_t = self.clock.now() + req.deadline_s
        self.tracer.record_host(EventType.REQUEST_ARRIVE, seq.rid,
                                len(self.queue))
        if self.spec_k and sp.greedy:
            # drafting is greedy-lane-only: verification is greedy argmax,
            # so a sampled lane's drafts could never be parity-accepted
            seq.spec_k_cur = self.spec_k
        self.queue.append(seq)
        if self.max_queue_depth and len(self.queue) > self.max_queue_depth:
            # admission-time load shedding: rather than admit work that
            # will thrash the pool, reject the lowest-priority waiter
            # (youngest within a class — so on a priority tie the
            # newcomer itself is turned away).  Preemption re-queues
            # bypass this: a victim already holds parked state and must
            # be allowed back.
            victim = min(self.queue, key=lambda r: (r.priority, -r.arrival))
            self.shed_count += 1
            self.tracer.record_host(EventType.REQUEST_SHED, victim.rid,
                                    len(self.queue))
            self._terminate(victim, FINISH_SHED, "shed",
                            diag="queue depth exceeded "
                                 f"{self.max_queue_depth}")

    def _pages_needed(self, req: SeqState) -> int:
        # every token the engine will *write* K/V for: the prompt plus all
        # generated tokens except the last (sampled but never fed back)
        total = len(req.prompt) + req.max_new - 1
        return int(page_counts_for(total, self.page_size))

    # --------------------------------------------------------- scheduler --
    def _cow_budget(self, req: SeqState) -> int:
        """One extra reserved page for a request whose prompt tail is
        partial: once that tail is *registered* in the prefix index, a
        later admission may share it, and this request's own next append
        then copy-on-writes — a page its plain per-page reservation never
        counted (the donor side of CoW must be budgeted too, or an
        admitted request could hit pool exhaustion mid-stream)."""
        return 1 if (self.enable_prefix_cache and req.max_new > 1
                     and len(req.prompt) % self.page_size) else 0

    def _plan(self, req: SeqState, cluster: int = 0) -> dict:
        """Admission plan against ``cluster``'s pool: which prefix-cache
        pages to map and how many pages to reserve.  ``need`` excludes only
        *stable* shared pages (fully written, never appended again); a
        shared partial tail keeps one reserved page as the sharer's
        copy-on-write budget, the donor-side CoW is budgeted by
        ``_cow_budget``, and a resuming request budgets every page it must
        restore or still allocate."""
        pool = self._pool_of(cluster)
        total = self._pages_needed(req) + self._cow_budget(req)
        ps = self.page_size
        if req.swapped is not None:            # resuming after preemption
            # preemption dropped every mapping, so the whole lifetime page
            # budget (restores + future allocations) is needed again
            return {"resume": True, "hits": [], "usable": 0,
                    "need": total, "cached_hits": 0, "cluster": cluster}
        usable, hits = 0, []
        if self.enable_prefix_cache and len(req.prompt) > 1:
            # the hit chain may cross tiers: ("device", ppage) entries map
            # by sharing, ("spilled", key) entries are non-resident and
            # cost a fresh page each (counted in `need` below) plus an
            # async promotion at placement
            entries, n = pool.match_prefix_tiered(req.prompt)
            # the final prompt token always runs through the model (it
            # produces the first sampled token), so it is never reused
            usable = min(n, len(req.prompt) - 1)
            hits = entries[:-(-usable // ps)] if usable else []
        # only *stable* device-resident full pages are free; spilled hits
        # still draw a page from the pool for their promoted payload
        full = usable // ps
        dev_full = sum(1 for i, (kind, _v) in enumerate(hits)
                       if kind == "device" and i < full)
        need = total - dev_full
        cached = sum(1 for kind, v in hits
                     if kind == "device" and v in pool.cached_free)
        plan = {"resume": False, "hits": hits, "usable": usable,
                "need": need, "cached_hits": cached, "cluster": cluster}
        if hits and not self._fits(plan):
            # hits sitting on cached-free pages cost evictable capacity a
            # no-sharing admission would simply reuse — never let the cache
            # starve a request that fits without it
            fallback = {"resume": False, "hits": [], "usable": 0,
                        "need": total, "cached_hits": 0, "cluster": cluster}
            if self._fits(fallback):
                return fallback
        return plan

    def _fits(self, plan: dict) -> bool:
        # reviving cached-free hit pages consumes them from the evictable
        # set, so they are budgeted on top of the reservation
        return self._pool_of(plan["cluster"]).available() >= \
            plan["need"] + plan["cached_hits"]

    def _victim(self, head: SeqState) -> Optional[SeqState]:
        """Lowest-priority running request (youngest within a class) —
        preemptable only by a strictly higher-priority waiter, so equal
        classes never churn each other."""
        running = [r for r in self.lanes if r is not None]
        if not running:
            return None
        v = min(running, key=lambda r: (r.priority, -r.arrival))
        return v if v.priority < head.priority else None

    def _eligible_head(self) -> Optional[SeqState]:
        """Highest-priority oldest waiter whose deferred-retry backoff (if
        any) has expired on the engine clock.  Deferred requests are
        skipped, not blocking: a lane freed behind one backing-off resume
        goes to the next waiter instead of idling."""
        self.queue.sort(key=lambda r: (-r.priority, r.arrival))
        now = self.clock.now()
        return next((r for r in self.queue if r.not_before <= now), None)

    def _admit(self):
        while self.queue:
            # re-sort every round (inside _eligible_head): _preempt
            # re-enqueues its victim, which must keep its priority rank
            # over lower-priority waiters
            head = self._eligible_head()
            if head is None:
                break                     # every waiter is backing off
            lane = next((i for i in range(self.max_lanes)
                         if self.lanes[i] is None), None)
            plan = self._plan(head)
            if lane is None or not self._fits(plan):
                victim = self._victim(head)
                if victim is None:
                    break
                self._preempt(victim)
                continue                  # pool/lane state changed: re-plan
            self.queue.remove(head)
            self._place(head, lane, plan)

    def _resolve_spilled_hits(self, req: SeqState, plan: dict):
        """Pull every spilled hit's payload out of the tier store *before*
        any pool mutation.  A fetch fault (CRC mismatch, injected pop
        fault past the retry budget) drops that entry from every tier and
        re-plans — dropping a spilled hit never changes ``need`` (device
        hits are untouched), so the replacement plan still fits and the
        admission proceeds with whatever prefix remains."""
        pool = self._pool_of(plan["cluster"])
        store = self._cache_store_of(plan["cluster"])
        while True:
            fetched: dict = {}
            ok = True
            for lp, (kind, val) in enumerate(plan["hits"]):
                if kind != "spilled":
                    continue
                eid = pool.key_ids[val]
                try:
                    payload, tier = self._with_retries(functools.partial(
                        store.fetch_cache, eid, req.rid), req.rid)
                except BackingStoreError:
                    pool.drop_spilled(val)
                    store.drop_cache(eid)
                    self._trace_store_moves(store)
                    ok = False
                    break
                fetched[lp] = (eid, payload, tier)
            if ok:
                return plan, fetched
            plan = self._plan(req, plan["cluster"])

    def _place(self, req: SeqState, lane: int, plan: dict):
        rid = req.rid
        req.lane = lane
        req.cluster = plan["cluster"]
        pool = self._pool(req)
        self.lanes[lane] = req
        fetched: dict = {}
        if not plan["resume"] and any(k == "spilled" for k, _ in
                                      plan["hits"]):
            # fetch before reserving: a fetch fault re-plans through
            # _fits, which must not see this request's own reservation
            plan, fetched = self._resolve_spilled_hits(req, plan)
        if not plan["resume"]:
            prompt_pages = -(-len(req.prompt) // self.page_size)
            self.cache_miss_pages += prompt_pages - len(plan["hits"])
        if plan["need"] > 0:
            # reserve the request's remaining lifetime page budget so
            # chunked prefill / restore can never hit exhaustion mid-stream
            pool.reserve(rid, plan["need"])
        req.progress_marker = (req.fed, len(req.out))
        req.progress_iter = self.iterations   # queue time never counts
        if plan["resume"]:
            try:
                self._swap_in(req)
            except BackingStoreError as e:
                if self._defer_resume(req, e):
                    # transient fault with backoff configured: undo the
                    # placement and re-queue the resume for a later tick —
                    # the lane goes back to the pool and every other lane
                    # keeps decoding while this request backs off
                    self._unplace(req)
                    return
                # the parked payload is unrestorable: demote THIS request
                # (reservation and any partial restore released through
                # _terminate) and keep serving everyone else
                self._fail(req, str(e))
                return
            if req.retry_attempt:
                # a deferred-retry resume finally restored: count the
                # recovery the in-place retry path would have counted
                self.recovered_faults += 1
                req.retry_attempt = 0
        elif plan["usable"]:
            # prefix-cache hit: map resident pages by sharing; adopt a
            # fresh page for each spilled hit (its payload was fetched
            # above and is uploaded in _begin_promotion below)
            promo: List[tuple] = []
            for lp, (kind, val) in enumerate(plan["hits"]):
                if kind == "device":
                    pool.share_page(rid, lp, val)
                    self.cache_hit_pages["device"] += 1
                else:
                    eid, payload, tier = fetched[lp]
                    p = pool.adopt_spilled(rid, lp, val)
                    self.cache_hit_pages[tier] += 1
                    promo.append((self._gpage(req, p), eid, payload, tier))
            pool.seq_len[rid] = plan["usable"]
            pool.stats["prefix_hit_tokens"] += plan["usable"]
            req.fed = plan["usable"]
            req.prefix_hit_tokens = plan["usable"]
            req.reg_pages = plan["usable"] // self.page_size
            self.tracer.record_host(EventType.PREFIX_HIT, rid,
                                    plan["usable"])
            self._delta(rid, event="prefix_hit", data=plan["usable"])
            if promo:
                # adopting may have evicted+spilled other entries: park
                # their payloads before the promotion upload can land on
                # a recycled page
                self._drain_tier_ops()
                self._begin_promotion(req, promo)
        self._refresh_row(lane, req)
        sp = req.sampling
        self.active_dev = self.active_dev.at[lane].set(
            0 if req.promoting else 1)
        self.len_dev = self.len_dev.at[lane].set(
            pool.seq_len.get(rid, 0))
        self.seed_dev = self.seed_dev.at[lane].set(sp.seed & 0xFFFFFFFF)
        self.temp_dev = self.temp_dev.at[lane].set(sp.temperature)
        self.topk_dev = self.topk_dev.at[lane].set(sp.top_k)
        self.topp_dev = self.topp_dev.at[lane].set(sp.top_p)
        if plan["resume"] and req.fed >= len(req.prompt) and req.out:
            # mid-decode resume: re-seed the device-resident last sample
            self.last_tok = self.last_tok.at[lane].set(req.out[-1])
        self._h2d(1)
        self.tracer.record_host(EventType.REQUEST_ADMIT, rid, lane)

    def _defer_resume(self, req: SeqState, e: BackingStoreError) -> bool:
        """Should this failed swap-in be rescheduled instead of demoting
        the request?  Yes iff the fault is transient, a backoff is
        configured (``retry_backoff_s > 0`` — with 0 the in-place retry
        loop already ran inside ``_swap_in``) and budget remains.  On
        True the request's ``not_before`` is set to the exponential-
        backoff deadline on the engine clock; the caller unwinds the
        placement.  The engine never sleeps: other lanes keep emitting
        tokens while this request waits out its backoff in the queue."""
        if not (e.transient and self.retry_backoff_s
                and req.retry_attempt < self.swap_retries):
            return False
        req.retry_attempt += 1
        self.fault_retries += 1
        req.not_before = self.clock.now() + \
            self.retry_backoff_s * (2 ** (req.retry_attempt - 1))
        return True

    def _unplace(self, req: SeqState):
        """Reverse an in-progress ``_place`` whose swap-in was deferred:
        free the lane, drop the reservation, re-queue the request (still
        ``swapped`` — ``_swap_in`` re-parked everything it had popped, so
        the backing store is exactly as before the attempt)."""
        pool, lane = self._pool(req), req.lane
        pool.reserved.pop(req.rid, None)
        self.lanes[lane] = None
        req.lane = -1
        self.queue.append(req)

    # -------------------------------------------------- page payload seam --
    # Every D2H snapshot / H2D restore of whole pages routes through these,
    # so the quantized path can carry the scales alongside the int8 bytes
    # (packed into one blob per page — one checksum, one tier entry) while
    # bf16 payloads keep their historical raw-array format.
    def _page_shapes(self) -> Tuple[tuple, tuple]:
        """(per-page payload shape, per-page scale shape) across layers."""
        L_ = self.cfg.num_layers
        kv, hd = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
        return (L_, 2, self.page_size, kv, hd), (L_, 2, kv)

    def _snap_pages(self, idx: List[int]) -> List[np.ndarray]:
        """One gathered D2H pull of ``idx``'s pages; returns one payload
        per page (packed blobs in int8 mode)."""
        gi = jnp.asarray(idx)
        payload = np.asarray(self.kv_pages[:, gi])
        if not self.quant_kv:
            return [payload[:, j] for j in range(len(idx))]
        scales = np.asarray(self.kv_scales[:, gi])
        return [_pack_kv_page(payload[:, j], scales[:, j])
                for j in range(len(idx))]

    def _load_pages(self, phys: List[int], payloads: List[np.ndarray]):
        """One batched H2D restore of ``payloads`` into pool slots
        ``phys`` (unpacking blob payloads into pages + scales in int8
        mode)."""
        gi = jnp.asarray(phys)
        if not self.quant_kv:
            payload = jnp.stack([jnp.asarray(p) for p in payloads], axis=1)
            self.kv_pages = self.kv_pages.at[:, gi].set(
                payload.astype(self.kv_pages.dtype))
            return
        pshape, sshape = self._page_shapes()
        parts = [_unpack_kv_page(p, pshape, sshape) for p in payloads]
        pages = jnp.stack([jnp.asarray(pg) for pg, _ in parts], axis=1)
        scales = jnp.stack([jnp.asarray(sc) for _, sc in parts], axis=1)
        self.kv_pages = self.kv_pages.at[:, gi].set(
            pages.astype(self.kv_pages.dtype))
        self.kv_scales = self.kv_scales.at[:, gi].set(scales)

    def _preempt(self, req: SeqState):
        """Reclaim a running lane: every mapped page's payload goes D2H
        into the host backing store and the mapping drops.  Non-shared
        pages are thereby freed immediately; shared pages merely lose this
        request's refcount (they live on under their other owners or on
        the cached-free list), but checkpointing their payload too makes
        re-admission independent of those owners' lifetimes — so a full
        preemption sweep always reclaims everything a victim held and the
        scheduler can never pin the pool behind preempted sequences."""
        rid, i = req.rid, req.lane
        pool = self._pool(req)
        if req.promoting:
            # the promoted payload is already device-resident (uploaded at
            # placement), so the sweep below checkpoints correct data —
            # just close the in-flight promotion's books first
            self._land_promotions(force_rid=rid)
        mapped = pool.seq_pages(rid)
        if mapped:
            payloads = self._snap_pages([self._gpage(req, p)
                                         for _, p in mapped])
            self._d2h(len(mapped))    # one gather, len(mapped) pages pulled
            try:
                for j, (lp, _p) in enumerate(mapped):
                    self._with_retries(functools.partial(
                        self.backing.put, rid, lp, payloads[j]), rid)
                    pool.unmap_page(rid, lp)
            except BackingStoreError as e:
                # checkpoint failed persistently mid-sweep: the victim
                # cannot be parked, so demote it — _terminate releases the
                # still-mapped tail and discards the already-parked head
                self._fail(req, str(e))
                return
        req.swapped = [lp for lp, _ in mapped]
        pool.reserved.pop(rid, None)
        req.lane = -1
        req.preemptions += 1
        self.preemptions += 1
        self.lanes[i] = None
        self.active_dev = self.active_dev.at[i].set(0)
        self.len_dev = self.len_dev.at[i].set(0)
        self._h2d(1)
        pool.stats["swapped_out"] += len(mapped)
        self.tracer.record_host(EventType.SWAP_OUT, rid, len(mapped))
        self.tracer.record_host(EventType.REQUEST_PREEMPT, rid, len(mapped))
        self._delta(rid, event="preempt", data=len(mapped))
        self.queue.append(req)

    def preempt(self, rid: int) -> bool:
        """Forcibly preempt a running request (test/benchmark hook; pool
        pressure drives the same path through the scheduler)."""
        for r in self.lanes:
            if r is not None and r.rid == rid:
                self._preempt(r)
                return True
        return False

    def _swap_in(self, req: SeqState):
        """Restore a preempted request's swapped pages: fresh physical
        pages, one batched H2D payload upload, mappings re-established.

        Raises :class:`BackingStoreError` when a parked payload cannot be
        restored (persistent fault / checksum mismatch / retry budget
        exhausted); payloads are popped *before* any pool mutation and
        ``req.swapped`` stays set until all pops succeed, so the caller's
        demotion path (``_place``) releases a consistent request.

        With ``retry_backoff_s > 0`` a transient pop fault is NOT retried
        in place: already-popped payloads are re-parked (the store ends
        up exactly as before the attempt — the faulted page itself was
        never removed, the injector fires before removal) and the error
        propagates so ``_place`` can defer the whole resume on the engine
        clock instead of stalling the tick."""
        rid = req.rid
        pool = self._pool(req)
        lps = req.swapped
        if not lps:
            req.swapped = None
            return
        deferring = bool(self.retry_backoff_s)
        payloads: List[np.ndarray] = []
        try:
            for lp in lps:
                if deferring:
                    payloads.append(self.backing.pop(rid, lp))
                else:
                    payloads.append(self._with_retries(functools.partial(
                        self.backing.pop, rid, lp), rid))
        except BackingStoreError as e:
            if deferring and e.transient \
                    and req.retry_attempt < self.swap_retries:
                for lp, payload in zip(lps, payloads):
                    self.backing.repark(rid, lp, payload)
            raise
        req.swapped = None
        phys = [self._gpage(req, pool.alloc_page(rid, lp)) for lp in lps]
        # allocating may have evicted+spilled indexed pages: park their
        # payloads before this restore's upload can overwrite them
        self._drain_tier_ops()
        self._load_pages(phys, payloads)
        self._h2d(len(lps))
        pool.stats["swapped_in"] += len(lps)
        self.tracer.record_host(EventType.SWAP_IN, rid, len(lps))

    def _refresh_row(self, lane: int, req: SeqState):
        """Rebuild a lane's repeat-padded host block-table row from the
        pool (through the RAB translate path) and mark it for upload."""
        pool, rid = self._pool(req), req.rid
        n = pool.seq_len.get(rid, 0)
        n_pages = -(-n // self.page_size) if n else 0
        last = 0
        for lp in range(n_pages):
            last = pool.translate(rid, lp)
            self._bt_host[lane, lp] = last
        self._bt_host[lane, n_pages:] = last
        self._dirty.add(lane)

    def _register_prompt_pages(self, active: List[SeqState],
                               n_new: np.ndarray):
        """Publish prompt-prefix pages completed this iteration into the
        prefix index (full pages as they fill; the partial tail page once
        the whole prompt is pool-resident).  Decode-phase pages are never
        indexed — generated tokens are request-private."""
        if not self.enable_prefix_cache:
            return
        ps = self.page_size
        for r in active:
            if n_new[r.lane] == 0 or r.fed >= len(r.prompt):
                continue
            pool = self._pool(r)
            written = min(pool.seq_len.get(r.rid, 0), len(r.prompt))
            for lp in range(r.reg_pages, written // ps):
                pool.register_page(r.rid, lp, r.prompt)
            r.reg_pages = max(r.reg_pages, written // ps)
            if written == len(r.prompt) and written % ps:
                pool.register_page(r.rid, written // ps, r.prompt)

    # ------------------------------------------- hierarchical cache tiers --
    def _trace_store_moves(self, store: HostBackingStore):
        for eid, src, dst in store.drain_cache_moves():
            self.tracer.record_host(EventType.PAGE_DEMOTE, eid,
                                    src * 4 + dst)

    def _drain_tier_ops(self):
        """Service the pools' pending tier transitions: pull the payload
        of every just-demoted page D2H and park it in the tier store
        (MUST run before any device write that could recycle the page),
        drop entries a re-registration superseded, then trace the store's
        own cascade moves (host -> disk under pressure, drops)."""
        for c, pool in enumerate(self._all_pools()):
            if not (pool.pending_demote or pool.pending_spill_drop):
                continue
            store = self._cache_store_of(c)
            moves = pool.drain_demotions()
            # skip entries superseded between eviction and this drain
            live = [(p, key) for p, key in moves if key in pool.spilled]
            if live:
                payloads = self._snap_pages([self._gpage_c(c, p)
                                             for p, _ in live])
                self._d2h(len(live))
                for j, (_p, key) in enumerate(live):
                    eid = pool.key_ids[key]
                    store.park_cache(eid, payloads[j])
                    self.tracer.record_host(EventType.PAGE_DEMOTE, eid,
                                            TIER_DEVICE * 4 + TIER_HOST)
            for key in pool.drain_spill_drops():
                store.drop_cache(pool.key_ids[key])
            self._trace_store_moves(store)

    def _begin_promotion(self, req: SeqState, promo: List[tuple]):
        """Upload the fetched spilled payloads into their adopted device
        pages and schedule the promotion's *landing* on the engine clock.

        The payload write is issued immediately — the pages are never
        garbage, so sharers admitted off the restored index entries and
        preemption sweeps always read correct data — but the admitted
        lane stays gated (``active_dev`` 0, fed nothing) until the
        modeled H2D prefetch latency elapses: ``prefetch_depth`` pages
        move per latency quantum.  All timing binds through
        ``self.clock`` (never raw time.*), so a VirtualClock replays the
        whole overlap byte-identically."""
        cc = self.cache_cfg
        self._load_pages([g for g, _eid, _pl, _t in promo],
                         [pl for _g, _eid, pl, _t in promo])
        self._h2d(len(promo))
        quanta = -(-len(promo) // max(1, cc.prefetch_depth))
        due = self.clock.now() + cc.promote_latency_s * quanta
        req.promoting = True
        self._promotions.append({
            "rid": req.rid, "due": due,
            "pages": [(eid, t) for _g, eid, _pl, t in promo]})

    def _land_promotions(self, force_rid: Optional[int] = None):
        """Complete every promotion whose due time has passed (or whose
        owner ``force_rid`` is being preempted/terminated — the payload
        is already device-resident, so cancellation just closes the
        books): trace PAGE_PROMOTE per page and un-gate the lane."""
        if not self._promotions:
            return
        now = self.clock.now()
        rest = []
        for pr in self._promotions:
            if pr["due"] > now and pr["rid"] != force_rid:
                rest.append(pr)
                continue
            for eid, tier in pr["pages"]:
                self.tracer.record_host(EventType.PAGE_PROMOTE, eid,
                                        TIER_CODES[tier] * 4 + TIER_DEVICE)
            req = next((r for r in self.lanes
                        if r is not None and r.rid == pr["rid"]), None)
            if req is not None and req.promoting:
                req.promoting = False
                # the gated interval must not count against the lane's
                # progress watchdog
                req.progress_iter = self.iterations
                self.active_dev = self.active_dev.at[req.lane].set(1)
                self._h2d(1)
        self._promotions = rest

    def _runnable(self) -> List[SeqState]:
        """Lanes the iteration may feed: resident and not promotion-gated."""
        return [r for r in self.lanes if r is not None and not r.promoting]

    def cache_stats(self) -> CacheStats:
        """One frozen snapshot of the hierarchical prefix cache — tier
        residency, per-tier admission hits, promotion/demotion traffic —
        aggregated across clusters.  The supported way to observe the
        cache (benchmarks and tests poke no pool internals)."""
        pools = self._all_pools()
        stores = self._cache_stores()
        resident = {"host": 0, "disk": 0}
        bytes_dem = bytes_pro = dropped = 0
        for st in stores:
            r = st.cache_resident()
            resident["host"] += r.get("host", 0)
            resident["disk"] += r.get("disk", 0)
            bytes_dem += st.cache_bytes_demoted
            bytes_pro += st.cache_bytes_promoted
            dropped += st.cache_dropped
        cfg = self.cfg
        kv_hd = cfg.num_kv_heads * cfg.resolved_head_dim
        if self.quant_kv:
            # int8 page bytes plus the per-page scale slab amortized over
            # the page's token slots (4 bytes per (K/V, head) per page)
            bpt = cfg.num_layers * 2 * (
                kv_hd + 4.0 * cfg.num_kv_heads / self.page_size)
        else:
            bpt = cfg.num_layers * 2 * kv_hd * \
                jnp.dtype(cfg.param_dtype).itemsize
        return CacheStats(
            bytes_per_token=float(bpt),
            device_pages=sum(p.num_pages for p in pools),
            device_indexed=sum(len(p.prefix_index) for p in pools),
            device_cached_free=sum(len(p.cached_free) for p in pools),
            host_pages=resident["host"],
            disk_pages=resident["disk"],
            hits_device_pages=self.cache_hit_pages["device"],
            hits_host_pages=self.cache_hit_pages["host"],
            hits_disk_pages=self.cache_hit_pages["disk"],
            miss_pages=self.cache_miss_pages,
            prefix_hit_tokens=sum(p.stats["prefix_hit_tokens"]
                                  for p in pools),
            promotions_in_flight=len(self._promotions),
            demoted_pages=sum(p.stats["cache_demoted"] for p in pools),
            promoted_pages=sum(p.stats["cache_promoted"] for p in pools),
            dropped_entries=dropped,
            bytes_demoted=bytes_dem,
            bytes_promoted=bytes_pro,
            evictions=sum(p.stats["cache_evictions"] for p in pools))

    # ------------------------------------------------------------- finish --
    def _emit(self, req: SeqState, toks) -> tuple:
        """Append generated tokens to ``req``, honouring stop tokens and
        the token budget.  Returns (kept tokens, finish_reason or None);
        a stop token IS included in the output (like an EOS) and wins over
        the length bound when both trigger on the same token."""
        kept: List[int] = []
        reason = None
        stop = req.sampling.stop_tokens
        for t in toks:
            t = int(t)
            req.out.append(t)
            kept.append(t)
            if t in stop:
                reason = FINISH_STOP
                break
            if len(req.out) >= req.max_new:
                reason = FINISH_LENGTH
                break
        return kept, reason

    def _result(self, req: SeqState) -> GenerationResult:
        return GenerationResult(
            rid=req.rid, prompt=tuple(req.prompt), tokens=tuple(req.out),
            finish_reason=req.finish_reason or FINISH_LENGTH,
            prefix_hit_tokens=req.prefix_hit_tokens,
            preemptions=req.preemptions, cluster=req.cluster,
            spec_proposed=req.spec_proposed,
            spec_accepted=req.spec_accepted,
            spec_rejected=req.spec_rejected,
            spec_k_final=req.spec_k_cur,
            error=req.error)

    def _finish(self, req: SeqState, reason: str):
        req.done = True
        req.finish_reason = reason
        self.tracer.record_host(EventType.REQUEST_FINISH, req.rid,
                                len(req.out))
        self._pool(req).release(req.rid)
        self.tracer.record_host(EventType.PAGE_RELEASE, req.rid, 0)
        self.lanes[req.lane] = None
        self.active_dev = self.active_dev.at[req.lane].set(0)
        self.len_dev = self.len_dev.at[req.lane].set(0)
        self._h2d(1)
        self.finished.append(self._result(req))

    def _terminate(self, req: SeqState, reason: str, event: str,
                   diag: Optional[str] = None):
        """Single exceptional-finish path: abort / cancel / timeout /
        error-demotion / shed all release the request's resources through
        the same refcount/CoW/reservation-aware route preemption uses
        (``pool.release`` drops every mapping and reservation credit;
        shared pages merely lose this request's refcount) and surface a
        finished result + terminal delta instead of silently dropping
        work.  Works on queued, running and preempted-parked requests."""
        req.done = True
        req.finish_reason = reason
        req.error = diag
        if req.promoting:
            self._land_promotions(force_rid=req.rid)
        if req in self.queue:
            self.queue.remove(req)
        self._pool(req).release(req.rid)
        if req.lane >= 0:
            self.lanes[req.lane] = None
            self.active_dev = self.active_dev.at[req.lane].set(0)
            self.len_dev = self.len_dev.at[req.lane].set(0)
            req.lane = -1
            self._h2d(1)
        # parked payload (if any) is dropped, not restored — no swap-in
        # traffic; discard is a no-op when nothing of ``rid`` is parked
        self.backing.discard(req.rid)
        req.swapped = None
        self.tracer.record_host(EventType.REQUEST_FINISH, req.rid,
                                len(req.out))
        self.tracer.record_host(EventType.PAGE_RELEASE, req.rid, 0)
        self.finished.append(self._result(req))
        self._delta(req.rid, event=event, reason=reason)

    def _fail(self, req: SeqState, diag: str):
        """Per-request ``"error"`` demotion: a persistent (or
        retry-exhausted) fault takes down THIS request, never the
        engine."""
        self.errors += 1
        self._terminate(req, FINISH_ERROR, "error", diag=diag)

    def _abort(self, req: SeqState):
        """Release a still-queued/running request at the iteration cap and
        surface it as a finished-with-``aborted`` result instead of
        silently dropping it."""
        self._terminate(req, FINISH_ABORTED, "abort")

    def _abort_all(self):
        pending = [r for r in self.lanes if r is not None] + list(self.queue)
        for r in pending:
            self._abort(r)

    def cancel(self, rid: int) -> bool:
        """User-initiated cancellation, callable from the streaming
        consumer's loop body (like mid-stream ``submit``): the request —
        queued, running or preempted-parked — finishes with
        ``finish_reason="aborted"``, its pages released through the
        preemption-grade path, and its terminal delta reaches the stream
        on the current drain.  Returns False for unknown/finished rids."""
        for r in list(self.lanes) + list(self.queue):
            if r is not None and r.rid == rid and not r.done:
                self.cancelled += 1
                self._terminate(r, FINISH_ABORTED, "cancel")
                return True
        return False

    def _expired(self, req: SeqState, now: float) -> bool:
        return (req.deadline_iter is not None
                and self.iterations >= req.deadline_iter) or \
               (req.deadline_t is not None and now >= req.deadline_t)

    def _sweep_deadlines(self):
        """Finish every queued/running request whose deadline has passed
        with ``finish_reason="timeout"`` (tokens generated so far are
        kept).  Runs ahead of admission each step, so a timed-out waiter
        never consumes pool capacity it can no longer use."""
        pending = [r for r in self.lanes if r is not None] + list(self.queue)
        if not any(r.deadline_iter is not None or r.deadline_t is not None
                   for r in pending):
            return
        now = self.clock.now()
        for r in pending:
            if self._expired(r, now):
                self.timeouts += 1
                self.tracer.record_host(EventType.REQUEST_TIMEOUT, r.rid,
                                        self.iterations)
                self._terminate(
                    r, FINISH_TIMEOUT, "timeout",
                    diag=f"deadline exceeded at iteration {self.iterations}")

    def _with_retries(self, fn: Callable[[], object], rid: int):
        """Run one backing-store op under the engine's retry policy:
        transient faults retry immediately, up to ``swap_retries`` times;
        persistent faults (and exhausted budgets) re-raise for the caller
        to demote the request.  This loop NEVER sleeps — spacing retries
        out in time is the deferred-resume path (``_defer_resume``),
        which reschedules on the engine clock while other lanes run."""
        attempt = 0
        while True:
            try:
                out = fn()
                if attempt:
                    self.recovered_faults += 1
                return out
            except BackingStoreError as e:
                if not e.transient or attempt >= self.swap_retries:
                    raise
                attempt += 1
                self.fault_retries += 1

    # --------------------------------------------------------------- step --
    def _account_appends(self, active: List[SeqState], n_new: np.ndarray):
        """Host-side page accounting for this iteration's candidate writes:
        allocate (through the RAB translate path) every page the new tokens
        touch, apply any copy-on-write remaps, and push only the dirty
        repeat-padded block-table rows."""
        dirty, self._dirty = self._dirty, set()
        cow_src: List[int] = []
        cow_dst: List[int] = []
        fresh_pages: List[int] = []
        for r in active:
            i = r.lane
            pool = self._pool(r)
            for _ in range(int(n_new[i])):
                lpage, slot = pool.append_token(r.rid)
                if slot == 0:
                    phys = pool.translate(r.rid, lpage)
                    self.tracer.record_host(EventType.PAGE_ALLOC, r.rid, phys)
                    self._bt_host[i, lpage:] = phys
                    fresh_pages.append(self._gpage(r, phys))
                    dirty.add(i)
                for (s, lp, src, dst) in pool.drain_cow():
                    # the writer was remapped off a shared page: patch its
                    # row and queue the device-side payload copy (slab
                    # indices are global; the block table stays pool-local)
                    cow_src.append(self._gpage(r, src))
                    cow_dst.append(self._gpage(r, dst))
                    self._bt_host[i, lp:] = dst
                    dirty.add(i)
                    self.tracer.record_host(EventType.PAGE_COW, s, dst)
        # park payloads of pages the appends just evicted-and-spilled
        # BEFORE the CoW copy / K-V scatter can write into them
        self._drain_tier_ops()
        if self.quant_kv and fresh_pages:
            # a recycled page must not inherit its previous owner's scale:
            # the running-max would only ever grow across pool reuse and
            # quantization precision would decay with pool age
            self.kv_scales = self.kv_scales.at[
                :, jnp.asarray(fresh_pages)].set(0.0)
        if cow_src:
            # one batched on-device page copy, applied before this step's
            # K/V scatter so the write lands in the private copy
            self.kv_pages = self.kv_pages.at[:, jnp.asarray(cow_dst)].set(
                self.kv_pages[:, jnp.asarray(cow_src)])
            if self.quant_kv:
                # the private copy inherits the donor page's scales too
                self.kv_scales = self.kv_scales.at[
                    :, jnp.asarray(cow_dst)].set(
                    self.kv_scales[:, jnp.asarray(cow_src)])
        self._register_prompt_pages(active, n_new)
        # registration may supersede spilled entries; drop them down-tier
        self._drain_tier_ops()
        if dirty:
            rows = sorted(dirty)
            self.bt_dev = self.bt_dev.at[jnp.asarray(rows)].set(
                jnp.asarray(self._bt_host[rows]))
            self._h2d(len(rows))    # one dispatch, len(rows) rows uploaded

    def step(self) -> bool:
        """One engine iteration.  Returns False when fully idle.

        Deltas accumulate on ``self._deltas`` (drained by ``generate()``
        after every step) rather than being cleared here, so events
        recorded *between* iterations — a ``preempt()`` or ``submit()``
        from the caller's generate-loop body — still reach the stream."""
        self._sweep_deadlines()
        self._land_promotions()
        self._admit()
        self._land_promotions()     # zero-latency promotions land in-step
        active = self._runnable()
        if not active and (self.queue or self._promotions):
            # nothing runs and every waiter is deferred (backing off) or
            # gated on an in-flight promotion: park on the clock until the
            # earliest retry/landing comes due, then re-try — otherwise
            # run() would spin on an idle engine (and on a VirtualClock
            # nobody else ever moves time forward)
            waits = [pr["due"] for pr in self._promotions]
            if self.queue:
                nb = min(r.not_before for r in self.queue)
                if nb > self.clock.now():
                    waits.append(nb)
            if waits:
                self.clock.hold_until(min(waits))
                self._land_promotions()
                self._admit()
                active = self._runnable()
        if not active:
            return bool(self.queue) or bool(self._promotions)
        self.iterations += 1
        t0 = self.clock.now()

        if self._spec_wanted(active):
            drafts, n_spec = self._propose(active)
            if drafts is not None:
                self._spec_iteration(active, drafts, n_spec)
                self._post_iteration(self.clock.now() - t0)
                return True

        B, C = self.max_lanes, self.chunk
        n_new = np.zeros((B,), np.int32)
        feed = np.zeros((B, C), np.int32)
        use_last = np.zeros((B,), np.int32)
        prefill = [(r.lane, len(r.prompt) - r.fed) for r in active
                   if r.fed < len(r.prompt)]
        decode_only = not prefill
        alloc: dict = {}
        if prefill:
            # the interleave policy decides how many prompt tokens each
            # prefill-phase lane feeds; decode lanes always advance one
            alloc = dict(self.policy.plan(
                tuple(prefill), len(active) - len(prefill), C))
            if len(prefill) == len(active) and \
                    not any(alloc.get(ln, rem) for ln, rem in prefill):
                # a budget policy may starve every prefill lane in a mixed
                # batch, but an all-prefill iteration that feeds nothing
                # would never progress: force the oldest lane one chunk
                alloc[prefill[0][0]] = min(C, prefill[0][1])
        for r in active:
            i = r.lane
            if r.fed < len(r.prompt):
                n = min(C, len(r.prompt) - r.fed)
                n = max(0, min(n, int(alloc.get(i, n))))
                if n:
                    feed[i, :n] = r.prompt[r.fed:r.fed + n]
                    n_new[i] = n
                    self.prefill_tokens += n
            else:
                n_new[i] = 1
                use_last[i] = 1     # token is device-resident; no upload

        self._account_appends(active, n_new)

        smp = any(not r.sampling.greedy for r in active)
        if decode_only:
            # sync-free: every input already lives on device
            self.last_tok, self.kv_pages, self.kv_scales, self.len_dev = \
                self._decode_step[smp](
                    self.params, self.kv_pages, self.kv_scales, self.bt_dev,
                    self.len_dev, self.active_dev, self.last_tok,
                    self.seed_dev, self.temp_dev, self.topk_dev,
                    self.topp_dev)
        else:
            self._h2d(1)            # the prompt-chunk feed bundle
            self.last_tok, self.kv_pages, self.kv_scales, self.len_dev = \
                self._chunk_step[smp](
                    self.params, self.kv_pages, self.kv_scales, self.bt_dev,
                    self.len_dev, jnp.asarray(n_new), jnp.asarray(feed),
                    self.last_tok, jnp.asarray(use_last), self.seed_dev,
                    self.temp_dev, self.topk_dev, self.topp_dev)

        tok = np.asarray(self.last_tok)     # one pull per iteration
        self._d2h(1)

        for r in list(active):
            i = r.lane
            reason = None
            kept: List[int] = []
            if r.fed < len(r.prompt):
                r.fed += int(n_new[i])
                if r.fed == len(r.prompt):
                    kept, reason = self._emit(r, [int(tok[i])])
            else:
                kept, reason = self._emit(r, [int(tok[i])])
            if kept or reason:
                self._delta(r.rid, kept, reason=reason)
            if reason:
                self._finish(r, reason)
        self._post_iteration(self.clock.now() - t0)
        return True

    def _post_iteration(self, dt: float):
        """Scheduler watchdog, run after every engine iteration.

        * **EMA straggler flag** (ported from the trainer's elastic-mesh
          watchdog): an iteration slower than ``straggler_factor`` times
          the exponential moving average of recent iterations is flagged
          with a ``DEGRADE(iteration, 3)`` event — diagnostics, not
          termination, since a slow step is usually the store stalling.
        * **Stalled-lane abort**: a lane whose ``(fed, len(out))``
          progress marker has not advanced for ``watchdog_iters``
          iterations is aborted with ``finish_reason="error"`` plus a
          ``DEGRADE(rid, 2)`` event carrying diagnostics — a wedged lane
          must not pin pool pages forever."""
        if self.straggler_factor:
            ema = self._ema_step_s
            # warmup guard: the first iterations pay jit tracing costs
            if ema is not None and self.iterations > 3 and \
                    dt > self.straggler_factor * ema:
                self.straggler_steps += 1
                self.degrades += 1
                self.tracer.record_host(EventType.DEGRADE,
                                        self.iterations, 3)
            alpha = 0.2            # the trainer watchdog's ema_alpha
            self._ema_step_s = dt if ema is None else \
                alpha * dt + (1 - alpha) * ema
        if self.watchdog_iters:
            for r in [r for r in self.lanes if r is not None]:
                if r.promoting:
                    # gated on an in-flight promotion: not stalled, the
                    # landing path resets the marker clock
                    r.progress_iter = self.iterations
                    continue
                marker = (r.fed, len(r.out))
                if marker != r.progress_marker:
                    r.progress_marker = marker
                    r.progress_iter = self.iterations
                elif self.iterations - r.progress_iter >= \
                        self.watchdog_iters:
                    self.degrades += 1
                    self.tracer.record_host(EventType.DEGRADE, r.rid, 2)
                    self._fail(
                        r, f"watchdog: lane {r.lane} made no progress "
                           f"for {self.watchdog_iters} iterations "
                           f"(stuck at fed={r.fed}, out={len(r.out)})")

    # -------------------------------------------------------- speculation --
    def _spec_wanted(self, active: List[SeqState]) -> bool:
        """Draft this iteration?  Only when speculation is configured,
        every active lane is in the decode phase (mixed prefill iterations
        keep the plain chunk path), at least one lane decodes greedily
        (sampled lanes never draft — greedy verification could not accept
        their drafts — but they ride along in the verify step, whose
        bonus-token sampler matches the plain decode step exactly), and
        nothing is waiting for admission — a non-empty queue is preemption
        pressure: lanes should not widen their verify window while other
        work is starved."""
        return (self.spec_k > 0 and not self.queue
                and all(r.fed >= len(r.prompt) for r in active)
                and any(r.sampling.greedy for r in active))

    def _propose(self, active: List[SeqState]):
        """Collect per-lane draft proposals into a fixed-width (B, spec_k)
        matrix (fixed so the verify step compiles once).  Sampled lanes
        never propose; a greedy lane's draft depth is its adaptive
        ``spec_k_cur`` capped by the tokens it still owes
        (``accepted + 1 <= remaining`` must hold, so at most
        ``remaining - 1`` drafts).  Returns (None, None) when no lane
        proposed anything — the plain decode step is strictly cheaper."""
        drafts = np.zeros((self.max_lanes, self.spec_k), np.int32)
        n_spec = np.zeros((self.max_lanes,), np.int32)
        any_draft = False
        for r in active:
            if not r.sampling.greedy:
                continue
            rem = r.max_new - len(r.out)
            cap = min(r.spec_k_cur, rem - 1, self.spec_k)
            if cap <= 0:
                continue
            try:
                d = self.drafter.propose(r.prompt + r.out, cap)[:cap]
            except Exception:
                # a broken drafter is an accelerant, not a dependency:
                # disable speculation for this lane (it decodes plainly
                # from here on) and log the degradation instead of letting
                # the exception crash the engine step
                r.spec_k_cur = 0
                self.degrades += 1
                self.tracer.record_host(EventType.DEGRADE, r.rid, 1)
                continue
            if not d:
                continue
            drafts[r.lane, :len(d)] = d
            n_spec[r.lane] = len(d)
            any_draft = True
        return (drafts, n_spec) if any_draft else (None, None)

    def _spec_iteration(self, active: List[SeqState], drafts: np.ndarray,
                        n_spec: np.ndarray):
        """One draft-verify-rollback engine iteration.

        The pool appends all K+1 candidate positions per lane (pages
        allocated, CoW applied — the ordinary append path), the verify
        step scores every position and counts the accepted prefix on
        device, and the host trims each lane back to ``accepted + 1``
        kept tokens: pages wholly beyond the kept length are unmapped and
        re-credited to the reservation.  Device lengths and the last
        sampled token are updated inside the jitted step from the
        acceptance itself, so the only pull is the one verdict array.
        Sampled lanes participate with zero drafts: they advance exactly
        one position-folded sampled token, unchanged from plain decode."""
        self.spec_iterations += 1
        lens0 = {r.rid: self._pool(r).seq_len[r.rid] for r in active}
        n_new = np.zeros((self.max_lanes,), np.int32)
        for r in active:
            k_i = int(n_spec[r.lane])
            n_new[r.lane] = k_i + 1
            if k_i:
                self.tracer.record_host(EventType.SPEC_PROPOSE, r.rid, k_i)
                self.spec_proposed += k_i
                r.spec_proposed += k_i
        self._account_appends(active, n_new)

        self._h2d(1)                # the draft feed bundle
        smp = any(not r.sampling.greedy for r in active)
        verdict, self.kv_pages, self.kv_scales, self.last_tok, \
            self.len_dev = self._spec_step[smp](
                self.params, self.kv_pages, self.kv_scales, self.bt_dev,
                self.len_dev, self.active_dev, self.last_tok,
                jnp.asarray(drafts), jnp.asarray(n_spec), self.seed_dev,
                self.temp_dev, self.topk_dev, self.topp_dev)
        v = np.asarray(verdict)     # one pull per iteration
        self._d2h(1)

        K = drafts.shape[1]
        for r in list(active):
            i = r.lane
            k_i = int(n_spec[i])
            a = int(v[i, K + 1])
            emitted = [int(t) for t in drafts[i, :a]] + [int(v[i, a])]
            freed = self._pool(r).trim(r.rid, lens0[r.rid] + a + 1)
            kept, reason = self._emit(r, emitted)
            self._delta(r.rid, kept, event="spec" if k_i else "token",
                        data=a, reason=reason)
            if k_i:
                self.tracer.record_host(EventType.SPEC_ACCEPT, r.rid, a)
                self.spec_accepted += a
                r.spec_accepted += a
                rej = k_i - a
                if rej:
                    self.spec_rejected += rej
                    r.spec_rejected += rej
                    self.tracer.record_host(EventType.SPEC_ROLLBACK,
                                            r.rid, rej)
                # adaptive depth: full acceptance earns a wider window,
                # total rejection halves it (never below 1)
                if a == k_i:
                    r.spec_k_cur = min(self.spec_k, r.spec_k_cur + 1)
                elif a == 0:
                    r.spec_k_cur = max(1, r.spec_k_cur // 2)
            if freed:
                self._refresh_row(i, r)
            if reason:
                self._finish(r, reason)

    # ---------------------------------------------------------- frontend --
    def poll_deltas(self) -> List[TokenDelta]:
        """Drain every delta accumulated since the last drain.  For
        callers that drive ``step()`` directly (the serving front door)
        instead of consuming the ``generate()`` stream; the two drains
        share one buffer, so use one or the other per engine."""
        out, self._deltas = self._deltas, []
        return out

    def generate(self, requests: Iterable[GenerationRequest] = (),
                 max_iters: Optional[int] = None) -> Iterator[TokenDelta]:
        """Submit ``requests`` and stream the engine: yields a
        :class:`TokenDelta` for every request-visible increment (new
        tokens, prefix-cache hits, preemptions, speculation verdicts) as
        each engine iteration completes, instead of making callers poll
        ``finished``.  The concatenation of a request's token deltas is
        exactly its final ``GenerationResult.tokens``.  When ``max_iters``
        is hit, still-queued/running requests are aborted (surfaced with
        ``finish_reason="aborted"``), never silently dropped.

        Exception-safe: ``break``-ing out of (or ``.close()``-ing) the
        stream leaves the pool consistent and the engine resumable —
        running lanes keep their pages, a later ``generate()``/``run()``
        picks up exactly where the stream stopped, and already-delivered
        deltas are never re-yielded.  ``engine.cancel(rid)`` and
        ``engine.submit(...)`` both work from the loop body."""
        for q in requests:
            self.submit(q)
        it = 0
        while True:
            busy = self.step()
            # drain one delta at a time from the live list: deltas the
            # caller's loop body triggers mid-yield (submit / preempt /
            # cancel) are picked up by the same drain, and a consumer
            # that ``break``s or ``.close()``es the generator mid-stream
            # leaves undelivered deltas queued (never re-yielded) with
            # the engine fully resumable — pool invariants hold between
            # iterations, so generate()/run() can simply be called again
            while self._deltas:
                yield self._deltas.pop(0)
            if not busy:
                return
            it += 1
            if max_iters is not None and it >= max_iters:
                self._abort_all()
                while self._deltas:
                    yield self._deltas.pop(0)
                return

    def run(self, max_iters: int = 10_000) -> List[GenerationResult]:
        """Drain the engine (``generate`` with nobody watching the stream)
        and return every result this engine has produced."""
        for _ in self.generate(max_iters=max_iters):
            pass
        return self.finished


# ===========================================================================
# jitted engine steps
# ===========================================================================

def _write_coords(bt, lens, n_new, C, page_size, trash):
    """Physical (page, slot) for the C candidate token writes of each lane.

    Invalid slots (beyond a lane's n_new) are routed to the trash page so a
    single unmasked scatter covers every lane."""
    n_pages = bt.shape[1]
    pos = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B,C)
    lp = jnp.minimum(pos // page_size, n_pages - 1)
    sl = pos % page_size
    phys = jnp.take_along_axis(bt, lp, axis=1)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_new[:, None]
    return jnp.where(valid, phys, trash), sl


def _layer_qkv(cfg, lp, x, pos):
    h = L.norm_forward(cfg, lp["ln1"], x)
    ap = lp["attn"]
    q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, ap["wv"])
    if cfg.use_qk_norm:
        q = rms_head_norm(ap["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(ap["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def _layer_mlp(cfg, lp, x):
    h = L.norm_forward(cfg, lp["ln2"], x)
    if "moe" in lp:
        from repro.models import moe as MOE
        return x + MOE.moe_forward(cfg, lp["moe"], h)
    return x + L.mlp_forward(cfg, lp["mlp"], h)


def _sample_tokens(logits, seeds, pos, temps, top_ks, top_ps):
    """Per-lane token selection from (B, V) logits.

    Temperature-0 lanes take exact greedy argmax (the historical engine
    path, byte-identical); sampled lanes divide by temperature, apply
    top-k then top-p truncation and draw categorically with
    ``fold_in(PRNGKey(seed), pos)`` — ``pos`` is the token's absolute
    sequence position, so a lane's draw is reproducible from (seed,
    position) alone no matter how the scheduler chunked, preempted or
    sharded the request."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(lg, seed, p, t, tk, tp):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), p)
        V = lg.shape[-1]
        lg = lg / jnp.maximum(t, 1e-6)
        desc = jnp.sort(lg)[::-1]
        kth = desc[jnp.clip(tk - 1, 0, V - 1)]
        lg = jnp.where((tk > 0) & (lg < kth), -jnp.inf, lg)
        probs = jax.nn.softmax(lg)
        sp = jnp.sort(probs)[::-1]
        # nucleus: keep the smallest prefix of descending probs whose mass
        # reaches tp (the mass of strictly-larger probs must be < tp)
        keep = (jnp.cumsum(sp) - sp) < tp
        thresh = jnp.min(jnp.where(keep, sp, jnp.inf))
        lg = jnp.where(probs >= thresh, lg, -jnp.inf)
        return jax.random.categorical(key, lg).astype(jnp.int32)

    sampled = jax.vmap(one)(logits, seeds, pos, temps, top_ks, top_ps)
    return jnp.where(temps > 0.0, sampled, greedy)


def _paged_forward(cfg: ArchConfig, use_kernel: bool,
                   pages_per_step: int, interpret: bool,
                   num_pages: int, params, kv_pages, kv_scales, bt, lens,
                   n_new, feed, last_tok, use_last, *, axis_name=None,
                   quant=False):
    """Shared forward for the chunk / decode / spec-verify steps: consume up
    to C tokens per lane (prompt chunks from ``feed``; lanes with
    ``use_last`` take the device-resident previous sample at position 0)
    and return the logits at EVERY fed position.

    kv_pages: (L, P+1, 2, page, kv, hd); kv_scales: (L, P+1, 2, kv) f32;
    bt: (B, n_pages) repeat-padded.  Returns (logits (B, C, V), kv_pages,
    kv_scales).

    ``quant`` (compile-time) marks the pool int8: the fused scatter
    quantizes each lane's new K/V under its page's running-max
    per-(page, K/V, head) scale — a grown scale re-packs the page's
    existing bytes under the new scale (untouched pages see factor 1.0
    exactly, so they round-trip losslessly) — and the attention fetch
    (kernel or oracle) dequantizes in-line.  In bf16 mode ``kv_scales``
    flows through untouched (jit DCEs it off the hot path).

    ``axis_name`` names the tensor-parallel head mesh axis when this runs
    as a ``shard_map`` body (sharded engine): q/k/v/o weights and the pool's
    kv-head dim arrive pre-sliced, so the only collective is one psum of the
    attention output per layer — everything else is replicated compute."""
    B, C = feed.shape
    page = kv_pages.shape[3]
    n_pages = bt.shape[1]
    tokens = feed.at[:, 0].set(jnp.where(use_last == 1, last_tok, feed[:, 0]))
    x = L.embed_tokens(cfg, params["embed"], tokens)        # (B,C,d)
    pos = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    new_lens = lens + n_new
    counts = page_counts_for(new_lens, page)
    phys, sl = _write_coords(bt, lens, n_new, C, page, num_pages)
    if not use_kernel:      # the -1-marked table form the oracle expects
        idx = jnp.arange(n_pages, dtype=jnp.int32)[None, :]
        bt_masked = jnp.where(idx < counts[:, None], bt, -1)

    for i in range(cfg.num_layers):
        lp = M._sub(params["layers"], i)
        q, k, v = _layer_qkv(cfg, lp, x, pos)
        kv_new = jnp.stack([k, v], axis=2)          # (B,C,2,Kv,hd)
        if quant:
            # running-max page scales: scatter-max the new tokens' absmax
            # into the touched pages (duplicate-index safe), re-pack pages
            # whose scale grew, then quantize the new tokens in place
            sc_i = kv_scales[i]                     # (P+1,2,Kv)
            tok_scale = headwise_scales(kv_new)     # (B,C,2,Kv)
            new_sc = sc_i.at[phys].max(tok_scale)
            factor = jnp.where(
                new_sc > 0.0, sc_i / jnp.maximum(new_sc, SCALE_EPS), 0.0)
            repacked = jnp.clip(
                jnp.round(kv_pages[i].astype(jnp.float32)
                          * factor[:, :, None, :, None]),
                -127, 127).astype(jnp.int8)
            q_new = quantize_int8(kv_new, new_sc[phys][..., None])
            kv_pages = kv_pages.at[i].set(
                repacked.at[phys, :, sl].set(q_new))
            kv_scales = kv_scales.at[i].set(new_sc)
        else:
            # one fused scatter writes K AND V for all lanes' chunk tokens
            kv_pages = kv_pages.at[i, phys, :, sl].set(kv_new)
        if use_kernel:
            a = paged_prefill_fused(q, kv_pages[i], bt, counts, new_lens,
                                    lens, interpret=interpret,
                                    pages_per_step=pages_per_step,
                                    kv_scales=kv_scales[i] if quant
                                    else None)
        elif quant:
            a = paged_prefill_ref(q, kv_pages[i, :, 0], kv_pages[i, :, 1],
                                  bt_masked, new_lens, lens,
                                  k_scales=kv_scales[i, :, 0],
                                  v_scales=kv_scales[i, :, 1])
        else:
            a = paged_prefill_ref(q, kv_pages[i, :, 0], kv_pages[i, :, 1],
                                  bt_masked, new_lens, lens)
        attn_out = jnp.einsum("bshk,hkd->bsd", a, lp["attn"]["wo"])
        if axis_name is not None:
            # each head shard holds a partial sum over its heads
            attn_out = jax.lax.psum(attn_out, axis_name)
        x = x + attn_out
        x = _layer_mlp(cfg, lp, x)

    x = L.norm_forward(cfg, params["final_norm"], x)
    logits = L.logits_from_hidden(cfg, params["embed"], x)  # (B,C,V)
    return logits, kv_pages, kv_scales


def _paged_chunk_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                      interpret: bool, num_pages: int, params, kv_pages,
                      kv_scales, bt, lens, n_new, feed, last_tok, use_last,
                      seeds, temps, top_ks, top_ps, *, axis_name=None,
                      quant=False, sample=True):
    """Consume up to C tokens per lane: prompt chunks from ``feed``, decode
    lanes (``use_last``) from the device-resident previous sample; the next
    token is selected at the last fed position by the per-lane sampling
    policy (greedy argmax for temperature-0 lanes).  ``sample`` is a
    compile-time flag: the host dispatches the False variant when every
    active lane is greedy, so the historical hot path never traces the
    sampler at all.

    Returns (sampled_tokens (B,), kv_pages, kv_scales, new_lens)."""
    logits, kv_pages, kv_scales = _paged_forward(
        cfg, use_kernel, pages_per_step, interpret, num_pages, params,
        kv_pages, kv_scales, bt, lens, n_new, feed, last_tok, use_last,
        axis_name=axis_name, quant=quant)
    row = jnp.maximum(n_new - 1, 0)
    last_logits = jnp.take_along_axis(
        logits, row[:, None, None], axis=1)[:, 0]           # (B,V)
    if sample:
        # the sampled token's absolute position is new_lens: fold there so
        # the draw is chunking/scheduling-independent
        nxt = _sample_tokens(last_logits, seeds, lens + n_new, temps,
                             top_ks, top_ps)
    else:
        nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    nxt = jnp.where(n_new > 0, nxt, last_tok)   # idle lanes keep their token
    return nxt, kv_pages, kv_scales, lens + n_new


def _paged_spec_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                     interpret: bool, num_pages: int, params, kv_pages,
                     kv_scales, bt, lens, active, last_tok, drafts, n_spec,
                     seeds, temps, top_ks, top_ps, *, axis_name=None,
                     quant=False, sample=True):
    """Speculative verify step: score all K+1 candidate positions of every
    lane in ONE chunked forward and count the accepted draft prefix.

    The feed is ``[x0, d_1 .. d_K]`` where x0 is the device-resident
    previous sample and d_j are host drafts; lane b uses ``n_spec[b]`` of
    them (the rest are dead weight routed to the trash page by the write
    coords).  Greedy verification: draft d_{j+1} is accepted iff every
    earlier draft was and d_{j+1} equals the greedy token after position j
    — so the accepted prefix plus the bonus token is exactly the plain
    greedy continuation (parity by construction).  The bonus token at
    position ``accepted`` goes through the same position-folded sampler
    the chunk step uses: for the greedy lanes that drafted it IS the
    greedy token, and for sampled lanes riding along with zero drafts it
    is the identical draw plain decode would have made.  Lengths advance
    by ``accepted + 1`` on device; the host applies the same trim to the
    pool.

    Returns (verdict (B, K+2), kv_pages, kv_scales, last_tok, new_lens)
    where ``verdict[:, :K+1]`` holds the per-position verify tokens (with
    the bonus token at column ``accepted``) and ``verdict[:, K+1]`` the
    accepted count."""
    B, K = drafts.shape
    feed = jnp.concatenate([jnp.zeros((B, 1), jnp.int32), drafts], axis=1)
    n_new = jnp.where(active == 1, n_spec + 1, 0)
    logits, kv_pages, kv_scales = _paged_forward(
        cfg, use_kernel, pages_per_step, interpret, num_pages, params,
        kv_pages, kv_scales, bt, lens, n_new, feed, last_tok, active,
        axis_name=axis_name, quant=quant)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    idx = jnp.arange(K, dtype=jnp.int32)[None, :]
    ok = (drafts == greedy[:, :K]) & (idx < n_spec[:, None])
    accepted = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
    new_lens = lens + jnp.where(active == 1, accepted + 1, 0)
    bonus_logits = jnp.take_along_axis(
        logits, accepted[:, None, None], axis=1)[:, 0]      # (B,V)
    if sample:
        bonus = _sample_tokens(bonus_logits, seeds, lens + accepted + 1,
                               temps, top_ks, top_ps)
    else:       # all-greedy batch: the bonus token IS the greedy token
        bonus = jnp.argmax(bonus_logits, axis=-1).astype(jnp.int32)
    last = jnp.where(active == 1, bonus, last_tok)
    toks = greedy.at[jnp.arange(B), accepted].set(last)
    verdict = jnp.concatenate([toks, accepted[:, None]], axis=1)
    return verdict, kv_pages, kv_scales, last, new_lens


def _paged_decode_step(cfg: ArchConfig, use_kernel: bool, pages_per_step: int,
                       interpret: bool, num_pages: int, params, kv_pages,
                       kv_scales, bt, lens, active, last_tok, seeds, temps,
                       top_ks, top_ps, *, axis_name=None, quant=False,
                       sample=True):
    """One decode token for every active lane, entirely from device state —
    the C=1 case of the chunk step (mirroring paged_decode_fwd, which is the
    C=1 case of the prefill kernel), with every lane fed its device-resident
    previous sample.

    Returns (sampled_tokens (B,), kv_pages, kv_scales, new_lens)."""
    B = lens.shape[0]
    return _paged_chunk_step(
        cfg, use_kernel, pages_per_step, interpret, num_pages, params,
        kv_pages, kv_scales, bt, lens, active, jnp.zeros((B, 1), jnp.int32),
        last_tok, jnp.ones((B,), jnp.int32), seeds, temps, top_ks, top_ps,
        axis_name=axis_name, quant=quant, sample=sample)
