"""Multi-cluster sharded paged serving engine (HERO §2.1 scaled out).

HERO's headline property is that the PMCA *scales*: throughput grows by
instantiating more RISC-V clusters behind one SVM/RAB fabric.  This module
is the serving-side reproduction of that scaling lever: the paged engine of
``runtime.server`` is sharded across a JAX device mesh of C "clusters"
(data-parallel lane groups) x H tensor-parallel head shards — the
``ClusterMesh`` with named axes ``("cluster", "head")``, which works on CPU
via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Mapping back to the paper:

* **per-cluster RAB + page shard** (§2.2) — every cluster owns a
  ``PagedKVPool`` slice of the fused KV slab with its own free list,
  refcounts, prefix index and ``RAB`` instance (``ClusterPagedPool``); a
  sequence lives entirely inside one cluster, so its block table holds
  cluster-local physical ids and the cluster id rides with the request;
* **cluster-aware admission** — placement is cache-affine least-loaded
  (largest prefix hit, then most obtainable pages); preemption stays
  cluster-local: a victim's pages swap out of *its* cluster's shard only;
* **one program, C clusters** (§3.2's shard_map discipline) — the jitted
  chunk/decode steps of ``runtime.server`` run unchanged as ``shard_map``
  bodies; lanes and their device-resident state (block tables, lengths,
  sampled tokens, per-lane sampling policy) shard over ``cluster``,
  attention heads GQA-aware over ``head`` (the only collective is one psum
  of the attention output per layer); with C = H = 1 the engine is
  token-for-token identical to the unsharded ``PagedServer`` — including
  sampled lanes, whose PRNG keys fold by (seed, position) and therefore
  never see the mesh;
* **tracing** (§2.3.1) — placement and the per-iteration cross-cluster
  token gather emit ``CLUSTER_DISPATCH`` / ``ALL_GATHER`` events, analyzed
  by ``core.analysis.layer2_cluster_balance``.

Configuration flows through the same :class:`~repro.runtime.EngineConfig`
as the unsharded engine (``clusters`` / ``heads`` / ``mesh`` select the
mesh; ``make_engine`` picks this class whenever the spec wants one).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import List, Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.offload import DiskTier, HostBackingStore
from repro.core.rab import ClusterPagedPool, PagedKVPool, RABConfig
from repro.core.tracing import EventType, TraceBuffer
from repro.kernels.paged_attention.ops import validate_head_sharding
from repro.launch.mesh import make_serving_mesh
from repro.parallel.sharding import cluster_engine_specs
from repro.runtime.api import EngineConfig
from repro.runtime.server import (
    PagedServer, SeqState, _paged_chunk_step, _paged_decode_step,
    _paged_spec_step,
)

__all__ = ["ShardedPagedServer"]


class ShardedPagedServer(PagedServer):
    """``PagedServer`` sharded over a ``("cluster", "head")`` device mesh.

    ``EngineConfig.num_pages`` and ``EngineConfig.max_lanes`` are *per
    cluster* (so a 1-cluster sharded engine is configured exactly like the
    unsharded one); the fused device slab holds ``C * (num_pages + 1)``
    pages — each cluster's contiguous block ends with its own trash page —
    sharded over the ``cluster`` axis, kv heads over ``head``.
    """

    def __init__(self, cfg: ArchConfig, params,
                 engine: Optional[EngineConfig] = None, *,
                 tracer: Optional[TraceBuffer] = None):
        if engine is None:
            engine = EngineConfig()
        cmesh = engine.mesh if engine.mesh is not None else \
            make_serving_mesh(engine.clusters, engine.heads)
        self.cmesh = cmesh
        self.clusters = cmesh.clusters
        self.heads = cmesh.heads
        self.lanes_per_cluster = engine.max_lanes
        self._local_pages = engine.cache.num_pages
        validate_head_sharding(cfg.num_heads, cfg.num_kv_heads, cmesh.heads)
        super().__init__(
            cfg, params,
            dataclasses.replace(engine,
                                max_lanes=engine.max_lanes * cmesh.clusters),
            tracer=tracer)
        self.engine_cfg = engine        # the per-cluster spec, as given
        self.peak_pages = [0] * cmesh.clusters  # per-cluster occupancy peak
        self._fin_mark = 0
        self._parked_len: dict = {}     # rid -> seq_len across preemption

    # ------------------------------------------------------ construction --
    def _build_pool(self, num_pages: int, rab_cfg: RABConfig):
        # per-cluster pools/RABs instead of the base's single pool;
        # self.pool points at an aggregate view (stats/free_pages) for
        # external readers, never at an allocator
        self.cpool = ClusterPagedPool(self.clusters, num_pages,
                                      self.page_size, self.max_pages,
                                      rab_cfg, self.tracer)
        self.pool = self.cpool
        self.rabs = self.cpool.rabs
        self.rab = self.rabs[0]

    def _build_device_state(self, num_pages: int, pages_per_step: int):
        # the fused slab, re-laid-out: C contiguous (num_pages + 1) blocks
        # (trash page per cluster), pages sharded over `cluster`, kv heads
        # over `head`; lane state (incl. the sampling-policy rows) shards
        # its batch dim over `cluster`
        cfg, C = self.cfg, self.clusters
        L_, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.int8 if self.quant_kv else jnp.dtype(cfg.param_dtype)
        specs = cluster_engine_specs(self.params)
        mesh_ = self.cmesh.mesh
        ns = functools.partial(NamedSharding, mesh_)
        self.kv_pages = jax.device_put(
            jnp.zeros((L_, C * (num_pages + 1), 2, self.page_size, kv, hd),
                      dt), ns(specs["kv"]))
        # per-page dequant scales for the int8 KV mode; allocated in both
        # modes so the step signatures stay uniform (bf16 jit DCEs it)
        self.kv_scales = jax.device_put(
            jnp.zeros((L_, C * (num_pages + 1), 2, kv), jnp.float32),
            ns(specs["kv_scales"]))
        B = self.max_lanes
        self.bt_dev = jax.device_put(
            jnp.zeros((B, self.max_pages), jnp.int32), ns(specs["lane2"]))
        self.len_dev = jax.device_put(jnp.zeros((B,), jnp.int32),
                                      ns(specs["lane"]))
        self.active_dev = jax.device_put(jnp.zeros((B,), jnp.int32),
                                         ns(specs["lane"]))
        self.last_tok = jax.device_put(jnp.zeros((B,), jnp.int32),
                                       ns(specs["lane"]))
        self.seed_dev = jax.device_put(jnp.zeros((B,), jnp.uint32),
                                       ns(specs["lane"]))
        self.temp_dev = jax.device_put(jnp.zeros((B,), jnp.float32),
                                       ns(specs["lane"]))
        self.topk_dev = jax.device_put(jnp.zeros((B,), jnp.int32),
                                       ns(specs["lane"]))
        self.topp_dev = jax.device_put(jnp.ones((B,), jnp.float32),
                                       ns(specs["lane"]))
        self.params = jax.tree.map(
            lambda x, s: jax.device_put(x, ns(s)), self.params,
            specs["params"])

        # the unsharded engine steps, shard_mapped: each (cluster, head)
        # shard runs the single-cluster program on its lane group, local
        # page block and local heads — HERO's "the per-cluster body is
        # literally the single-cluster program" discipline
        itp = jax.default_backend() != "tpu"
        out_specs = (specs["lane"], specs["kv"], specs["kv_scales"],
                     specs["lane"])
        sampling_specs = (specs["lane"],) * 4   # seeds, temps, topk, topp

        # the same two-variant dispatch as the unsharded engine (all-greedy
        # batches never trace the sampler), each variant the shard_map'd
        # single-cluster program; jit is lazy, so only used variants compile
        def mk(step_fn, in_specs, outs):
            def one(s):
                body = functools.partial(
                    step_fn, cfg, self.use_kernel, pages_per_step, itp,
                    num_pages, axis_name="head", quant=self.quant_kv,
                    sample=s)
                return jax.jit(shard_map(body, mesh=mesh_,
                                         in_specs=in_specs, out_specs=outs,
                                         check_rep=False))
            return {s: one(s) for s in (False, True)}

        self._chunk_step = mk(
            _paged_chunk_step,
            (specs["params"], specs["kv"], specs["kv_scales"],
             specs["lane2"], specs["lane"], specs["lane"], specs["lane2"],
             specs["lane"], specs["lane"]) + sampling_specs, out_specs)
        self._decode_step = mk(
            _paged_decode_step,
            (specs["params"], specs["kv"], specs["kv_scales"],
             specs["lane2"], specs["lane"], specs["lane"],
             specs["lane"]) + sampling_specs, out_specs)
        if self.spec_k:
            # the speculative verify step is the same shard_map discipline:
            # drafts/verdicts shard their lane dim over `cluster`, the
            # acceptance count is computed shard-locally per lane group
            self._spec_step = mk(
                _paged_spec_step,
                (specs["params"], specs["kv"], specs["kv_scales"],
                 specs["lane2"], specs["lane"], specs["lane"], specs["lane"],
                 specs["lane2"], specs["lane"]) + sampling_specs,
                (specs["lane2"], specs["kv"], specs["kv_scales"],
                 specs["lane"], specs["lane"]))

    def _build_backing_store(self) -> HostBackingStore:
        # cache spill tiers are per cluster (like the pools and prefix
        # indexes they back); swap traffic stays on ONE engine-wide store
        # because a preempted victim may resume on any cluster
        cc = self.cache_cfg
        self.tier_stores: List[HostBackingStore] = []
        for c in range(self.clusters):
            sub = None if cc.disk_dir is None else \
                os.path.join(cc.disk_dir, f"cluster{c}")
            disk = DiskTier(cc.disk_tier_pages, sub) \
                if cc.disk_tier_pages else None
            self.tier_stores.append(HostBackingStore(
                self.faults, host_pages=cc.host_tier_pages, disk_tier=disk))
        return HostBackingStore(self.faults)

    # ---------------------------------------------------------- pool seam --
    def _pool_of(self, cluster: int) -> PagedKVPool:
        return self.cpool.pools[cluster]

    def _all_pools(self) -> List[PagedKVPool]:
        return list(self.cpool.pools)

    def _capacity_pages(self) -> int:
        return self._local_pages

    def _gpage(self, req: SeqState, p: int) -> int:
        return self.cpool.global_page(req.cluster, p)

    def _gpage_c(self, cluster: int, p: int) -> int:
        return self.cpool.global_page(cluster, p)

    def _cache_store_of(self, cluster: int) -> HostBackingStore:
        return self.tier_stores[cluster]

    def _cache_stores(self) -> List[HostBackingStore]:
        return list(self.tier_stores)

    # --------------------------------------------------------- scheduler --
    def _free_lane(self, cluster: int) -> Optional[int]:
        lo = cluster * self.lanes_per_cluster
        for i in range(lo, lo + self.lanes_per_cluster):
            if self.lanes[i] is None:
                return i
        return None

    def _admit(self):
        """Cluster-aware admission: plan the queue head against every
        cluster with a free lane and place it cache-affine least-loaded —
        largest usable prefix hit first, then most obtainable pages, then
        lowest cluster id.  When no cluster fits, preemption reclaims the
        lowest-priority running lane (the sweep is cluster-local: only the
        victim's cluster shard is touched) and planning retries."""
        while self.queue:
            head = self._eligible_head()
            if head is None:
                break                 # every waiter is backing off
            best = None
            for c in range(self.clusters):
                lane = self._free_lane(c)
                if lane is None:
                    continue
                plan = self._plan(head, cluster=c)
                if not self._fits(plan):
                    continue
                score = (plan["usable"], self._pool_of(c).available(), -c)
                if best is None or score > best[0]:
                    best = (score, lane, plan)
            if best is None:
                victim = self._victim(head)
                if victim is None:
                    break
                self._preempt(victim)
                continue
            self.queue.remove(head)
            self._place(head, best[1], best[2])

    def _place(self, req: SeqState, lane: int, plan: dict):
        self.cpool.place(req.rid, plan["cluster"])
        self.tracer.record_host(EventType.CLUSTER_DISPATCH, req.rid,
                                plan["cluster"])
        if plan["resume"] and req.rid in self._parked_len:
            # re-install the sequence length into the (possibly different)
            # destination cluster's pool before the swap-in restores pages
            self._pool_of(plan["cluster"]).seq_len[req.rid] = \
                self._parked_len.pop(req.rid)
        super()._place(req, lane, plan)

    def _unplace(self, req: SeqState):
        # a deferred swap-in retry: re-park the sequence length and drop
        # the routing entry (mirroring _preempt) so the later retry may
        # place the request on ANY cluster again
        pool = self._pool(req)
        super()._unplace(req)
        self._parked_len[req.rid] = pool.seq_len.pop(req.rid, 0)
        self.cpool.forget(req.rid)

    def _preempt(self, req: SeqState):
        pool = self._pool(req)
        super()._preempt(req)
        if req.done:
            # the checkpoint sweep hit a persistent backing-store fault
            # and demoted the victim: _terminate already cleaned up
            return
        # the victim may be re-placed on ANY cluster (its KV payload is
        # host-resident now): park its sequence length with the scheduler
        # and drop the old cluster's routing entry
        self._parked_len[req.rid] = pool.seq_len.pop(req.rid, 0)
        self.cpool.forget(req.rid)

    def _finish(self, req: SeqState, reason: str):
        super()._finish(req, reason)
        self.cpool.forget(req.rid)

    def _terminate(self, req: SeqState, reason: str, event: str,
                   diag: Optional[str] = None):
        # every exceptional exit (abort / cancel / timeout / error / shed)
        # flows through here: drop the parked length and routing entry too
        super()._terminate(req, reason, event, diag)
        self._parked_len.pop(req.rid, None)
        self.cpool.forget(req.rid)

    # --------------------------------------------------------------- step --
    def step(self) -> bool:
        before_it = self.iterations
        occ0 = self.cpool.occupancy()
        progressed = super().step()
        if self.iterations > before_it:
            busy = {r.cluster for r in self.lanes if r is not None}
            busy |= {r.cluster for r in self.finished[self._fin_mark:]}
            self._fin_mark = len(self.finished)
            # the one D2H token pull per iteration gathers every active
            # cluster's sampled tokens through the mesh
            self.tracer.record_host(EventType.ALL_GATHER, self.iterations,
                                    len(busy))
            for c, (a, b) in enumerate(zip(occ0, self.cpool.occupancy())):
                self.peak_pages[c] = max(self.peak_pages[c], a, b)
        return progressed

    # ------------------------------------------------------------- report --
    def cluster_report(self) -> dict:
        """Per-cluster occupancy/balance summary for benchmarks."""
        occ = self.cpool.occupancy()
        return {
            "clusters": self.clusters,
            "heads": self.heads,
            "peak_pages_per_cluster": list(self.peak_pages),
            "pages_per_cluster": self._local_pages,
            "peak_occupancy_per_cluster": [
                p / self._local_pages for p in self.peak_pages],
            "live_pages_per_cluster": occ,
        }
