"""Speculative-decoding drafters: the host-side proposers of the
draft-then-verify split (HERO §2.2's heterogeneity, serving-side).

HERO co-executes a lightweight general-purpose host with a heavy parallel
accelerator.  Speculative decoding is the serving analogue: a cheap
*drafter* runs on the host and proposes K continuation tokens per lane,
and the target model *verifies* all K+1 positions in one batched
chunked-prefill step on the accelerator — the expensive side never runs
more iterations, only wider ones.  A lane advances ``accepted + 1`` tokens
per engine iteration (the ``+ 1`` is the bonus token the verify step
samples itself), with greedy parity guaranteed: the accepted prefix plus
the bonus token is exactly the sequence plain greedy decode would emit.

Two drafters:

* :class:`NGramDrafter` — matches the longest recent n-gram suffix of the
  lane's token history (prompt + generated) against earlier occurrences
  and proposes the continuation that followed last time.  Zero model
  cost; strong on the repetitive tails greedy decode produces.
* :class:`DraftModelDrafter` — a smoke-size draft model (any
  ``configs/`` arch sharing the target's vocabulary) greedily extended k
  tokens on the host.  The general mechanism for a learned drafter; at
  demo scale it re-runs the full context per proposed token.

Both are stateless with respect to the engine: proposals are recomputed
from the request's token history each iteration, so preemption/resume and
rollback need no drafter bookkeeping.

Under the unified generation API, drafting is **greedy-lane-only**: the
verify step accepts a draft iff it equals the greedy argmax at its
position, so a ``SamplingParams(temperature > 0)`` lane's drafts could
never be parity-accepted — the engine simply never asks the drafter for
such lanes (they ride along in verify iterations with zero drafts,
advancing by their ordinary position-folded sampled token).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Protocol, Sequence

import jax
import jax.numpy as jnp


class Drafter(Protocol):
    """Proposes up to ``k`` continuation tokens for a token history."""

    def propose(self, ctx: Sequence[int], k: int) -> List[int]:
        """Return 0..k draft tokens continuing ``ctx`` (never padded)."""
        ...


class NGramDrafter:
    """Suffix-match drafter over the lane's own token history.

    For ``n`` from ``max_n`` down to ``min_n``, the last ``n`` tokens of
    the context are searched for earlier occurrences; the tokens that
    followed an occurrence are proposed (capped at ``k``).  Longest match
    wins.  Among occurrences of the winning n-gram, the most recent one
    with ``k`` tokens of continuation is preferred (recency tracks the
    short cycles greedy decode settles into); when none has ``k``, the
    one with the longest continuation is used — so a token *run* still
    proposes everything history can support.
    """

    def __init__(self, max_n: int = 3, min_n: int = 1):
        assert 1 <= min_n <= max_n
        self.max_n = max_n
        self.min_n = min_n

    def propose(self, ctx: Sequence[int], k: int) -> List[int]:
        L = len(ctx)
        if k <= 0 or L < self.min_n + 1:
            return []
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            tail = tuple(ctx[L - n:])
            best = None         # (continuation length, start) seen so far
            # scan from the most recent earlier occurrence backwards; the
            # match may not end at the final position (the tail itself)
            for i in range(L - n - 1, -1, -1):
                if tuple(ctx[i:i + n]) == tail:
                    avail = min(k, L - (i + n))
                    if avail >= k:
                        return list(ctx[i + n:i + n + k])
                    if avail > 0 and (best is None or avail > best[0]):
                        best = (avail, i)
            if best is not None:
                a, i = best
                return list(ctx[i + n:i + n + a])
        return []


class DraftModelDrafter:
    """Greedy k-token continuation from a (small) draft model.

    ``cfg``/``params`` come from the same ``configs/`` + ``models``
    machinery as the target (the draft arch must share the target's
    vocabulary — asserted against ``target_vocab`` when given).  Context
    length is right-padded to a bucket so jit compiles once per bucket,
    not once per length; causal attention makes the padding invisible to
    the logits at the last real position.
    """

    def __init__(self, cfg, params, *, target_vocab: Optional[int] = None,
                 bucket: int = 32):
        if target_vocab is not None and cfg.vocab_size != target_vocab:
            raise ValueError(
                f"draft model vocab {cfg.vocab_size} != target vocab "
                f"{target_vocab}: draft tokens would be meaningless")
        self.cfg = cfg
        self.params = params
        self.bucket = bucket
        self._next_tok = jax.jit(functools.partial(_greedy_next, cfg))

    def propose(self, ctx: Sequence[int], k: int) -> List[int]:
        toks = list(ctx)
        out: List[int] = []
        for _ in range(max(k, 0)):
            pad = -len(toks) % self.bucket or self.bucket
            arr = jnp.asarray(toks + [0] * pad, jnp.int32)[None, :]
            nxt = int(self._next_tok(self.params, arr,
                                     jnp.asarray(len(toks), jnp.int32)))
            out.append(nxt)
            toks.append(nxt)
        return out


def _greedy_next(cfg, params, tokens, length):
    """Greedy next token after position ``length - 1`` of padded ``tokens``
    (``length`` is traced, so jit compiles once per padding bucket, not
    once per context length)."""
    from repro.models import layers as L
    from repro.models import model as M

    h = M.forward_fullseq(cfg, params, tokens)
    hl = jax.lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
    logits = L.logits_from_hidden(cfg, params["embed"], hl)
    return jnp.argmax(logits[0, 0], axis=-1).astype(jnp.int32)
