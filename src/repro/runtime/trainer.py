"""Trainer: checkpoint/restart fault tolerance + straggler watchdog.

Large-fleet posture:
  * async checkpoint every N steps with atomic commit;
  * ``run_with_recovery`` restarts from the last commit on (injected or
    real) step failures — the checkpoint-reshard-restart loop used at
    1000+-node scale;
  * a straggler watchdog tracks a step-time EMA; steps slower than
    ``straggler_factor x EMA`` are flagged (and counted) — on a real fleet
    the flag triggers hot-spare swap / data re-sharding, simulated here;
  * deterministic data (pure function of step) makes recovery replayable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax

from repro.checkpoint.store import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.tracing import EventType, TraceBuffer
from repro.models import steps as ST
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    ema_alpha: float = 0.2
    max_restarts: int = 3


class FailureInjector:
    """Deterministic failure schedule: raise at given steps (once each)."""

    def __init__(self, fail_at: List[int]):
        self.fail_at = set(fail_at)
        self.fired: set = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class Trainer:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, data,
                 tcfg: TrainerConfig = TrainerConfig(),
                 opt_cfg: Optional[AdamWConfig] = None,
                 tracer: Optional[TraceBuffer] = None,
                 compress: bool = False):
        self.cfg, self.shape, self.data, self.tcfg = cfg, shape, data, tcfg
        self.opt_cfg = opt_cfg or ST.default_opt_cfg(cfg)
        self.tracer = tracer
        self.compress = compress
        self.step_fn = jax.jit(ST.make_train_step(cfg, self.opt_cfg, compress),
                               donate_argnums=(0,))
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir)
        self.metrics_log: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []
        self.restarts = 0

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        return ST.init_train_state(self.cfg, self.opt_cfg,
                                   jax.random.PRNGKey(seed), self.compress)

    def _resume_or_init(self, seed: int = 0):
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return self.init_state(seed), 0
        like = ST.init_train_state(self.cfg, self.opt_cfg,
                                   jax.random.PRNGKey(seed), self.compress)
        state, step = restore_checkpoint(self.tcfg.ckpt_dir, like, last)
        return state, step

    # ------------------------------------------------------------------
    def run(self, state=None, start_step: int = 0,
            failure: Optional[FailureInjector] = None) -> Dict[str, Any]:
        if state is None:
            state, start_step = self._resume_or_init()
        ema = None
        step = start_step
        while step < self.tcfg.total_steps:
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch(step).items()}
            t0 = time.perf_counter()
            if failure is not None:
                failure.maybe_fail(step)
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            # straggler watchdog
            if ema is None:
                ema = dt
            if dt > self.tcfg.straggler_factor * ema and step > start_step + 2:
                self.straggler_steps.append(step)
                if self.tracer:
                    self.tracer.record_host(EventType.SYNC, step, 1)
            ema = self.tcfg.ema_alpha * dt + (1 - self.tcfg.ema_alpha) * ema

            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                self.metrics_log.append({
                    "step": step, "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "lr": float(metrics["lr"]), "step_s": dt,
                })
            step += 1
            if step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return {"state": state, "final_step": step,
                "metrics": self.metrics_log,
                "stragglers": self.straggler_steps,
                "restarts": self.restarts}

    # ------------------------------------------------------------------
    def run_with_recovery(self, failure: Optional[FailureInjector] = None,
                          seed: int = 0) -> Dict[str, Any]:
        """Full fault-tolerant loop: restart from last commit on failure."""
        attempts = 0
        while True:
            try:
                state, start = self._resume_or_init(seed)
                return self.run(state, start, failure)
            except RuntimeError as e:
                attempts += 1
                self.restarts = attempts
                self.ckpt.wait()
                if attempts > self.tcfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.tcfg.max_restarts}") from e
