import os

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# Hypothesis example budgets: "ci" is the default everywhere (same budget
# the suites historically hardcoded); the scheduled nightly job selects
# "nightly" via --hypothesis-profile=nightly for a much deeper search.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile("ci", max_examples=50, deadline=None)
    settings.register_profile(
        "nightly", max_examples=500, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:        # property suites importorskip hypothesis anyway
    pass


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def matrix_page_size() -> int:
    """Engine page size under test — the CI matrix sets REPRO_PAGE_SIZE
    to cover {4, 8} in separate jobs."""
    return int(os.environ.get("REPRO_PAGE_SIZE", "4"))


@pytest.fixture(scope="session")
def matrix_use_kernel() -> bool:
    """Attention path under test — the CI matrix sets REPRO_ATTN_PATH to
    'kernel' (Pallas, interpret mode on CPU) or 'ref' (XLA oracle)."""
    return os.environ.get("REPRO_ATTN_PATH", "ref") == "kernel"


@pytest.fixture(scope="session")
def matrix_kv_dtype() -> str:
    """KV-pool storage dtype under test — the CI quantization matrix sets
    REPRO_KV_DTYPE to 'int8' in dedicated legs (default 'bf16')."""
    return os.environ.get("REPRO_KV_DTYPE", "bf16")
