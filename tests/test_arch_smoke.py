"""Per-architecture smoke tests: reduced config, one fwd/train/decode step
on CPU, asserting output shapes + finiteness (the assignment's contract)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, smoke_shape
from repro.models import model as M
from repro.models import steps as ST

ARCHS = list_archs()


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).smoke()
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, arch_state):
    cfg, _ = arch_state(arch)
    shape = smoke_shape("train")
    batch = ST.make_batch(cfg, shape, jax.random.PRNGKey(1))
    state = ST.init_train_state(cfg, ST.default_opt_cfg(cfg),
                                jax.random.PRNGKey(0))
    step = jax.jit(ST.make_train_step(cfg))
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), metrics
    assert metrics["loss"] > 0
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_shapes(arch, arch_state):
    cfg, params = arch_state(arch)
    shape = smoke_shape("prefill")
    batch = ST.make_batch(cfg, shape, jax.random.PRNGKey(2))
    logits = jax.jit(ST.make_prefill_step(cfg))(params, batch)
    assert logits.shape == (shape.global_batch, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, arch_state):
    cfg, params = arch_state(arch)
    shape = smoke_shape("decode")
    T = max(cfg.cache_len(shape), 1)
    cache = M.init_cache(cfg, shape.global_batch, T)
    batch = ST.make_batch(cfg, shape, jax.random.PRNGKey(3))
    logits, new_cache = jax.jit(ST.make_decode_step(cfg))(params, cache, batch)
    assert logits.shape == (shape.global_batch, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_init(arch, arch_state):
    cfg, params = arch_state(arch)
    specs = M.param_specs(cfg)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs)
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert p.shape == s.shape, (p.shape, s.shape)
        assert p.dtype == s.dtype
