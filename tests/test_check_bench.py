"""The bench-regression gate itself (``scripts/check_bench.py``) — the
script that guards every PR was previously the only untested code path in
CI.  Covers: pass-through, relative regressions in both gate directions
(lower-better and higher-better), improvements, metrics missing from the
fresh vs the baseline side, workload mismatch, malformed input, and the
absolute speculation gates (acceptance floor, spec-on < spec-off), and
the fault-tolerance gates on the ``degradation`` section (goodput and
within-deadline floors, zero unhandled exceptions, missing section
fails), and the live-traffic gates on the ``latency`` section (tail
TTFT/TPOT relative gates in both directions, SLO-goodput floor,
replay-identical requirement, missing section fails), and the tiered
prefix-cache gates on the ``hierarchical_cache`` section (tiered hit
rate strictly above device-only, corpus/pool ratio floor, token-parity
requirement, missing section fails), and the int8 gates on the
``quantized_kv`` section (bytes/token-ratio ceiling, teacher-forced
token-agreement floor, kernel/oracle parity flag, missing section
fails), and the ``--allow-missing-baseline`` bootstrap path."""
import copy
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "scripts"))
import check_bench  # noqa: E402


def result(**over):
    """A minimal valid BENCH_serve.json covering every gated path."""
    r = {
        "workload": {"requests": 3, "prompt_len": 12, "max_new": 4,
                     "page_size": 4, "max_lanes": 2},
        "chunked_prefill": {"iters_per_request": 4.0,
                            "h2d_per_generated_token": 1.5},
        "speculation": {
            "acceptance_rate": 0.6,
            "spec_off": {"iters_per_generated_token": 0.54},
            "spec_on": {"iters_per_generated_token": 0.46},
        },
        "sampling": {
            "greedy": {"iters_per_generated_token": 0.78},
        },
        "degradation": {
            "goodput": 0.5,
            "within_deadline_fraction": 0.67,
            "unhandled_exceptions": 0,
        },
        "latency": {
            "ttft_p95_s": 0.08,
            "ttft_p99_s": 0.10,
            "tpot_p95_s": 0.01,
            "tpot_p99_s": 0.01,
            "slo_goodput": 1.0,
            "replay_identical": True,
        },
        "hierarchical_cache": {
            "corpus_to_pool_ratio": 4.0,
            "device_only": {"prefix_hit_rate": 0.23},
            "tiered": {"prefix_hit_rate": 0.43},
            "token_parity": True,
        },
        "quantized_kv": {
            "bytes_per_token_ratio": 0.53,
            "page_pool_headroom": 1.88,
            "token_agreement": 1.0,
            "kernel_ref_outputs_match": True,
        },
        "planner_accuracy": {
            "tolerance": 0.25,
            "gated": {
                "latency.throughput_rps": 0.0,
                "latency.ttft_p95_s": 0.0,
                "quantized_kv.bf16.iterations": 0.0,
                "cluster_sweep.1.iterations": 0.0,
                "hierarchical_cache.tiered.demoted_pages": -0.018,
            },
            "workloads_within_tolerance": 4,
            "max_gated_abs_rel_err": 0.018,
            "capacity_demo": {"slo_met": True},
        },
    }
    for k, v in over.items():
        parts = k.split(".")
        d = r
        for p in parts[:-1]:
            d = d[p]
        if v is ...:
            del d[parts[-1]]
        else:
            d[parts[-1]] = v
    return r


@pytest.fixture
def gate(tmp_path):
    """Write (baseline, fresh) dicts and run the gate, returning its exit
    code; non-dict payloads are written verbatim (malformed-input cases)."""
    def run(base, fresh, *extra):
        bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
        bp.write_text(base if isinstance(base, str) else json.dumps(base))
        fp.write_text(fresh if isinstance(fresh, str) else json.dumps(fresh))
        return check_bench.main(["--baseline", str(bp), "--fresh", str(fp),
                                 *extra])
    return run


def test_identical_results_pass(gate):
    assert gate(result(), result()) == 0


def test_improvement_passes(gate):
    fresh = result(**{"chunked_prefill.iters_per_request": 2.0,
                      "speculation.acceptance_rate": 0.9})
    assert gate(result(), fresh) == 0


def test_lower_better_regression_fails(gate):
    fresh = result(**{"chunked_prefill.iters_per_request": 4.6})  # +15%
    assert gate(result(), fresh) == 1


def test_higher_better_regression_fails(gate):
    # acceptance rate DROPPING 15% must fail even though the raw ratio
    # check for lower-better metrics would wave it through
    fresh = result(**{"speculation.acceptance_rate": 0.51})
    assert gate(result(), fresh) == 1


def test_within_tolerance_passes(gate):
    fresh = result(**{"chunked_prefill.iters_per_request": 4.3})   # +7.5%
    assert gate(result(), fresh) == 0


def test_custom_max_regress(gate):
    fresh = result(**{"chunked_prefill.iters_per_request": 4.3})   # +7.5%
    assert gate(result(), fresh, "--max-regress", "0.05") == 1


def test_metric_missing_from_fresh_fails(gate):
    fresh = result(**{"chunked_prefill.iters_per_request": ...})
    assert gate(result(), fresh) == 1


def test_new_metric_missing_from_baseline_passes(gate, capsys):
    # a metric introduced by the current PR has no baseline yet: report it
    # as NEW, do not fail — otherwise metrics could never be added
    base = result(**{"chunked_prefill.h2d_per_generated_token": ...})
    assert gate(base, result()) == 0
    assert "NEW" in capsys.readouterr().out


def test_workload_mismatch_exits_2(gate):
    fresh = result(**{"workload.max_new": 8})
    assert gate(result(), fresh) == 2


def test_malformed_baseline_exits_2(gate):
    assert gate("{not json", result()) == 2


def test_malformed_fresh_exits_2(gate):
    assert gate(result(), "[]") == 2


def test_missing_baseline_file_exits_2(tmp_path):
    fp = tmp_path / "fresh.json"
    fp.write_text(json.dumps(result()))
    assert check_bench.main(["--baseline", str(tmp_path / "nope.json"),
                             "--fresh", str(fp)]) == 2


def test_acceptance_floor_gates(gate):
    fresh = result(**{"speculation.acceptance_rate": 0.1})
    base = copy.deepcopy(fresh)       # relative gate is clean: same values
    assert gate(base, fresh) == 1     # ... but the absolute floor fails
    assert gate(base, fresh, "--spec-accept-floor", "0.05") == 0


def test_spec_on_must_beat_spec_off(gate):
    fresh = result(**{"speculation.spec_on.iters_per_generated_token": 0.54})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


def test_speculation_section_missing_fails(gate):
    fresh = result(**{"speculation": ...})
    base = result(**{"speculation": ...})
    assert gate(base, fresh) == 1


def test_sampling_greedy_path_regression_fails(gate):
    # the unified-API sampler must not inflate the greedy hot path's
    # iteration structure: +15% on the temperature-0 workload fails
    fresh = result(**{"sampling.greedy.iters_per_generated_token": 0.9})
    assert gate(result(), fresh) == 1


def test_sampling_metric_new_in_baseline_passes(gate, capsys):
    # baselines committed before the sampling workload existed must not
    # chicken/egg-block the PR that introduces it
    base = result(**{"sampling": ...})
    assert gate(base, result()) == 0
    assert "NEW" in capsys.readouterr().out


# ------------------------------------------------ degradation gates --

def test_goodput_relative_regression_fails(gate):
    # goodput is higher-better: a 20% drop fails the relative gate even
    # though it still clears the absolute floor
    fresh = result(**{"degradation.goodput": 0.4,
                      "degradation.within_deadline_fraction": 0.67})
    assert gate(result(), fresh, "--goodput-floor", "0.3") == 1


def test_goodput_floor_gates(gate):
    fresh = result(**{"degradation.goodput": 0.2})
    base = copy.deepcopy(fresh)        # relative gate is clean: same values
    assert gate(base, fresh) == 1      # ... but the absolute floor fails
    assert gate(base, fresh, "--goodput-floor", "0.1") == 0


def test_deadline_floor_gates(gate):
    fresh = result(**{"degradation.within_deadline_fraction": 0.3})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1
    assert gate(base, fresh, "--deadline-floor", "0.2") == 0


def test_unhandled_exceptions_fail_outright(gate):
    # an exception escaping the engine under fault injection is never
    # acceptable, whatever the baseline says
    fresh = result(**{"degradation.unhandled_exceptions": 1})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


def test_degradation_section_missing_from_fresh_fails(gate):
    # unlike a NEW metric, the fault storm silently disappearing from the
    # fresh result is exactly the regression the absolute gate catches
    fresh = result(**{"degradation": ...})
    base = result(**{"degradation": ...})
    assert gate(base, fresh) == 1


def test_degradation_new_in_baseline_passes(gate, capsys):
    # the PR that introduces the fault storm has no baseline for it yet:
    # relative gates report NEW, the absolute floors run on fresh alone
    base = result(**{"degradation": ...})
    assert gate(base, result()) == 0
    assert "NEW" in capsys.readouterr().out


def test_degradation_incomplete_section_fails(gate):
    fresh = result(**{"degradation.unhandled_exceptions": ...})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


# ---------------------------------------------------- latency gates --

def test_ttft_tail_regression_fails(gate):
    # p95 TTFT is lower-better: +25% on virtual time is a real scheduling
    # regression (virtual-clock metrics have no runner noise to excuse it)
    fresh = result(**{"latency.ttft_p95_s": 0.10})
    assert gate(result(), fresh) == 1


def test_tpot_tail_regression_fails(gate):
    fresh = result(**{"latency.tpot_p99_s": 0.015})
    assert gate(result(), fresh) == 1


def test_latency_improvement_passes(gate):
    fresh = result(**{"latency.ttft_p95_s": 0.05,
                      "latency.tpot_p95_s": 0.005})
    assert gate(result(), fresh) == 0


def test_slo_goodput_relative_regression_fails(gate):
    # higher-better direction: goodput dropping 20% fails even above floor
    fresh = result(**{"latency.slo_goodput": 0.8})
    assert gate(result(), fresh, "--slo-goodput-floor", "0.5") == 1


def test_slo_goodput_floor_gates(gate):
    fresh = result(**{"latency.slo_goodput": 0.4})
    base = copy.deepcopy(fresh)        # relative gate is clean: same values
    assert gate(base, fresh) == 1      # ... but the absolute floor fails
    assert gate(base, fresh, "--slo-goodput-floor", "0.3") == 0


def test_replay_divergence_fails_outright(gate):
    # two same-seed virtual-clock runs disagreeing means wall time leaked
    # into the metrics — every other latency gate is noise; always fail
    fresh = result(**{"latency.replay_identical": False})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


def test_latency_section_missing_from_fresh_fails(gate):
    # like degradation: the live-traffic probe going silent IS the
    # regression, it is not NEW-tolerated on the fresh side
    fresh = result(**{"latency": ...})
    base = result(**{"latency": ...})
    assert gate(base, fresh) == 1


def test_latency_new_in_baseline_passes(gate, capsys):
    # the PR that introduces the load generator has no baseline for it
    # yet: relative gates report NEW, absolute gates run on fresh alone
    base = result(**{"latency": ...})
    assert gate(base, result()) == 0
    assert "NEW" in capsys.readouterr().out


def test_latency_incomplete_section_fails(gate):
    fresh = result(**{"latency.replay_identical": ...})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


# ------------------------------------------- hierarchical-cache gates --

def test_tiered_hit_rate_relative_regression_fails(gate):
    # higher-better direction: the tiered hit rate dropping 20% fails the
    # relative gate even while still strictly above device-only
    fresh = result(**{"hierarchical_cache.tiered.prefix_hit_rate": 0.34})
    assert gate(result(), fresh) == 1


def test_tiered_must_beat_device_only(gate):
    # spill tiers that stop buying hits over the device pool are dead
    # weight — fails regardless of the baseline
    fresh = result(**{"hierarchical_cache.tiered.prefix_hit_rate": 0.23})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


def test_corpus_ratio_floor_gates(gate):
    fresh = result(**{"hierarchical_cache.corpus_to_pool_ratio": 2.0})
    base = copy.deepcopy(fresh)        # relative gate is clean: same values
    assert gate(base, fresh) == 1      # ... but the absolute floor fails
    assert gate(base, fresh, "--corpus-ratio-floor", "1.5") == 0


def test_tier_restore_parity_required(gate):
    # a page restored through host/disk decoding differently from the
    # device-resident original is corruption, never a trade-off
    fresh = result(**{"hierarchical_cache.token_parity": False})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


def test_hierarchical_cache_parity_flag_missing_fails(gate):
    fresh = result(**{"hierarchical_cache.token_parity": ...})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


def test_hierarchical_cache_section_missing_from_fresh_fails(gate):
    # like degradation/latency: the tiered-cache probe going silent IS
    # the regression, it is not NEW-tolerated on the fresh side
    fresh = result(**{"hierarchical_cache": ...})
    base = result(**{"hierarchical_cache": ...})
    assert gate(base, fresh) == 1


def test_hierarchical_cache_new_in_baseline_passes(gate, capsys):
    # the PR that introduces the tiered cache has no baseline for it yet:
    # relative gates report NEW, absolute gates run on fresh alone
    base = result(**{"hierarchical_cache": ...})
    assert gate(base, result()) == 0
    assert "NEW" in capsys.readouterr().out


# -------------------------------------------------- quantized-kv gates --

def test_kv_ratio_relative_regression_fails(gate):
    # lower-better direction: the int8 footprint creeping up 15% fails
    # the relative gate even while still under the absolute ceiling
    fresh = result(**{"quantized_kv.bytes_per_token_ratio": 0.609})
    assert gate(result(), fresh, "--kv-ratio-ceiling", "0.7") == 1


def test_kv_ratio_ceiling_gates(gate):
    fresh = result(**{"quantized_kv.bytes_per_token_ratio": 0.65})
    base = copy.deepcopy(fresh)        # relative gate is clean: same values
    assert gate(base, fresh) == 1      # ... but the absolute ceiling fails
    assert gate(base, fresh, "--kv-ratio-ceiling", "0.7") == 0


def test_token_agreement_floor_gates(gate):
    fresh = result(**{"quantized_kv.token_agreement": 0.95})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1
    assert gate(base, fresh, "--token-agreement-floor", "0.9") == 0


def test_token_agreement_relative_regression_fails(gate):
    # higher-better direction: agreement dropping 15% below the baseline
    # fails even when it still clears a loosened absolute floor
    fresh = result(**{"quantized_kv.token_agreement": 0.85})
    assert gate(result(), fresh, "--token-agreement-floor", "0.8") == 1


def test_quantized_kernel_ref_parity_required(gate):
    # the in-kernel dequant and the oracle disagreeing on tokens is a
    # kernel bug, never a quantization trade-off
    fresh = result(**{"quantized_kv.kernel_ref_outputs_match": False})
    base = copy.deepcopy(fresh)
    assert gate(base, fresh) == 1


def test_quantized_kv_section_missing_from_fresh_fails(gate):
    # like degradation/latency: the int8 probe going silent IS the
    # regression, it is not NEW-tolerated on the fresh side
    fresh = result(**{"quantized_kv": ...})
    base = result(**{"quantized_kv": ...})
    assert gate(base, fresh) == 1


def test_quantized_kv_new_in_baseline_passes(gate, capsys):
    # the PR that introduces the int8 path has no baseline for it yet:
    # relative gates report NEW, absolute gates run on fresh alone
    base = result(**{"quantized_kv": ...})
    assert gate(base, result()) == 0
    assert "NEW" in capsys.readouterr().out


# ---------------------------------------------- missing-baseline path --

def test_missing_baseline_with_flag_passes(tmp_path, capsys):
    # bootstrap path: no committed baseline yet, absolute gates only
    fp = tmp_path / "fresh.json"
    fp.write_text(json.dumps(result()))
    assert check_bench.main(["--baseline", str(tmp_path / "nope.json"),
                             "--fresh", str(fp),
                             "--allow-missing-baseline"]) == 0
    assert "WARN" in capsys.readouterr().out


def test_missing_baseline_with_flag_still_runs_absolute_gates(tmp_path):
    # the flag tolerates the missing baseline, not a failing fresh result
    fp = tmp_path / "fresh.json"
    fp.write_text(json.dumps(result(
        **{"quantized_kv.token_agreement": 0.5})))
    assert check_bench.main(["--baseline", str(tmp_path / "nope.json"),
                             "--fresh", str(fp),
                             "--allow-missing-baseline"]) == 1


def test_malformed_baseline_with_flag_passes(gate):
    # an unreadable baseline is the same bootstrap case as a missing one
    assert gate("{not json", result(), "--allow-missing-baseline") == 0


def test_missing_fresh_exits_2_despite_flag(tmp_path):
    # --allow-missing-baseline never excuses the fresh side
    bp = tmp_path / "base.json"
    bp.write_text(json.dumps(result()))
    assert check_bench.main(["--baseline", str(bp),
                             "--fresh", str(tmp_path / "nope.json"),
                             "--allow-missing-baseline"]) == 2


# --------------------------------------------- planner-accuracy gates --

def test_planner_section_missing_fails(gate):
    assert gate(result(), result(**{"planner_accuracy": ...})) == 1


def _gated(**errs):
    """The fixture's gated map with per-metric overrides (the metric
    names themselves contain dots, so the fixture's dotted-path override
    cannot reach into them)."""
    g = dict(result()["planner_accuracy"]["gated"])
    g.update(errs)
    return g


def test_planner_rel_err_above_ceiling_fails(gate):
    fresh = result(**{"planner_accuracy.gated":
                      _gated(**{"latency.throughput_rps": 0.4})})
    assert gate(result(), fresh) == 1


def test_planner_rel_err_within_ceiling_passes(gate):
    fresh = result(**{"planner_accuracy.gated":
                      _gated(**{"latency.throughput_rps": -0.2})})
    assert gate(result(), fresh) == 0


def test_planner_custom_ceiling(gate):
    fresh = result(**{"planner_accuracy.gated":
                      _gated(**{"latency.throughput_rps": -0.2})})
    assert gate(result(), fresh, "--planner-err-ceiling", "0.1") == 1


def test_planner_too_few_workloads_fails(gate):
    fresh = result(**{"planner_accuracy.gated": {
        "latency.throughput_rps": 0.0, "latency.ttft_p95_s": 0.0}})
    assert gate(result(), fresh) == 1


def test_planner_empty_gated_fails(gate):
    assert gate(result(), result(**{"planner_accuracy.gated": {}})) == 1


def test_planner_non_numeric_rel_err_fails(gate):
    fresh = result(**{"planner_accuracy.gated":
                      _gated(**{"latency.throughput_rps": None})})
    assert gate(result(), fresh) == 1


def test_planner_capacity_demo_slo_not_met_fails(gate):
    fresh = result(**{"planner_accuracy.capacity_demo.slo_met": False})
    assert gate(result(), fresh) == 1


def test_planner_accuracy_erosion_fails_relative_gate(gate):
    # still inside the absolute ceiling, but 67% worse than the
    # committed baseline -> the relative gate catches the drift
    fresh = result(**{"planner_accuracy.max_gated_abs_rel_err": 0.03})
    assert gate(result(), fresh) == 1
