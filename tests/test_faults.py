"""Fault tolerance end to end: typed backing-store errors, seeded fault
injection (transient I/O retried with backoff, corruption caught by
checksum, stalls), per-request ``"error"`` demotion instead of engine
death, deadlines (`deadline_iters`/`deadline_s` -> ``"timeout"``),
mid-stream cancellation, ``break``/``close()`` exception-safety of the
streaming iterator, admission-time load shedding (``"shed"``), the
drafter-failure and scheduler-watchdog DEGRADE paths, and the layer-2/
layer-3 trace analyses that make it all observable.

The fault-matrix tests carry ``@pytest.mark.chaos`` and run in the CI
``chaos`` job across page sizes {4, 8} (via ``REPRO_PAGE_SIZE`` and the
``matrix_page_size`` fixture) and, in the nightly int8 leg, with the
quantized KV pool (``REPRO_KV_DTYPE=int8`` / ``matrix_kv_dtype``) so
faults land on packed int8-page+scales swap blobs too; everything here
also runs in the plain suite at the default page size.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.analysis import (
    assert_faults_contained, layer1_decode, layer2_fault_recovery,
)
from repro.core.offload import BackingStoreError, HostBackingStore
from repro.core.tracing import EventType, TraceBuffer
from repro.models import model as M
from repro.runtime import (
    CacheConfig, EngineConfig, FaultInjector, FaultSpec,
    GenerationRequest, SamplingParams, ShardedPagedServer, make_engine,
    FINISH_ERROR, FINISH_SHED, FINISH_TIMEOUT,
)

MAX_NEW = 6
NUM_PAGES = 32


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b").smoke()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(vocab, n=4, seed=2):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=ln).tolist()
            for ln in rng.integers(3, 11, size=n)]


def _engine(cfg, params, *, page_size=4, kv_dtype="bf16", **kw):
    tracer = TraceBuffer(capacity=1 << 14)
    return make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=NUM_PAGES, page_size=page_size,
                          max_pages_per_seq=8, kv_dtype=kv_dtype),
        max_lanes=2, chunk=4, use_kernel=False, **kw),
        tracer=tracer)


def _submit_all(srv, prompts, **per_req):
    for rid, p in enumerate(prompts):
        srv.submit(GenerationRequest(
            rid=rid, prompt=tuple(p),
            sampling=SamplingParams(max_new=MAX_NEW),
            **{k: (v(rid) if callable(v) else v)
               for k, v in per_req.items()}))


def _drive_with_preempts(srv, at=(4,)):
    """Drain the engine, forcing a preemption of a running lane at the
    given delta counts so pages travel through the backing store."""
    hits = set(at)
    for i, _ in enumerate(srv.generate()):
        if i in hits:
            run = [r for r in srv.lanes if r is not None and not r.done]
            if run:
                srv.preempt(run[0].rid)
    return {r.rid: r for r in srv.finished}


def _assert_pristine(srv):
    srv.pool.check_invariants()
    assert srv.pool.free_pages() == NUM_PAGES
    assert len(srv.backing) == 0


@pytest.fixture(scope="module")
def baseline(cfg, params, matrix_kv_dtype):
    """Fault-free greedy outputs every survivor-parity check compares to
    — computed at the matrix KV dtype so int8 runs compare int8-to-int8
    (quantization shifts tokens relative to bf16, faults must not)."""
    srv = _engine(cfg, params, kv_dtype=matrix_kv_dtype)
    _submit_all(srv, _prompts(cfg.vocab_size))
    return {r.rid: r.tokens for r in srv.run()}


# ------------------------------------------------------- typed errors --

def test_backing_store_error_message():
    e = BackingStoreError(7, 3, "pop", kind="corrupt",
                          detail="checksum mismatch on restore")
    msg = str(e)
    assert "rid=7" in msg and "lpage=3" in msg
    assert "pop" in msg and "corrupt" in msg
    assert "checksum mismatch on restore" in msg
    assert (e.rid, e.lpage, e.op, e.kind) == (7, 3, "pop", "corrupt")
    assert not e.transient
    assert isinstance(e, RuntimeError)


def test_backing_store_pop_missing_is_typed():
    store = HostBackingStore()
    with pytest.raises(BackingStoreError) as ei:
        store.pop(5, 2)
    assert ei.value.kind == "missing"
    assert (ei.value.rid, ei.value.lpage, ei.value.op) == (5, 2, "pop")


def test_backing_store_overwrite_is_typed():
    store = HostBackingStore()
    page = np.zeros((2, 3), np.float32)
    store.put(1, 0, page)
    with pytest.raises(BackingStoreError) as ei:
        store.put(1, 0, page)
    assert ei.value.kind == "overwrite"
    store.pop(1, 0)                     # slot reusable after pop
    store.put(1, 0, page)


def test_backing_store_checksum_roundtrip():
    store = HostBackingStore()
    page = np.arange(12, dtype=np.float32).reshape(3, 4)
    store.put(9, 1, page)
    out = store.pop(9, 1)
    np.testing.assert_array_equal(out, page)


def test_backing_store_detects_corruption():
    inj = FaultInjector(plan={0: FaultSpec("corrupt", op="put")})
    store = HostBackingStore(inj)
    store.put(4, 0, np.ones((2, 2), np.float32))
    with pytest.raises(BackingStoreError) as ei:
        store.pop(4, 0)
    assert ei.value.kind == "corrupt" and not ei.value.transient
    assert "checksum" in str(ei.value)


# ---------------------------------------------------------- injector --

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(kind="cosmic-ray")
    with pytest.raises(ValueError):
        FaultSpec(op="get")
    with pytest.raises(ValueError):
        FaultInjector(rate=1.5)


def test_injector_is_deterministic():
    def decisions(seed):
        inj = FaultInjector(seed=seed, rate=0.3)
        fired = []
        for i in range(200):
            try:
                inj.before("put", i % 5, i % 3)
                fired.append(False)
            except BackingStoreError:
                fired.append(True)
        return fired

    a, b = decisions(11), decisions(11)
    assert a == b and any(a)
    assert decisions(12) != a


def test_injector_plan_and_persistent_site():
    inj = FaultInjector(plan={2: FaultSpec("io", persistent=True)})
    inj.before("put", 1, 0)
    inj.before("put", 1, 1)
    with pytest.raises(BackingStoreError) as ei:
        inj.before("put", 7, 4)         # op index 2: the planted fault
    assert not ei.value.transient
    # the same (op, rid, lpage) site keeps failing on every retry
    for _ in range(3):
        with pytest.raises(BackingStoreError):
            inj.before("put", 7, 4)
    inj.before("put", 7, 5)             # a different site is clean
    assert inj.report()["persistent_sites"] == 1


def test_injector_max_faults_bounds_storm():
    inj = FaultInjector(rate=1.0, max_faults=2)
    fired = 0
    for i in range(10):
        try:
            inj.before("put", 0, i)
        except BackingStoreError:
            fired += 1
    assert fired == 2
    assert inj.report() == {"ops": 10, "injected": 2,
                            "by_kind": {"io": 2, "corrupt": 0, "stall": 0},
                            "persistent_sites": 0}


def test_injector_traces_fault_events():
    tracer = TraceBuffer()
    inj = FaultInjector(plan={0: FaultSpec("io", persistent=True),
                              1: FaultSpec("corrupt", op="put")},
                        tracer=tracer)
    with pytest.raises(BackingStoreError):
        inj.before("put", 3, 0)
    assert inj.before("put", 4, 1).kind == "corrupt"
    events = layer1_decode(tracer.drain())
    codes = [(e.a0, e.a1) for e in events
             if e.etype == EventType.FAULT_INJECT]
    assert codes == [(3, 1 + 8), (4, 2)]


# --------------------------------------------------------- validation --

def test_deadline_validation():
    with pytest.raises(ValueError):
        GenerationRequest(rid=0, prompt=(1, 2), deadline_iters=0)
    with pytest.raises(ValueError):
        GenerationRequest(rid=0, prompt=(1, 2), deadline_s=-1.0)
    GenerationRequest(rid=0, prompt=(1, 2), deadline_iters=1,
                      deadline_s=0.5)   # both together are fine


# ----------------------------------------------------------- engine --

@pytest.mark.chaos
def test_deadline_iters_times_out(cfg, params, matrix_page_size):
    srv = _engine(cfg, params, page_size=matrix_page_size)
    _submit_all(srv, _prompts(cfg.vocab_size),
                deadline_iters=lambda rid: 2 if rid == 0 else None)
    res = {r.rid: r for r in srv.run()}
    assert res[0].finish_reason == FINISH_TIMEOUT
    assert "deadline" in res[0].error
    assert all(res[r].finish_reason == "length" for r in (1, 2, 3))
    assert srv.timeouts == 1
    events = layer1_decode(srv.tracer.drain())
    assert any(e.etype == EventType.REQUEST_TIMEOUT and e.a0 == 0
               for e in events)
    assert assert_faults_contained(events)
    _assert_pristine(srv)


@pytest.mark.chaos
def test_deadline_s_times_out(cfg, params):
    srv = _engine(cfg, params)
    _submit_all(srv, _prompts(cfg.vocab_size),
                deadline_s=lambda rid: 1e-9 if rid == 1 else None)
    res = {r.rid: r for r in srv.run()}
    assert res[1].finish_reason == FINISH_TIMEOUT
    assert all(res[r].finish_reason == "length" for r in (0, 2, 3))
    _assert_pristine(srv)


@pytest.mark.chaos
def test_cancel_from_stream_loop(cfg, params, matrix_page_size,
                                 matrix_kv_dtype, baseline):
    srv = _engine(cfg, params, page_size=matrix_page_size,
                  kv_dtype=matrix_kv_dtype)
    _submit_all(srv, _prompts(cfg.vocab_size))
    cancelled = False
    deltas = []
    for d in srv.generate():
        deltas.append(d)
        if not cancelled and d.rid == 0 and d.tokens:
            assert srv.cancel(0)
            cancelled = True
    res = {r.rid: r for r in srv.finished}
    assert res[0].finish_reason == "aborted"
    assert any(d.event == "cancel" and d.rid == 0 for d in deltas)
    assert srv.cancelled == 1
    if matrix_page_size == 4:
        survivors = {r: res[r].tokens for r in (1, 2, 3)}
        assert survivors == {r: baseline[r] for r in (1, 2, 3)}
    assert not srv.cancel(0)            # already finished
    assert not srv.cancel(99)           # unknown rid
    _assert_pristine(srv)


def test_break_and_close_leave_pool_consistent(cfg, params, matrix_kv_dtype,
                                               baseline):
    """Regression: a consumer that ``break``s (or ``.close()``s) the
    streaming iterator mid-run must leave the pool consistent — and the
    engine resumable to the exact fault-free outputs."""
    srv = _engine(cfg, params, kv_dtype=matrix_kv_dtype)
    _submit_all(srv, _prompts(cfg.vocab_size))
    gen = srv.generate()
    for i, _ in enumerate(gen):
        if i == 3:
            break                       # implicit GeneratorExit
    srv.pool.check_invariants()
    res = {r.rid: r.tokens for r in srv.run()}
    assert res == baseline, "resume after break diverged"
    _assert_pristine(srv)

    srv = _engine(cfg, params, kv_dtype=matrix_kv_dtype)
    _submit_all(srv, _prompts(cfg.vocab_size))
    gen = srv.generate()
    next(gen)
    gen.close()                         # explicit close
    srv.pool.check_invariants()
    assert {r.rid: r.tokens for r in srv.run()} == baseline


@pytest.mark.chaos
def test_transient_faults_recovered_by_retry(cfg, params, matrix_page_size,
                                             matrix_kv_dtype, baseline):
    inj = FaultInjector(seed=2, rate=0.5, kinds=(FaultSpec("io"),))
    srv = _engine(cfg, params, page_size=matrix_page_size,
                  kv_dtype=matrix_kv_dtype,
                  fault_injector=inj, swap_retries=6)
    _submit_all(srv, _prompts(cfg.vocab_size))
    res = _drive_with_preempts(srv, at=(2, 6))
    assert len(res) == 4
    assert all(r.finish_reason == "length" for r in res.values())
    assert inj.injected > 0 and srv.fault_retries > 0
    assert srv.recovered_faults > 0 and srv.errors == 0
    if matrix_page_size == 4:
        assert {r: res[r].tokens for r in res} == baseline, \
            "transient fault storm changed survivor outputs"
    events = layer1_decode(srv.tracer.drain())
    rep = layer2_fault_recovery(events)
    assert rep["faults"] == inj.injected
    assert all(v["finished"] for v in rep["requests"].values())
    assert assert_faults_contained(events)
    _assert_pristine(srv)


@pytest.mark.chaos
def test_persistent_fault_demotes_one_request(cfg, params, matrix_page_size,
                                              matrix_kv_dtype, baseline):
    inj = FaultInjector(plan={i: FaultSpec("io", op="pop", persistent=True)
                              for i in range(64)})
    srv = _engine(cfg, params, page_size=matrix_page_size,
                  kv_dtype=matrix_kv_dtype,
                  fault_injector=inj, swap_retries=2)
    _submit_all(srv, _prompts(cfg.vocab_size))
    res = _drive_with_preempts(srv)
    assert len(res) == 4
    errs = [r for r in res.values() if r.finish_reason == FINISH_ERROR]
    assert len(errs) == 1 and srv.errors == 1
    assert "injected I/O fault" in errs[0].error
    survivors = [r for r in res.values() if r.finish_reason == "length"]
    assert len(survivors) == 3
    if matrix_page_size == 4:
        assert all(r.tokens == baseline[r.rid] for r in survivors)
    events = layer1_decode(srv.tracer.drain())
    assert layer2_fault_recovery(events)["persistent_faults"] > 0
    assert assert_faults_contained(events)
    _assert_pristine(srv)


@pytest.mark.chaos
def test_corruption_detected_at_swap_in(cfg, params, matrix_page_size,
                                        matrix_kv_dtype):
    inj = FaultInjector(plan={0: FaultSpec("corrupt", op="put")})
    srv = _engine(cfg, params, page_size=matrix_page_size,
                  kv_dtype=matrix_kv_dtype, fault_injector=inj)
    _submit_all(srv, _prompts(cfg.vocab_size))
    res = _drive_with_preempts(srv)
    errs = [r for r in res.values() if r.finish_reason == FINISH_ERROR]
    assert len(errs) == 1
    assert "checksum" in errs[0].error
    _assert_pristine(srv)


@pytest.mark.chaos
def test_stall_fault_slows_but_completes(cfg, params, matrix_kv_dtype,
                                         baseline):
    inj = FaultInjector(plan={0: FaultSpec("stall", stall_s=0.01),
                              1: FaultSpec("stall", stall_s=0.01)})
    srv = _engine(cfg, params, kv_dtype=matrix_kv_dtype, fault_injector=inj)
    _submit_all(srv, _prompts(cfg.vocab_size))
    res = _drive_with_preempts(srv)
    assert all(r.finish_reason == "length" for r in res.values())
    assert {r: res[r].tokens for r in res} == baseline
    assert inj.by_kind["stall"] == 2
    _assert_pristine(srv)


@pytest.mark.chaos
def test_load_shedding_rejects_lowest_priority(cfg, params):
    srv = _engine(cfg, params, max_queue_depth=3)
    _submit_all(srv, _prompts(cfg.vocab_size),
                priority=lambda rid: 1 if rid < 3 else 0)
    res = {r.rid: r for r in srv.run()}
    assert res[3].finish_reason == FINISH_SHED
    assert srv.shed_count == 1
    assert all(res[r].finish_reason == "length" for r in range(3))
    events = layer1_decode(srv.tracer.drain())
    assert any(e.etype == EventType.REQUEST_SHED and e.a0 == 3
               for e in events)
    assert assert_faults_contained(events)
    _assert_pristine(srv)


@pytest.mark.chaos
def test_drafter_exception_degrades_lane(cfg, params):
    class ExplodingDrafter:
        def propose(self, tokens, k):
            raise RuntimeError("drafter died")

    prompts = _prompts(cfg.vocab_size, n=2)
    ref = _engine(cfg, params)
    _submit_all(ref, prompts)
    want = {r.rid: r.tokens for r in ref.run()}

    srv = _engine(cfg, params, spec_k=3)
    srv.drafter = ExplodingDrafter()
    _submit_all(srv, prompts)
    res = {r.rid: r for r in srv.run()}
    assert all(r.finish_reason == "length" for r in res.values())
    assert {r: res[r].tokens for r in res} == want, \
        "a broken drafter changed outputs"
    assert srv.degrades > 0
    events = layer1_decode(srv.tracer.drain())
    assert any(e.etype == EventType.DEGRADE and e.a1 == 1 for e in events)
    _assert_pristine(srv)


@pytest.mark.chaos
def test_watchdog_aborts_stalled_lane(cfg, params):
    srv = _engine(cfg, params, watchdog_iters=2)
    _submit_all(srv, _prompts(cfg.vocab_size, n=1))
    srv.step()
    req = next(r for r in srv.lanes if r is not None)
    # freeze the lane: iterations pass, the (fed, out) marker does not move
    for _ in range(4):
        srv.iterations += 1
        srv._post_iteration(0.01)
        if req.done:
            break
    assert req.done and req.finish_reason == FINISH_ERROR
    assert "watchdog" in req.error
    events = layer1_decode(srv.tracer.drain())
    assert any(e.etype == EventType.DEGRADE and e.a1 == 2 and
               e.a0 == req.rid for e in events)
    _assert_pristine(srv)


def test_straggler_ema_flags_slow_iteration(cfg, params):
    srv = _engine(cfg, params, straggler_factor=3.0)
    srv.iterations = 10                 # past the jit warmup guard
    for _ in range(5):
        srv._post_iteration(0.01)       # settle the EMA
    srv._post_iteration(0.5)            # 50x the moving average
    assert srv.straggler_steps == 1
    events = layer1_decode(srv.tracer.drain())
    assert any(e.etype == EventType.DEGRADE and e.a1 == 3 for e in events)


@pytest.mark.chaos
def test_sharded_engine_survives_faults(cfg, params):
    inj = FaultInjector(seed=5, rate=0.4, kinds=(FaultSpec("io"),))
    tracer = TraceBuffer(capacity=1 << 14)
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=NUM_PAGES, page_size=4,
                          max_pages_per_seq=8),
        max_lanes=2, chunk=4, use_kernel=False, sharded=True,
        clusters=1, heads=1, fault_injector=inj, swap_retries=4),
        tracer=tracer)
    assert isinstance(srv, ShardedPagedServer)
    _submit_all(srv, _prompts(cfg.vocab_size))
    res = _drive_with_preempts(srv, at=(2, 6))
    assert len(res) == 4
    assert all(r.finish_reason in ("length", FINISH_ERROR)
               for r in res.values())
    assert inj.injected > 0
    # exceptional exits must clear the cluster map and parked lengths
    assert not srv.cpool.cluster_of and not srv._parked_len
    srv.cpool.check_invariants()
    assert assert_faults_contained(layer1_decode(tracer.drain()))


@pytest.mark.chaos
def test_timeout_releases_swapped_out_request(cfg, params, matrix_kv_dtype):
    """A request that times out while parked in the backing store must
    release its host payloads too — the discard path, not just pages."""
    srv = _engine(cfg, params, kv_dtype=matrix_kv_dtype)
    ps = _prompts(cfg.vocab_size)
    _submit_all(srv, ps, deadline_iters=lambda rid: 6 if rid == 0 else None)
    for i, _ in enumerate(srv.generate()):
        if i == 1 and not srv.lanes[0].done:
            victim = next(r for r in srv.lanes if r is not None)
            if victim.rid == 0:
                srv.preempt(0)
    res = {r.rid: r for r in srv.finished}
    assert len(res) == 4
    _assert_pristine(srv)


# ---------------------------------------------------------- analysis --

def _host_rows(*evs):
    return np.asarray([(i, 255, int(t), a0, a1)
                       for i, (t, a0, a1) in enumerate(evs)], np.int64)


def test_layer2_fault_recovery_decodes_codes():
    rows = _host_rows(
        (EventType.FAULT_INJECT, 1, 1),          # io, transient
        (EventType.FAULT_INJECT, 1, 2 + 8),      # corrupt, persistent
        (EventType.FAULT_INJECT, 2, 3),          # stall
        (EventType.REQUEST_TIMEOUT, 3, 10),
        (EventType.REQUEST_SHED, 4, 9),
        (EventType.DEGRADE, 5, 1),
        (EventType.DEGRADE, 6, 2),
        (EventType.REQUEST_FINISH, 1, 4),
        (EventType.REQUEST_FINISH, 2, 4),
    )
    rep = layer2_fault_recovery(layer1_decode(rows))
    assert rep["faults"] == 3
    assert rep["by_kind"] == {"io": 1, "corrupt": 1, "stall": 1}
    assert rep["persistent_faults"] == 1
    assert rep["timeouts"] == 1 and rep["sheds"] == 1
    assert rep["degrades"] == {"drafter": 1, "watchdog": 1, "straggler": 0}
    assert rep["requests"][1]["finished"]
    assert rep["requests"][1]["kinds"] == ["io", "corrupt"]


def test_assert_faults_contained_catches_lost_request():
    lost = _host_rows((EventType.FAULT_INJECT, 1, 1),
                      (EventType.REQUEST_FINISH, 2, 4))
    assert not assert_faults_contained(layer1_decode(lost))
    ok = _host_rows((EventType.FAULT_INJECT, 1, 1),
                    (EventType.REQUEST_FINISH, 1, 4))
    assert assert_faults_contained(layer1_decode(ok))
