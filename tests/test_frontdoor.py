"""Live-traffic serving front door: the injectable engine clock
(deterministic deadlines + non-blocking retry backoff), the scheduler
policy object (greedy-chunk parity, token-budget interleave), admission
shed-victim ordering, the ``FrontDoor`` arrival loop with its latency
report, and the ``layer2_latency`` trace view.

Everything timing-shaped runs on a :class:`VirtualClock`: a deadline
expires at an exact, asserted tick; a lane in retry backoff visibly
yields the engine to its neighbours instead of sleeping; and two
identical serve runs produce byte-identical latency reports.
"""
import json

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.analysis import layer1_decode, layer2_latency
from repro.core.tracing import EventType, TraceBuffer
from repro.models import model as M
from repro.runtime import (
    Arrival, CacheConfig, EngineConfig, FaultInjector, FaultSpec,
    FrontDoor,
    GenerationRequest, GreedyChunkPolicy, MonotonicClock, SamplingParams,
    TokenBudgetPolicy, VirtualClock, latency_report, make_engine,
    FINISH_LENGTH, FINISH_SHED, FINISH_TIMEOUT,
)

MAX_NEW = 6
NUM_PAGES = 32


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b").smoke()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(vocab, n=2, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=ln).tolist()
            for ln in rng.integers(4, 10, size=n)]


def _engine(cfg, params, **kw):
    tracer = TraceBuffer(capacity=1 << 14)
    return make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=NUM_PAGES, page_size=4,
                          max_pages_per_seq=8),
        max_lanes=2, chunk=4, use_kernel=False, **kw),
        tracer=tracer)


def _submit_all(srv, prompts, **per_req):
    for rid, p in enumerate(prompts):
        srv.submit(GenerationRequest(
            rid=rid, prompt=tuple(p),
            sampling=SamplingParams(max_new=MAX_NEW),
            **{k: (v(rid) if callable(v) else v)
               for k, v in per_req.items()}))


# ----------------------------------------------------------- clocks --

def test_virtual_clock_advance_and_hold():
    clk = VirtualClock()
    assert clk.now() == 0.0
    assert clk.advance(1.5) == 1.5
    clk.hold_until(3.0)
    assert clk.now() == 3.0
    clk.hold_until(2.0)            # never backwards
    assert clk.now() == 3.0
    assert clk.advance(0.0) == 3.0


def test_virtual_clock_rejects_negative_advance():
    with pytest.raises(ValueError):
        VirtualClock().advance(-0.1)


def test_monotonic_clock_hold_is_capped():
    clk = MonotonicClock()
    t0 = clk.now()
    clk.hold_until(t0 + 3600.0)    # far future: one capped sleep, no wedge
    assert clk.now() - t0 < 1.0
    clk.hold_until(t0)             # past target returns immediately
    assert clk.now() >= t0


# --------------------------------------------------------- policies --

def test_greedy_chunk_policy_plan():
    alloc = GreedyChunkPolicy().plan(((0, 10), (1, 2)), 0, 4)
    assert alloc == {0: 4, 1: 2}


def test_token_budget_policy_decode_first():
    # 3 decode lanes eat 3 of the 5-token budget; the two prefill lanes
    # split the remaining 2 in admission order
    alloc = TokenBudgetPolicy(5).plan(((2, 10), (3, 7)), 3, 4)
    assert alloc == {2: 2, 3: 0}


def test_token_budget_policy_starved_prefill_gets_zero():
    alloc = TokenBudgetPolicy(2).plan(((0, 8),), 4, 4)
    assert alloc == {0: 0}


def test_token_budget_policy_rejects_empty_budget():
    with pytest.raises(ValueError):
        TokenBudgetPolicy(0)


def test_token_budget_engine_outputs_match_greedy(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=3)
    ref = _engine(cfg, params)
    _submit_all(ref, prompts)
    want = {r.rid: r.tokens for r in ref.run()}

    srv = _engine(cfg, params, scheduler_policy=TokenBudgetPolicy(3))
    _submit_all(srv, prompts)
    got = {r.rid: r.tokens for r in srv.run()}
    # the budget reshapes WHEN prompt chunks are fed, never what the
    # model computes: token-for-token parity with the greedy interleave
    assert got == want
    assert all(r.finish_reason == FINISH_LENGTH for r in srv.finished)


def test_policy_zero_allocation_cannot_stall_engine(cfg, params):
    class Lazy:
        def plan(self, prefill, n_decode, chunk):
            return {lane: 0 for lane, _ in prefill}
    srv = _engine(cfg, params, scheduler_policy=Lazy())
    _submit_all(srv, _prompts(cfg.vocab_size, n=2))
    done = srv.run()
    # an all-zero plan with no decode lanes would deadlock; the engine
    # forces the oldest prefill lane forward one chunk instead
    assert len(done) == 2
    assert all(r.finish_reason == FINISH_LENGTH for r in done)


# ------------------------------------------- deadlines on the clock --

def test_deadline_s_expires_at_exact_virtual_tick(cfg, params):
    clk = VirtualClock()
    srv = _engine(cfg, params, clock=clk)
    srv.submit(GenerationRequest(rid=0, prompt=(5, 6, 7),
                                 sampling=SamplingParams(max_new=20),
                                 deadline_s=1.0))
    srv.step()                      # admit + prefill at t=0
    clk.advance(0.5)
    srv.step()                      # t=0.5 < 1.0: still alive
    assert not srv.finished
    clk.advance(0.5)                # t == deadline exactly
    srv.step()
    res = {r.rid: r for r in srv.finished}
    assert res[0].finish_reason == FINISH_TIMEOUT
    # the sweep fired the moment now() reached the bound — a property
    # raw time.monotonic() could never pin down to a tick
    assert clk.now() == 1.0


def test_deadline_s_on_virtual_clock_never_fires_early(cfg, params):
    clk = VirtualClock()
    srv = _engine(cfg, params, clock=clk)
    srv.submit(GenerationRequest(rid=0, prompt=(5, 6, 7),
                                 sampling=SamplingParams(max_new=4),
                                 deadline_s=100.0))
    done = srv.run()                # time never moves: deadline unreachable
    assert done[0].finish_reason == FINISH_LENGTH
    assert srv.timeouts == 0


# ------------------------------------- non-blocking retry backoff --

def _drive_logging(srv, clk, *, iter_time=0.01, preempt_rid=None,
                   preempt_at=3, max_steps=500):
    """Step the engine to drain, charging ``iter_time`` virtual seconds
    per iteration; returns [(virtual time, TokenDelta)] in emit order."""
    log = []
    steps = 0
    while True:
        before = srv.iterations
        busy = srv.step()
        if srv.iterations > before:
            clk.advance(iter_time)
        for d in srv.poll_deltas():
            log.append((clk.now(), d))
        if not busy:
            return log
        steps += 1
        if steps == preempt_at and preempt_rid is not None:
            srv.preempt(preempt_rid)
        assert steps < max_steps, "engine did not drain"


def test_backoff_defers_instead_of_blocking(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=2)
    ref = _engine(cfg, params)
    _submit_all(ref, prompts)
    want = {r.rid: r.tokens for r in ref.run()}

    backoff = 0.25
    clk = VirtualClock()
    inj = FaultInjector(rate=1.0, kinds=(FaultSpec("io", op="pop"),),
                        max_faults=1)
    srv = _engine(cfg, params, clock=clk, fault_injector=inj,
                  retry_backoff_s=backoff)
    _submit_all(srv, prompts)

    fault_t = resume_t = None
    log = []
    steps = 0
    while True:
        t0 = clk.now()
        n_retries, n_recovered = srv.fault_retries, srv.recovered_faults
        n_iters = srv.iterations
        busy = srv.step()
        if srv.iterations > n_iters:
            clk.advance(0.01)
        if fault_t is None and srv.fault_retries > n_retries:
            fault_t = t0           # defer stamped at this virtual time
        if resume_t is None and srv.recovered_faults > n_recovered:
            # the resume step may itself have idle-held the clock to the
            # backoff deadline, so sample time AFTER the step
            resume_t = clk.now()
        for d in srv.poll_deltas():
            log.append((clk.now(), d))
        if not busy:
            break
        steps += 1
        if steps == 3:
            srv.preempt(0)
        assert steps < 500, "engine did not drain"

    assert inj.injected == 1
    assert fault_t is not None and resume_t is not None
    assert srv.fault_retries == 1 and srv.recovered_faults == 1
    done = {r.rid: r for r in srv.finished}
    assert done[0].finish_reason == FINISH_LENGTH
    assert {rid: r.tokens for rid, r in done.items()} == want

    # the regression this guards: the engine loop must NOT sit in
    # time.sleep() while rid 0 backs off — rid 1 keeps emitting tokens
    # inside the backoff window, and rid 0 only resumes once the window
    # has elapsed on the engine clock
    assert resume_t >= fault_t + backoff
    other = [t for t, d in log
             if d.rid == 1 and d.tokens and fault_t < t < resume_t]
    assert other, "no other lane emitted tokens during the backoff window"


def test_backoff_zero_keeps_immediate_retry(cfg, params):
    # retry_backoff_s=0 is the historical in-place retry: the fault is
    # absorbed inside one step, no deferral, clock never consulted
    prompts = _prompts(cfg.vocab_size, n=2)
    inj = FaultInjector(rate=1.0, kinds=(FaultSpec("io", op="pop"),),
                        max_faults=1)
    srv = _engine(cfg, params, fault_injector=inj)
    _submit_all(srv, prompts)
    log = _drive_logging(srv, VirtualClock(), preempt_rid=0)
    assert srv.fault_retries == 1 and srv.recovered_faults == 1
    done = {r.rid: r for r in srv.finished}
    assert done[0].finish_reason == FINISH_LENGTH
    assert log, "no deltas streamed"


# ------------------------------------------------ shed-victim order --

def test_equal_priority_shed_victim_is_newest(cfg, params):
    srv = _engine(cfg, params, max_queue_depth=2)
    _submit_all(srv, _prompts(cfg.vocab_size, n=3))
    shed = [r for r in srv.finished if r.finish_reason == FINISH_SHED]
    # (priority, -arrival) ordering: on a tie the newcomer sheds itself
    assert [r.rid for r in shed] == [2]
    assert {r.rid for r in srv.queue} == {0, 1}


def test_high_priority_arrival_sheds_low_priority_waiter(cfg, params):
    srv = _engine(cfg, params, max_queue_depth=2)
    _submit_all(srv, _prompts(cfg.vocab_size, n=3),
                priority=lambda rid: 5 if rid == 2 else 0)
    shed = [r for r in srv.finished if r.finish_reason == FINISH_SHED]
    # the high-priority newcomer displaces the YOUNGEST low-priority
    # waiter, not the oldest (oldest has waited longest; shedding it
    # would make the queue a LIFO under pressure)
    assert [r.rid for r in shed] == [1]
    assert {r.rid for r in srv.queue} == {0, 2}


def test_low_priority_newcomer_sheds_itself(cfg, params):
    srv = _engine(cfg, params, max_queue_depth=2)
    _submit_all(srv, _prompts(cfg.vocab_size, n=3),
                priority=lambda rid: 0 if rid == 2 else 5)
    shed = [r for r in srv.finished if r.finish_reason == FINISH_SHED]
    assert [r.rid for r in shed] == [2]
    assert {r.rid for r in srv.queue} == {0, 1}


# -------------------------------------------------- the front door --

def _arrivals(prompts, *, gap=0.05, max_new=MAX_NEW):
    return [Arrival(t=i * gap,
                    request=GenerationRequest(
                        rid=i, prompt=tuple(p),
                        sampling=SamplingParams(max_new=max_new)))
            for i, p in enumerate(prompts)]


def test_frontdoor_serves_live_arrivals(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=4)
    srv = _engine(cfg, params, clock=VirtualClock(),
                  scheduler_policy=TokenBudgetPolicy(6))
    door = FrontDoor(srv, iter_time_s=0.01)
    records = door.serve(_arrivals(prompts))
    assert len(records) == 4
    for rid, rec in records.items():
        assert rec.finish_reason == FINISH_LENGTH
        assert rec.tokens == MAX_NEW
        # lifecycle is ordered on one clock axis: arrive <= submit <=
        # first token <= finish, and queueing counts toward TTFT
        assert rec.arrive_t <= rec.submit_t <= rec.first_token_t \
            <= rec.finish_t
        assert rec.ttft_s >= 0.0 and rec.tpot_s > 0.0
    # mid-loop admission really happened: later arrivals were submitted
    # at their due times, while earlier lanes were already streaming
    assert records[3].submit_t >= 3 * 0.05


def test_frontdoor_idle_gap_jumps_not_spins(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=2)
    arrivals = _arrivals(prompts, gap=50.0)   # huge gap between arrivals
    srv = _engine(cfg, params, clock=VirtualClock())
    door = FrontDoor(srv, iter_time_s=0.01)
    records = door.serve(arrivals, max_iters=200)
    # an engine that busy-waited through the gap would blow max_iters;
    # the front door holds the clock straight to the next arrival
    assert all(r.finish_reason == FINISH_LENGTH for r in records.values())
    assert records[1].submit_t >= 50.0


def test_frontdoor_rejects_duplicate_rid(cfg, params):
    srv = _engine(cfg, params, clock=VirtualClock())
    reqs = _arrivals(_prompts(cfg.vocab_size, n=1)) * 2
    with pytest.raises(ValueError):
        FrontDoor(srv).serve(reqs)


def test_frontdoor_replay_is_byte_identical(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=4)

    def once():
        srv = _engine(cfg, params, clock=VirtualClock(),
                      scheduler_policy=TokenBudgetPolicy(5))
        records = FrontDoor(srv, iter_time_s=0.01).serve(_arrivals(prompts))
        return latency_report(records, slo_ttft_s=0.25, slo_tpot_s=0.05)

    a, b = once(), once()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert a["slo_goodput"] == 1.0 and a["completed"] == 4


# -------------------------------------------------- latency report --

def _rec(rid, arrive, first, finish, tokens, reason=FINISH_LENGTH):
    from repro.runtime.frontdoor import RequestRecord
    return RequestRecord(rid=rid, arrive_t=arrive, submit_t=arrive,
                         first_token_t=first, finish_t=finish,
                         tokens=tokens, finish_reason=reason)


def test_latency_report_math():
    records = {
        0: _rec(0, 0.0, 0.1, 0.5, 5),       # ttft .1, tpot .1
        1: _rec(1, 0.0, 0.3, 0.3, 1),       # ttft .3, tpot 0 (one token)
        2: _rec(2, 0.0, None, None, 0, reason=FINISH_SHED),
    }
    rep = latency_report(records, slo_ttft_s=0.2, slo_tpot_s=0.15)
    assert rep["requests"] == 3 and rep["completed"] == 2
    # only rid 0 meets both SLOs; the shed request still counts in the
    # denominator — refused load is not neutral
    assert rep["slo_goodput"] == pytest.approx(1 / 3)
    assert rep["ttft_p50_s"] == pytest.approx(0.1)
    assert rep["ttft_p99_s"] == pytest.approx(0.3)
    assert rep["tpot_p99_s"] == pytest.approx(0.1)


def test_latency_report_empty():
    rep = latency_report({}, slo_ttft_s=1.0, slo_tpot_s=1.0)
    assert rep["requests"] == 0 and rep["slo_goodput"] == 0.0
    assert rep["ttft_p95_s"] == 0.0


# ------------------------------------------------- trace analysis --

def test_layer2_latency_stitches_lifecycle(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=3)
    srv = _engine(cfg, params)
    _submit_all(srv, prompts)
    done = srv.run()
    view = layer2_latency(layer1_decode(srv.tracer.drain()))
    assert view["arrived"] == 3 and view["finished"] == 3
    per = view["requests"]
    for rid in (0, 1, 2):
        r = per[rid]
        assert r["arrive_ts"] <= r["admit_ts"] <= r["finish_ts"]
        assert r["admissions"] >= 1
        assert r["queue_delay"] >= 0 and r["service"] > 0
        assert r["e2e"] == r["queue_delay"] + r["service"]
    assert per[0]["tokens"] == len(done[0].tokens)


def test_request_arrive_traced_with_queue_depth(cfg, params):
    srv = _engine(cfg, params)
    _submit_all(srv, _prompts(cfg.vocab_size, n=3))
    events = [e for e in layer1_decode(srv.tracer.drain())
              if e.etype == EventType.REQUEST_ARRIVE]
    assert [(e.a0, e.a1) for e in events] == [(0, 0), (1, 1), (2, 2)]
