"""Hierarchical prefix cache: device -> host -> disk spill with async
promotion, behind the redesigned ``CacheConfig``/``cache_stats()`` API.

Deterministic unit suite (no hypothesis) for the tiered backing
hierarchy:

* admission-time prefix hits on host- and disk-resident pages, with
  token parity against a device-only engine;
* asynchronous promotion on the engine clock — a ``VirtualClock`` run
  replays byte-identically, and the modeled promotion latency shows up
  as virtual time, never wall time;
* promotion racing preemption/termination (force-landing keeps the
  lane's pages consistent);
* faults during promotion: a transient planted I/O fault is retried and
  the hit still lands; a persistent fault drops the entry everywhere
  and the request re-plans (full prefill) with identical outputs;
* ``HostBackingStore.discard`` sweeping every tier (regression for the
  host-only discard bug);
* the ``CacheConfig`` grouping shim: flat ``EngineConfig`` spellings
  still work one release behind a ``DeprecationWarning``, and
  ``dataclasses.replace`` on an already-folded config does not re-warn;
* trace-level accounting: ``layer2_tier_residency`` and
  ``assert_tier_conservation`` over PAGE_DEMOTE/PAGE_PROMOTE events;
* ``DiskTier`` file lifecycle (owned temp dir removed on close, caller
  directories left in place).
"""
import dataclasses
import os
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.analysis import (
    assert_tier_conservation, layer1_decode, layer2_tier_residency,
)
from repro.core.offload import (
    BackingStoreError, DiskTier, HostBackingStore,
)
from repro.core.tracing import TraceBuffer
from repro.models import model as M
from repro.runtime import (
    CacheConfig, CacheStats, EngineConfig, FaultInjector, FaultSpec,
    GenerationRequest, PagedServer, SamplingParams, VirtualClock,
    make_engine,
)


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b").smoke()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _req(rid, prompt, max_new=3):
    return GenerationRequest(rid=rid, prompt=tuple(prompt),
                             sampling=SamplingParams(max_new=max_new))


def _tenant_prompts(tenants=6, reps=2):
    """Each tenant owns a 16-token (4 pages @ ps=4) system prompt; every
    visit appends a unique 2-token tail.  24 pages of prefix corpus vs
    the 12-page device pool used below."""
    systems = {t: [t * 7 + 1, t + 2, t + 3, t + 4] * 4
               for t in range(tenants)}
    prompts = []
    for rep in range(reps):
        for t in range(tenants):
            prompts.append(systems[t] + [90 + rep, 95 + rep])
    return prompts


def _cache(**kw):
    base = dict(num_pages=12, page_size=4, max_pages_per_seq=8)
    base.update(kw)
    return CacheConfig(**base)


def _serve(cfg, params, prompts, cache, *, clock=None, tracer=None,
           fault_injector=None, swap_retries=2, preempt_rid=None,
           cancel_rid=None):
    srv = make_engine(cfg, params, EngineConfig(
        cache=cache, max_lanes=2, chunk=8, use_kernel=False, clock=clock,
        fault_injector=fault_injector, swap_retries=swap_retries,
        retry_backoff_s=0.0), tracer=tracer)
    try:
        for rid, p in enumerate(prompts):
            srv.submit(_req(rid, p))
        if preempt_rid is not None or cancel_rid is not None:
            srv.step()                      # target reaches a lane
            if preempt_rid is not None:
                srv.preempt(preempt_rid)
            if cancel_rid is not None:
                srv.cancel(cancel_rid)
        done = srv.run()
        out = {r.rid: list(r.tokens) for r in done}
        stats = srv.cache_stats()
        srv.pool.check_invariants()
        for store in srv._cache_stores():
            store.check_invariants()
    finally:
        srv.close()
    return out, stats


# ------------------------------------------------------- tiered hits --

def test_prefix_hit_on_host_tier(cfg, params):
    prompts = _tenant_prompts()
    ref, st_dev = _serve(cfg, params, prompts, _cache())
    out, st = _serve(cfg, params, prompts,
                     _cache(host_tier_pages=64), clock=VirtualClock())
    assert out == ref, "host-tier restore changed tokens"
    assert st.hits_host_pages > 0
    assert st.demoted_pages > 0 and st.promoted_pages > 0
    assert st.bytes_demoted > 0 and st.bytes_promoted > 0
    # the tiers bought hits the device-only engine had to re-prefill
    assert st.miss_pages < st_dev.miss_pages


def test_prefix_hit_on_disk_tier(cfg, params):
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    out, st = _serve(
        cfg, params, prompts,
        _cache(host_tier_pages=4, disk_tier_pages=64, prefetch_depth=2),
        clock=VirtualClock())
    assert out == ref, "disk-tier restore changed tokens"
    assert st.hits_disk_pages > 0, "host tier too small, disk never hit"
    assert st.disk_pages > 0 or st.hits_disk_pages > 0


def test_virtual_clock_promotion_replays_identically(cfg, params):
    prompts = _tenant_prompts()
    cache = _cache(host_tier_pages=8, disk_tier_pages=64,
                   prefetch_depth=2, promote_latency_s=0.5)
    a_out, a_st = _serve(cfg, params, prompts, cache, clock=VirtualClock())
    b_out, b_st = _serve(cfg, params, prompts, cache, clock=VirtualClock())
    assert a_out == b_out
    assert a_st == b_st, "same-seed tiered runs diverged"
    assert a_st.promoted_pages > 0


def test_promotion_latency_is_virtual_time(cfg, params):
    """A large modeled promotion latency must cost virtual seconds, not
    wall seconds, and must not change tokens."""
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    clock = VirtualClock()
    out, st = _serve(cfg, params, prompts,
                     _cache(host_tier_pages=64, promote_latency_s=10.0),
                     clock=clock)
    assert out == ref
    assert st.promoted_pages > 0
    assert clock.now() >= 10.0, "promotion latency never bound the clock"


# ------------------------------------------- races with lane removal --

def test_promotion_races_preemption(cfg, params):
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    cache = _cache(host_tier_pages=64, promote_latency_s=1.0)
    # preempt a lane that may be mid-promotion: the engine force-lands
    # its in-flight pages before the D2H sweep, so outputs are unchanged
    out, st = _serve(cfg, params, prompts, cache, clock=VirtualClock(),
                     preempt_rid=0)
    assert out == ref, "preemption during promotion changed tokens"
    assert st.promoted_pages > 0


def test_promotion_races_cancellation(cfg, params):
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    cache = _cache(host_tier_pages=64, promote_latency_s=1.0)
    out, st = _serve(cfg, params, prompts, cache, clock=VirtualClock(),
                     cancel_rid=1)
    del ref[1]
    out.pop(1, None)                        # cancelled: tokens undefined
    assert out == ref, "cancel during promotion changed survivors"


# -------------------------------------------- faults during promotion --

def test_transient_fault_during_promotion_retries(cfg, params):
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    inj = FaultInjector(plan={0: FaultSpec("io", op="pop")})
    out, st = _serve(cfg, params, prompts, _cache(host_tier_pages=64),
                     clock=VirtualClock(), fault_injector=inj,
                     swap_retries=3)
    assert out == ref
    assert inj.injected >= 1, "planted fault never fired"
    assert st.hits_host_pages > 0, "retry did not recover the tier hit"


def test_persistent_fault_drops_entry_and_replans(cfg, params):
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    inj = FaultInjector(
        plan={0: FaultSpec("io", op="pop", persistent=True)})
    out, st = _serve(cfg, params, prompts, _cache(host_tier_pages=64),
                     clock=VirtualClock(), fault_injector=inj,
                     swap_retries=2)
    assert out == ref, "dropped tier entry must re-plan, not corrupt"
    # persistent faults are non-transient: the engine drops the entry on
    # first failure instead of burning retries on un-rottable state
    assert inj.injected >= 1, "planted fault never fired"
    assert st.dropped_entries >= 1


def test_fault_storm_on_fetch_path_keeps_parity(cfg, params):
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    inj = FaultInjector(seed=3, rate=1.0,
                        kinds=(FaultSpec("io", persistent=True),))
    out, st = _serve(cfg, params, prompts, _cache(host_tier_pages=64),
                     clock=VirtualClock(), fault_injector=inj,
                     swap_retries=2)
    assert out == ref, "all-faulting tier store must degrade to misses"
    assert st.hits_host_pages == 0 and st.hits_disk_pages == 0


# ----------------------------------------------- store-level contract --

def test_discard_sweeps_all_tiers():
    """Regression: ``discard(seq)`` used to sweep only the host tier —
    pages cascaded to disk leaked until close()."""
    store = HostBackingStore(host_pages=1, disk_tier=DiskTier(8))
    try:
        page = np.arange(8, dtype=np.float32).reshape(2, 4)
        for lpage in range(3):              # cascade pushes 2 to disk
            store.put(5, lpage, page + lpage)
        assert len(store) == 3
        resident = store.cache_resident()
        assert sum(resident.values()) == 0  # swap keys, not cache keys
        store.discard(5)
        assert len(store) == 0
        store.check_invariants()
        for lpage in range(3):
            with pytest.raises(BackingStoreError):
                store.pop(5, lpage)
    finally:
        store.close()


def test_cache_entry_survives_cascade_and_restores():
    store = HostBackingStore(host_pages=1, disk_tier=DiskTier(8))
    try:
        pages = [np.full((2, 4), i, dtype=np.float32) for i in range(3)]
        for i, p in enumerate(pages):
            store.park_cache(i, p)
        # host holds 1 page; the two oldest cascaded to disk
        assert store.cache_resident()["disk"] == 2
        arr, tier = store.fetch_cache(0, rid=7)
        assert tier == "disk"
        np.testing.assert_array_equal(arr, pages[0])
        arr, tier = store.fetch_cache(2, rid=7)
        assert tier == "host"
        store.check_invariants()
    finally:
        store.close()


def test_disk_tier_preserves_dtype():
    """Raw-byte files: ml_dtypes payloads (bfloat16) must round-trip
    exactly — ``np.save`` would degrade them to void16."""
    import ml_dtypes
    tier = DiskTier(4)
    try:
        arr = np.arange(8, dtype=np.float32).astype(ml_dtypes.bfloat16)
        tier.store(("cache", 1), arr)
        back = tier.load(("cache", 1))
        assert back.dtype == arr.dtype
        np.testing.assert_array_equal(
            back.astype(np.float32), arr.astype(np.float32))
    finally:
        tier.close()


def test_disk_tier_owned_dir_removed_on_close(tmp_path):
    tier = DiskTier(4)
    tier.store(("cache", 1), np.zeros(4, dtype=np.float32))
    owned = tier._ensure_dir()
    assert os.path.isdir(owned)
    tier.close()
    assert not os.path.exists(owned)

    kept = tmp_path / "disk"
    kept.mkdir()
    tier = DiskTier(4, str(kept))
    tier.store(("cache", 2), np.zeros(4, dtype=np.float32))
    assert len(list(kept.iterdir())) == 1
    tier.close()
    assert kept.is_dir(), "caller-provided directory must be left alone"
    assert len(list(kept.iterdir())) == 0, "parked files must be removed"


# --------------------------------------------------- CacheConfig shim --

def test_flat_cache_knobs_warn_and_fold():
    with pytest.warns(DeprecationWarning):
        e = EngineConfig(num_pages=48, page_size=8, max_lanes=2)
    assert e.cache.num_pages == 48 and e.cache.page_size == 8
    assert e.num_pages == 48                # mirrored back for readers

    with pytest.warns(DeprecationWarning):
        e = EngineConfig(enable_prefix_cache=False)
    assert e.cache.enable_prefix_cache is False


def test_replace_on_folded_config_does_not_rewarn():
    with pytest.warns(DeprecationWarning):
        e = EngineConfig(num_pages=48)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        e2 = dataclasses.replace(e, max_lanes=4)
    assert e2.cache.num_pages == 48 and e2.max_lanes == 4


def test_grouped_spelling_never_warns():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        e = EngineConfig(cache=CacheConfig(num_pages=48, page_size=8))
    assert e.cache.num_pages == 48


def test_cache_config_validation():
    with pytest.raises(ValueError):
        CacheConfig(disk_tier_pages=8)      # disk requires a host tier
    with pytest.raises(ValueError):
        CacheConfig(prefetch_depth=0)
    with pytest.raises(ValueError):
        CacheConfig(promote_latency_s=-1.0)
    assert CacheConfig(host_tier_pages=8).spill_enabled
    assert not CacheConfig().spill_enabled


# ----------------------------------------------------- cache_stats() --

def test_cache_stats_shape_and_sanity(cfg, params):
    prompts = _tenant_prompts(tenants=3, reps=2)
    srv = make_engine(cfg, params, EngineConfig(
        cache=_cache(host_tier_pages=16), max_lanes=2, chunk=8,
        use_kernel=False, clock=VirtualClock()))
    try:
        st0 = srv.cache_stats()
        assert isinstance(st0, CacheStats)
        assert st0.device_pages == 12
        assert st0.host_pages == 0          # residency, not capacity
        assert st0.promotions_in_flight == 0
        with pytest.raises(dataclasses.FrozenInstanceError):
            st0.device_pages = 1
        for rid, p in enumerate(prompts):
            srv.submit(_req(rid, p))
        srv.run()
        st = srv.cache_stats()
        assert st.prefix_hit_tokens > 0
        assert st.hits_device_pages + st.hits_host_pages + \
            st.hits_disk_pages + st.miss_pages > 0
        # indexed pages park on the cached-free list while staying in the
        # prefix index, so the two overlap — each is bounded by the pool
        assert st.device_indexed <= st.device_pages
        assert st.device_cached_free <= st.device_pages
        assert st.promotions_in_flight == 0    # all landed by drain
    finally:
        srv.close()


def test_sharded_engine_tiers_per_cluster(cfg, params, tmp_path):
    prompts = _tenant_prompts()
    ref, _ = _serve(cfg, params, prompts, _cache())
    srv = make_engine(cfg, params, EngineConfig(
        cache=_cache(host_tier_pages=16, disk_tier_pages=32,
                     disk_dir=str(tmp_path / "spill")),
        max_lanes=1, chunk=8, use_kernel=False, clock=VirtualClock(),
        sharded=True, clusters=1, heads=1))
    try:
        for rid, p in enumerate(prompts):
            srv.submit(_req(rid, p))
        done = srv.run()
        out = {r.rid: list(r.tokens) for r in done}
        st = srv.cache_stats()
        assert out == ref
        assert st.hits_host_pages + st.hits_disk_pages > 0
        assert (tmp_path / "spill" / "cluster0").exists() or \
            st.hits_disk_pages == 0
    finally:
        srv.close()


# ----------------------------------------------------------- tracing --

def test_tier_moves_traced_and_conserved(cfg, params):
    prompts = _tenant_prompts()
    tracer = TraceBuffer(capacity=1 << 14)
    _serve(cfg, params, prompts,
           _cache(host_tier_pages=8, disk_tier_pages=64, prefetch_depth=2),
           clock=VirtualClock(), tracer=tracer)
    events = layer1_decode(tracer.drain())
    rep = layer2_tier_residency(events)
    assert rep["moves"].get("device->host", 0) > 0, "no demotions traced"
    assert sum(n for m, n in rep["moves"].items()
               if m.endswith("->device")) > 0, "no promotions traced"
    assert assert_tier_conservation(events), \
        "a tier move contradicted the entry's tracked residency"


def test_tier_conservation_rejects_teleports():
    from repro.core.tracing import EventType, HOST_TRACER_ID

    class E:                                # minimal decoded-event stand-in
        def __init__(self, etype, a0, a1):
            self.ts, self.tracer = 0, HOST_TRACER_ID
            self.etype, self.a0, self.a1 = etype, a0, a1

    demote = EventType.PAGE_DEMOTE
    promote = EventType.PAGE_PROMOTE
    ok = [E(demote, 1, 0 * 4 + 1), E(demote, 1, 1 * 4 + 2),
          E(promote, 1, 2 * 4 + 0)]
    assert assert_tier_conservation(ok)
    # entry 1 never reached disk, so a disk->device promote is a lie
    bad = [E(demote, 1, 0 * 4 + 1), E(promote, 1, 2 * 4 + 0)]
    assert not assert_tier_conservation(bad)
