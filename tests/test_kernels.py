"""Kernel sweeps: shapes x dtypes, interpret-mode vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cluster_matmul import cluster_matmul, cluster_matmul_ref
from repro.kernels.flash_attention import (
    flash_attention, flash_attention_ref, mha_flash,
)
from repro.kernels.paged_attention import (
    paged_attention, paged_attention_ref, paged_prefill, paged_prefill_ref,
    paged_prefill_fused, pad_block_table, page_counts_for,
)

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 512),
                                   (384, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cluster_matmul(m, k, n, dtype, rng):
    a = jax.random.normal(rng, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(rng, 1), (k, n),
                          jnp.float32).astype(dtype)
    out = cluster_matmul(a, b, interpret=True)
    ref = cluster_matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("bhsd", [(2, 128, 128, 64), (4, 256, 128, 32),
                                  (1, 128, 384, 128)])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (False, 0, 0.0), (True, 64, 0.0), (True, 0, 50.0),
    (True, 32, 30.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(bhsd, causal, window, cap, dtype, rng):
    BH, S, T, d = bhsd
    q = (jax.random.normal(rng, (BH, S, d), jnp.float32) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(rng, 1), (BH, T, d),
                           jnp.float32) * 0.3).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (BH, T, d),
                          jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal, window, cap, True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_attention_grad(rng):
    q = jax.random.normal(rng, (2, 128, 32), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 128, 32),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 128, 32),
                          jnp.float32)
    g = jax.grad(lambda q_: flash_attention(q_, k, v, True, 0, 0.0,
                                            True).sum())(q)
    gr = jax.grad(lambda q_: flash_attention_ref(q_, k, v,
                                                 causal=True).sum())(q)
    np.testing.assert_allclose(g, gr, rtol=2e-3, atol=2e-3)


def test_mha_flash_gqa_grad(rng):
    """The groups>1 backward (repeat-based VJP over the unexpanded KV
    layout) must sum per-group grads back onto the shared KV heads."""
    B, S, H, Kv, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(rng, (B, S, H, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, Kv, hd),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, Kv, hd),
                          jnp.float32)
    from repro.models.attention import attend_fullseq
    pos = jnp.arange(S, dtype=jnp.int32)

    def loss_kernel(q_, k_, v_):
        return (mha_flash(q_, k_, v_, interpret=True) ** 2).sum()

    def loss_ref(q_, k_, v_):
        out = attend_fullseq(q_, k_, v_, q_positions=pos, k_positions=pos,
                             causal=True)
        return (out ** 2).sum()

    g = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_mha_flash_gqa(rng):
    B, S, H, Kv, hd = 2, 128, 8, 2, 32
    q = jax.random.normal(rng, (B, S, H, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, Kv, hd),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, Kv, hd),
                          jnp.float32)
    out = mha_flash(q, k, v, interpret=True)
    from repro.models.attention import attend_fullseq
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = attend_fullseq(q, k, v, q_positions=pos, k_positions=pos,
                         causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B,H,Kv,hd,page,npg,P", [
    (3, 8, 4, 32, 8, 6, 16),
    (2, 4, 4, 64, 16, 4, 8),
    (1, 16, 2, 128, 8, 8, 12),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(B, H, Kv, hd, page, npg, P, dtype, rng):
    q = (jax.random.normal(rng, (B, H, hd), jnp.float32) * 0.3).astype(dtype)
    kp = (jax.random.normal(jax.random.fold_in(rng, 1), (P, page, Kv, hd),
                            jnp.float32) * 0.3).astype(dtype)
    vp = jax.random.normal(jax.random.fold_in(rng, 2), (P, page, Kv, hd),
                           jnp.float32).astype(dtype)
    lengths = np.minimum(
        np.asarray(jax.random.randint(jax.random.fold_in(rng, 3), (B,), 1,
                                      npg * page)), npg * page).astype(np.int32)
    bt = np.full((B, npg), -1, np.int32)
    nxt = 0
    for i, ln in enumerate(lengths):
        for j in range(-(-int(ln) // page)):
            bt[i, j] = nxt % P
            nxt += 1
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
                          interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# chunked prefill + multi-page decode parity
# ---------------------------------------------------------------------------

def _paged_pool(rng, B, Kv, hd, page, npg, P, lengths):
    """Random pool + a block table mapping ceil(len/page) pages per lane."""
    kp = jax.random.normal(jax.random.fold_in(rng, 1), (P, page, Kv, hd),
                           jnp.float32) * 0.3
    vp = jax.random.normal(jax.random.fold_in(rng, 2), (P, page, Kv, hd),
                           jnp.float32)
    bt = np.full((B, npg), -1, np.int32)
    nxt = 0
    for i, ln in enumerate(lengths):
        for j in range(-(-int(ln) // page)):
            bt[i, j] = nxt % P
            nxt += 1
    return kp, vp, jnp.asarray(bt)


@pytest.mark.parametrize("pages_per_step", [1, 2, 3])
def test_paged_decode_multi_page_grid(pages_per_step, rng):
    """Multi-page decode grid is numerically identical to the oracle for
    every pages-per-step grouping (incl. groups that don't divide npg)."""
    B, H, Kv, hd, page, npg, P = 3, 8, 4, 32, 8, 7, 24
    q = jax.random.normal(rng, (B, H, hd), jnp.float32) * 0.3
    lengths = np.array([1, 29, 56], np.int32)
    kp, vp, bt = _paged_pool(rng, B, Kv, hd, page, npg, P, lengths)
    out = paged_attention(q, kp, vp, bt, jnp.asarray(lengths),
                          interpret=True, pages_per_step=pages_per_step)
    ref = paged_attention_ref(q, kp, vp, bt, jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("C,H,Kv,hd,page,npg,P", [
    (8, 8, 4, 32, 4, 10, 24),      # G=2
    (16, 8, 2, 16, 8, 6, 32),      # G=4
    (5, 6, 6, 32, 16, 3, 8),       # G=1, chunk not a divisor of anything
])
@pytest.mark.parametrize("pages_per_step", [1, 2])
def test_paged_prefill_matches_ref(C, H, Kv, hd, page, npg, P,
                                   pages_per_step, rng):
    """Chunked prefill vs the dense oracle across random prompt lengths,
    page sizes and GQA group counts."""
    B = 3
    cap = npg * page - C
    start = np.asarray(jax.random.randint(jax.random.fold_in(rng, 5), (B,),
                                          0, max(cap, 1))).astype(np.int32)
    lengths = (start + C).astype(np.int32)
    q = jax.random.normal(rng, (B, C, H, hd), jnp.float32) * 0.3
    kp, vp, bt = _paged_pool(rng, B, Kv, hd, page, npg, P, lengths)
    out = paged_prefill(q, kp, vp, bt, jnp.asarray(lengths),
                        jnp.asarray(start), interpret=True,
                        pages_per_step=pages_per_step)
    ref = paged_prefill_ref(q, kp, vp, bt, jnp.asarray(lengths),
                            jnp.asarray(start))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("pages_per_step", [1, 2])
def test_paged_prefill_fused_aliased_pages(pages_per_step, rng):
    """Shared-prefix block tables alias the same physical pages across
    lanes (prefix-cache hits); the fused kernel must match the oracle when
    reads of one physical page serve several lanes at different logical
    positions."""
    B, C, H, Kv, hd, page, npg, P = 3, 4, 4, 2, 16, 4, 4, 10
    # lanes share physical pages 0 and 1 for their first two logical pages
    # (a 8-token shared prefix), then diverge into private tails
    lengths = np.array([12, 11, 10], np.int32)
    start = (lengths - C).astype(np.int32)
    bt = np.full((B, npg), -1, np.int32)
    bt[0, :3] = [0, 1, 2]
    bt[1, :3] = [0, 1, 3]
    bt[2, :3] = [0, 1, 4]
    q = jax.random.normal(rng, (B, C, H, hd), jnp.float32) * 0.3
    kp = jax.random.normal(jax.random.fold_in(rng, 1), (P, page, Kv, hd),
                           jnp.float32) * 0.3
    vp = jax.random.normal(jax.random.fold_in(rng, 2), (P, page, Kv, hd),
                           jnp.float32)
    counts = page_counts_for(jnp.asarray(lengths), page)
    out = paged_prefill_fused(
        q, jnp.stack([kp, vp], axis=1),
        pad_block_table(jnp.asarray(bt), counts), counts,
        jnp.asarray(lengths), jnp.asarray(start), interpret=True,
        pages_per_step=pages_per_step)
    ref = paged_prefill_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
                            jnp.asarray(start))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_paged_prefill_matches_token_by_token(rng):
    """A whole chunk through the prefill kernel equals feeding the same
    positions one at a time through the decode kernel (the pre-chunked
    engine's path)."""
    B, C, H, Kv, hd, page, npg, P = 2, 8, 4, 2, 16, 4, 6, 16
    start = np.array([0, 5], np.int32)
    lengths = (start + C).astype(np.int32)
    q = jax.random.normal(rng, (B, C, H, hd), jnp.float32) * 0.3
    kp, vp, bt = _paged_pool(rng, B, Kv, hd, page, npg, P, lengths)
    chunked = np.asarray(paged_prefill(q, kp, vp, bt, jnp.asarray(lengths),
                                       jnp.asarray(start), interpret=True))
    for c in range(C):
        step_len = jnp.asarray((start + c + 1).astype(np.int32))
        one = paged_attention(q[:, c], kp, vp, bt, step_len, interpret=True)
        np.testing.assert_allclose(chunked[:, c], np.asarray(one),
                                   rtol=1e-4, atol=1e-4)
