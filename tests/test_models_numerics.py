"""Numerical invariants of the model zoo: chunked-vs-reference mLSTM,
decode-vs-fullseq consistency per arch family, MoE routing properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models import ssm as S
from repro.models import moe as MOE


def test_mlstm_chunkwise_matches_recurrent(rng):
    cfg = get_config("xlstm-350m").smoke()
    p = S.build_mlstm(__import__("repro.parallel.sharding",
                                 fromlist=["ParamFactory"]).ParamFactory(
        "init", jnp.float32, rng), cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 9), (2, 64, cfg.d_model),
                          jnp.float32) * 0.5
    out_chunk = S.mlstm_fullseq(cfg, p, x, chunk=16)
    out_ref = S.mlstm_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_ref),
                               rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_fullseq(rng):
    cfg = get_config("hymba-1.5b").smoke()
    from repro.parallel.sharding import ParamFactory
    p = S.build_mamba(ParamFactory("init", jnp.float32, rng), cfg)
    B, T = 2, 12
    x = jax.random.normal(jax.random.fold_in(rng, 3), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    full = S.mamba_fullseq(cfg, p, x)
    state = {k: jnp.zeros(s, dt) for k, (s, dt, _)
             in S.mamba_state_specs(cfg, B).items()}
    outs = []
    for t in range(T):
        o, state = S.mamba_decode(cfg, p, x[:, t:t + 1], state)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-3, atol=2e-3)


def test_slstm_decode_matches_fullseq(rng):
    cfg = get_config("xlstm-350m").smoke()
    from repro.parallel.sharding import ParamFactory
    p = S.build_slstm(ParamFactory("init", jnp.float32, rng), cfg)
    B, T = 2, 10
    x = jax.random.normal(jax.random.fold_in(rng, 4), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    full = S.slstm_fullseq(cfg, p, x)
    state = {k: jnp.zeros(s, dt) for k, (s, dt, _)
             in S.slstm_state_specs(cfg, B).items()}
    outs = []
    for t in range(T):
        o, state = S.slstm_decode(cfg, p, x[:, t:t + 1], state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# decode-vs-prefill consistency (the cache correctness test), all families
# ---------------------------------------------------------------------------

DECODE_ARCHS = ["yi-6b", "qwen3-32b", "gemma2-2b", "olmoe-1b-7b",
                "deepseek-v2-236b", "xlstm-350m", "hymba-1.5b",
                "whisper-medium", "pixtral-12b", "minitron-8b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_fullseq_logits(arch, rng):
    cfg = get_config(arch).smoke()
    # hymba SWA ring needs window >= T for exact equivalence at this length
    T = 12
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=max(cfg.sliding_window,
                                                          T))
    if cfg.moe_num_experts:
        # joint-prefill routing must not drop tokens for exact equivalence
        # with the (dropless) decode path
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = M.init_params(cfg, rng)
    B = 2
    tokens = jax.random.randint(jax.random.fold_in(rng, 7), (B, T), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    frontend = None
    if cfg.frontend:
        frontend = (jax.random.normal(
            jax.random.fold_in(rng, 8), (B, cfg.frontend_seq, cfg.d_model),
            jnp.float32) * 0.1).astype(jnp.bfloat16)

    hidden = M.forward_fullseq(cfg, params, tokens, frontend=frontend)
    from repro.models.layers import logits_from_hidden
    want = logits_from_hidden(cfg, params["embed"], hidden[:, -1:, :])

    cache = M.init_cache(cfg, B, T)
    if cfg.block_kind == "encdec":
        xk, xv = M.encdec_cross_cache(cfg, params, frontend)
        cache["xk"], cache["xv"] = xk, xv
    got = None
    for t in range(T):
        pos = jnp.full((B,), t, jnp.int32)
        embeds = None
        if cfg.frontend == "patch" and t < cfg.frontend_seq:
            # fullseq replaces the first Fs positions with patch embeddings;
            # the decode path consumes them as inputs_embeds
            embeds = frontend[:, t:t + 1]
        got, cache = M.decode_forward(cfg, params, cache, tokens[:, t:t + 1],
                                      pos, inputs_embeds=embeds)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_matches_dense_loop(rng):
    """Capacity-based dispatch == per-token dense loop when capacity ample."""
    cfg = dataclasses.replace(get_config("olmoe-1b-7b").smoke(),
                              moe_capacity_factor=8.0)
    from repro.parallel.sharding import ParamFactory
    p = MOE.build_moe(ParamFactory("init", jnp.float32, rng), cfg)
    B, T = 2, 8
    x = jax.random.normal(jax.random.fold_in(rng, 5), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    got = MOE.moe_forward(cfg, p, x)

    # reference: explicit per-token top-k loop
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    k = cfg.moe_top_k
    vals, idx = jax.lax.top_k(logits, k)
    probs = jax.nn.softmax(vals, -1)
    ref = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(k):
            e = int(idx[t, j])
            h = np.asarray(jax.nn.silu(xf[t] @ p["w_gate"][e]) *
                           (xf[t] @ p["w_up"][e]))
            ref[t] += float(probs[t, j]) * (h @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model)),
                               ref, rtol=3e-3, atol=3e-3)


def test_moe_capacity_drops_overflow(rng):
    cfg = dataclasses.replace(get_config("olmoe-1b-7b").smoke(),
                              moe_capacity_factor=0.05)
    from repro.parallel.sharding import ParamFactory
    p = MOE.build_moe(ParamFactory("init", jnp.float32, rng), cfg)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.float32)
    out = MOE.moe_forward(cfg, p, x)
    assert bool(jnp.isfinite(out).all())
    # with tiny capacity, most tokens are dropped -> smaller magnitude
    big = MOE.moe_forward(dataclasses.replace(cfg, moe_capacity_factor=8.0),
                          p, x)
    assert float(jnp.abs(out).mean()) <= float(jnp.abs(big).mean()) + 1e-6


def test_router_load_counts(rng):
    cfg = get_config("olmoe-1b-7b").smoke()
    from repro.parallel.sharding import ParamFactory
    p = MOE.build_moe(ParamFactory("init", jnp.float32, rng), cfg)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.float32)
    load = MOE.router_load(cfg, p, x)
    assert int(load.sum()) == 2 * 16 * cfg.moe_top_k
