"""Capacity planner: the WorkloadSpec schema shared with the load
generator, the discrete-event simulator's accuracy against the
committed bench artifact, trace-driven calibration, and the
``plan_capacity`` inversion — determinism, SLO feasibility of the
recommendation, and the monotonicity properties (a tighter SLO is never
cheaper; a higher arrival rate never shrinks the recommended pool) in
the scripted-random style of ``test_pool_properties.py`` (seeded
``default_rng`` schedules, no hypothesis dependency).
"""
import dataclasses
import inspect
import json
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.analysis import layer1_decode, layer2_calibration
from repro.core.tracing import TraceBuffer
from repro.models import model as M
from repro.planner import (
    AnalyticCostModel, Calibration, FixedIterationCost, IterationStats,
    SLOSpec, WorkloadSpec, candidate_grid, config_cost, plan_capacity,
    simulate,
)
from repro.runtime import (
    Arrival, CacheConfig, EngineConfig, FrontDoor, GenerationRequest,
    SamplingParams, TokenBudgetPolicy, VirtualClock, make_engine,
)

ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def model_cfg():
    return get_config("yi-6b").smoke()


@pytest.fixture(scope="module")
def bench():
    with open(ROOT / "BENCH_serve.json") as f:
        return json.load(f)


def _spec(**over):
    base = dict(rate_rps=50.0, requests=8, prompt_min=4, prompt_max=10,
                output_min=2, output_max=4, seed=0)
    base.update(over)
    return WorkloadSpec(**base)


def _engine_for(arrivals, *, page_size=4, max_lanes=2, chunk=4,
                token_budget=None, clusters=1, kv_dtype="bf16",
                spec_k=0):
    longest = max(len(a.prompt) + a.max_new for a in arrivals)
    per_seq = -(-longest // page_size) + 1
    policy = TokenBudgetPolicy(token_budget) if token_budget else None
    return EngineConfig(
        cache=CacheConfig(num_pages=per_seq * max_lanes + 8,
                          page_size=page_size,
                          max_pages_per_seq=per_seq, kv_dtype=kv_dtype),
        max_lanes=max_lanes, chunk=chunk, clusters=clusters,
        spec_k=spec_k, use_kernel=False, scheduler_policy=policy)


# ===========================================================================
# WorkloadSpec schema
# ===========================================================================

def test_sample_arrivals_deterministic():
    a = _spec().sample_arrivals(256)
    b = _spec().sample_arrivals(256)
    assert a == b


def test_sample_arrivals_shape():
    arr = _spec(requests=16).sample_arrivals(256)
    assert [r.rid for r in arr] == list(range(16))
    assert all(arr[i].t <= arr[i + 1].t for i in range(15))
    for r in arr:
        assert 4 <= len(r.prompt) <= 10
        assert 2 <= r.max_new <= 4
        assert all(1 <= tok < 256 for tok in r.prompt)


def test_json_round_trip():
    spec = _spec(prefix_share_ratio=0.5, spec_acceptance_rate=0.7,
                 seed=9)
    back = WorkloadSpec.from_json(json.loads(json.dumps(spec.to_json())))
    assert back == spec
    assert back.sample_arrivals(64) == spec.sample_arrivals(64)


def test_from_json_rejects_unknown_fields():
    d = _spec().to_json()
    d["surprise"] = 1
    with pytest.raises(ValueError, match="unknown WorkloadSpec"):
        WorkloadSpec.from_json(d)


@pytest.mark.parametrize("over", [
    {"rate_rps": 0.0}, {"requests": 0}, {"prompt_min": 0},
    {"prompt_min": 12, "prompt_max": 4}, {"output_min": 0},
    {"prefix_share_ratio": 1.5}, {"spec_acceptance_rate": -0.1},
])
def test_validation_rejects(over):
    with pytest.raises(ValueError):
        _spec(**over)


def test_prefix_share_prompts_share_head():
    arr = _spec(prefix_share_ratio=1.0, requests=6).sample_arrivals(256)
    head = arr[0].prompt[:4]                  # prompt_min-token block
    assert all(r.prompt[:min(4, len(r.prompt))] ==
               head[:min(4, len(r.prompt))] for r in arr)
    # the zero-ratio stream is a different (historical) draw order
    plain = _spec(requests=6).sample_arrivals(256)
    assert [r.prompt for r in plain] != [r.prompt for r in arr]


# ===========================================================================
# simulator vs the committed bench artifact
# ===========================================================================

def test_simulator_replays_committed_latency_bench(bench, model_cfg):
    lat = bench["latency"]
    wl = lat["workload"]
    spec = WorkloadSpec(
        rate_rps=wl["rate_rps"], requests=wl["requests"],
        prompt_min=wl["prompt_len"][0], prompt_max=wl["prompt_len"][1],
        output_min=wl["output_len"][0], output_max=wl["output_len"][1],
        seed=wl["seed"])
    arrivals = spec.sample_arrivals(model_cfg.vocab_size)
    engine = _engine_for(arrivals, page_size=wl["page_size"],
                         max_lanes=wl["max_lanes"], chunk=wl["chunk"],
                         token_budget=wl["token_budget"])
    rep = simulate(arrivals, engine,
                   iteration_cost=FixedIterationCost(wl["iter_time_s"]),
                   slo_ttft_s=lat["slo"]["ttft_s"],
                   slo_tpot_s=lat["slo"]["tpot_s"])
    # the simulator reproduces the measured engine run EXACTLY — same
    # iteration count, same virtual clock, same latency percentiles
    assert rep["iterations"] == lat["iterations"]
    assert rep["virtual_duration_s"] == lat["virtual_duration_s"]
    assert rep["completed"] == lat["completed"]
    for k in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s", "tpot_p50_s",
              "tpot_p95_s", "tpot_p99_s", "slo_goodput"):
        assert rep[k] == pytest.approx(lat[k], rel=1e-9), k


def test_simulator_replays_committed_spec_off_bench(bench, model_cfg):
    sp = bench["speculation"]
    wl = sp["workload"]
    # token values do not change iteration structure for distinct
    # same-length prompts, so any seeded prompts replay the bench
    rng = np.random.default_rng(0)
    arrivals = []
    from repro.planner import SampledRequest
    for rid in range(wl["requests"]):
        prompt = tuple(int(x) for x in rng.integers(
            1, model_cfg.vocab_size, size=wl["prompt_len"]))
        arrivals.append(SampledRequest(rid=rid, t=0.0, prompt=prompt,
                                       max_new=wl["max_new"]))
    engine = _engine_for(arrivals, max_lanes=wl["requests"], chunk=8)
    rep = simulate(arrivals, engine,
                   iteration_cost=FixedIterationCost(0.01))
    off = sp["spec_off"]
    assert rep["iterations"] == off["iterations"]
    assert rep["generated_tokens"] == off["generated_tokens"]


# ===========================================================================
# simulator invariants (engine-free)
# ===========================================================================

def test_simulate_conserves_tokens():
    spec = _spec(requests=6, seed=3)
    arrivals = spec.sample_arrivals(256)
    engine = _engine_for(arrivals, token_budget=6)
    rep = simulate(arrivals, engine,
                   iteration_cost=FixedIterationCost(0.01))
    assert rep["completed"] == 6
    assert rep["generated_tokens"] == sum(r.max_new for r in arrivals)
    assert rep["prefill_tokens"] + rep["prefix_hit_tokens"] == \
        sum(len(r.prompt) for r in arrivals)
    assert all(p <= engine.cache.num_pages
               for p in rep["peak_pages_per_cluster"])


def test_simulate_deterministic():
    spec = _spec(requests=6, seed=5)
    arrivals = spec.sample_arrivals(256)
    engine = _engine_for(arrivals, clusters=2)
    kw = dict(iteration_cost=FixedIterationCost(0.01))
    assert simulate(arrivals, engine, **kw) == \
        simulate(arrivals, engine, **kw)


def test_simulate_speculation_reduces_iterations():
    from repro.planner import SampledRequest
    rng = np.random.default_rng(0)
    arrivals = [SampledRequest(
        rid=i, t=0.0,
        prompt=tuple(int(x) for x in rng.integers(1, 256, size=6)),
        max_new=12) for i in range(2)]
    plain = _engine_for(arrivals, chunk=8)
    spec = _engine_for(arrivals, chunk=8, spec_k=4)
    rep0 = simulate(arrivals, plain,
                    iteration_cost=FixedIterationCost(0.01))
    rep1 = simulate(arrivals, spec,
                    iteration_cost=FixedIterationCost(0.01),
                    spec_acceptance=0.8)
    assert rep1["iterations"] < rep0["iterations"]
    assert rep1["spec_accepted"] > 0
    assert rep1["generated_tokens"] == rep0["generated_tokens"]


# ===========================================================================
# cost models
# ===========================================================================

def _st(p=0, d=0, s=0, ctx=0, c=1):
    return IterationStats(prefill_tokens=p, decode_lanes=d,
                          spec_tokens=s, context_tokens=ctx,
                          active_clusters=c)


def test_fixed_cost_is_constant():
    cost = FixedIterationCost(0.01)
    assert cost(_st()) == cost(_st(p=999, ctx=10_000)) == 0.01


def test_analytic_cost_monotone_in_work(model_cfg):
    engine = _engine_for([type("A", (), {"prompt": (1,) * 8,
                                         "max_new": 4})()])
    cost = AnalyticCostModel.for_engine(model_cfg, engine)
    assert 0 < cost(_st(d=1)) <= cost(_st(p=64, d=1)) \
        <= cost(_st(p=64, d=1, ctx=10_000))


def test_analytic_cost_int8_kv_cheaper_on_memory_bound(model_cfg):
    arr = [type("A", (), {"prompt": (1,) * 8, "max_new": 4})()]
    bf16 = AnalyticCostModel.for_engine(model_cfg,
                                        _engine_for(arr, kv_dtype="bf16"))
    int8 = AnalyticCostModel.for_engine(model_cfg,
                                        _engine_for(arr, kv_dtype="int8"))
    big_ctx = _st(d=2, ctx=10_000_000)        # deep in the memory regime
    assert int8(big_ctx) < bf16(big_ctx)
    assert int8.kv_bytes_token == 136.0 and bf16.kv_bytes_token == 256.0


def test_calibration_rejects_negative_quantum():
    with pytest.raises(ValueError):
        Calibration(iter_time_s=-1.0)
    assert Calibration(iter_time_s=0.01).cost()(_st()) == 0.01


# ===========================================================================
# calibration from a recorded trace (real engine, virtual clock)
# ===========================================================================

def test_calibration_from_recorded_trace(model_cfg):
    params = M.init_params(model_cfg, jax.random.PRNGKey(0))
    tracer = TraceBuffer(capacity=1 << 14)
    srv = make_engine(model_cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=4, max_pages_per_seq=8),
        max_lanes=2, chunk=4, use_kernel=False, clock=VirtualClock(),
        scheduler_policy=TokenBudgetPolicy(6)), tracer=tracer)
    spec = _spec(requests=4, seed=1)
    arrivals = [Arrival(t=r.t, request=GenerationRequest(
                    rid=r.rid, prompt=list(r.prompt),
                    sampling=SamplingParams(max_new=r.max_new)))
                for r in spec.sample_arrivals(model_cfg.vocab_size)]
    FrontDoor(srv, iter_time_s=0.01).serve(arrivals)
    events = layer1_decode(srv.tracer.drain())
    cal = layer2_calibration(events, iter_time_s=0.01)
    # D2H ticks count engine iterations exactly
    assert cal["iterations"] == srv.iterations
    assert cal["arrived"] == cal["finished"] == 4
    for row in cal["requests"].values():
        assert row["service_iters"] >= 1
        assert row["queue_delay_iters"] >= 0
    assert cal["mean_service_s"] == \
        pytest.approx(cal["mean_service_iters"] * 0.01)
    assert cal["duration_s"] == pytest.approx(srv.iterations * 0.01)
    c = Calibration.from_trace(events, iter_time_s=0.01)
    assert c.mean_service_iters == cal["mean_service_iters"]
    assert c.mean_queue_delay_iters == cal["mean_queue_delay_iters"]
    assert c.cost()(_st()) == 0.01


# ===========================================================================
# plan_capacity: determinism + feasibility
# ===========================================================================

def test_candidate_grid_is_deterministic_and_sized():
    spec = _spec()
    a = candidate_grid(spec, max_clusters=4)
    b = candidate_grid(spec, max_clusters=4)
    assert a == b
    longest = spec.prompt_max + spec.output_max
    for e in a:
        assert e.cache.max_pages_per_seq * e.cache.page_size >= longest
        assert e.spec_k == 0                  # no acceptance -> no spec
    assert any(e.spec_k == 4 for e in
               candidate_grid(_spec(spec_acceptance_rate=0.7)))


def test_plan_capacity_deterministic_and_meets_slo(model_cfg):
    spec = _spec(requests=12, rate_rps=60.0)
    slo = SLOSpec(ttft_p95_s=0.15, tpot_p95_s=0.03)
    kw = dict(model_cfg=model_cfg, max_clusters=4,
              calibration=Calibration(iter_time_s=0.01))
    a = plan_capacity(spec, slo, **kw)
    b = plan_capacity(spec, slo, **kw)
    assert a.engine == b.engine
    assert a.predicted == b.predicted
    assert a.cost == b.cost == config_cost(a.engine, model_cfg)
    assert slo.met_by(a.predicted)
    assert a.evaluated >= 1


def test_plan_capacity_impossible_slo_raises(model_cfg):
    with pytest.raises(ValueError, match="no candidate"):
        plan_capacity(_spec(), SLOSpec(ttft_p95_s=1e-6, tpot_p95_s=1e-6),
                      model_cfg=model_cfg, max_clusters=2,
                      calibration=Calibration(iter_time_s=0.01))


def test_plan_capacity_restricted_candidates(model_cfg):
    spec = _spec()
    arrivals = spec.sample_arrivals(256)
    only = [_engine_for(arrivals, max_lanes=4, token_budget=None)]
    res = plan_capacity(spec, SLOSpec(ttft_p95_s=1.0, tpot_p95_s=1.0),
                        model_cfg=model_cfg, candidates=only,
                        calibration=Calibration(iter_time_s=0.01))
    assert res.engine == only[0]


# ===========================================================================
# plan_capacity monotonicity (scripted-random, seeded)
# ===========================================================================

def _plan(model_cfg, rate, ttft, tpot, seed):
    spec = WorkloadSpec(rate_rps=rate, requests=12, prompt_min=4,
                        prompt_max=10, output_min=2, output_max=4,
                        seed=seed)
    return plan_capacity(spec, SLOSpec(ttft_p95_s=ttft, tpot_p95_s=tpot),
                         model_cfg=model_cfg, max_clusters=4,
                         calibration=Calibration(iter_time_s=0.01))


def test_tighter_slo_never_cheaper(model_cfg):
    for seed in range(6):
        rng = np.random.default_rng(seed)
        rate = float(rng.uniform(20, 120))
        ttft = float(rng.uniform(0.04, 0.2))
        tpot = float(rng.uniform(0.01, 0.04))
        loose = _plan(model_cfg, rate, ttft, tpot, seed)
        try:
            tight = _plan(model_cfg, rate, ttft / 2, tpot, seed)
            cost_tight = tight.cost
        except ValueError:
            cost_tight = float("inf")         # infeasible = maximally dear
        assert cost_tight >= loose.cost, \
            f"seed {seed}: tighter SLO picked a cheaper config"


def test_higher_rate_never_shrinks_the_pool(model_cfg):
    for seed in range(6):
        rng = np.random.default_rng(seed)
        rate = float(rng.uniform(20, 60))
        ttft = float(rng.uniform(0.06, 0.2))
        tpot = float(rng.uniform(0.015, 0.04))
        prev = _plan(model_cfg, rate, ttft, tpot, seed)
        for mult in (2, 4):
            cur = _plan(model_cfg, rate * mult, ttft, tpot, seed)
            assert cur.engine.clusters >= prev.engine.clusters, \
                f"seed {seed} x{mult}: fewer clusters at higher rate"
            assert cur.engine.clusters * cur.engine.cache.num_pages >= \
                prev.engine.clusters * prev.engine.cache.num_pages, \
                f"seed {seed} x{mult}: smaller pool at higher rate"
            assert cur.cost >= prev.cost
            prev = cur


# ===========================================================================
# no wall clock anywhere in the planner
# ===========================================================================

def test_planner_never_reads_the_wall_clock():
    import repro.planner.capacity
    import repro.planner.costs
    import repro.planner.simulator
    import repro.planner.workload
    banned = ("time.time", "perf_counter", "time.monotonic",
              "datetime", "time.sleep", "import time")
    for mod in (repro.planner.capacity, repro.planner.costs,
                repro.planner.simulator, repro.planner.workload):
        src = inspect.getsource(mod)
        for tok in banned:
            assert tok not in src, f"{mod.__name__} uses {tok}"


def test_plan_result_is_frozen(model_cfg):
    res = plan_capacity(_spec(), SLOSpec(ttft_p95_s=1.0, tpot_p95_s=1.0),
                        model_cfg=model_cfg, max_clusters=1,
                        calibration=Calibration(iter_time_s=0.01))
    with pytest.raises(dataclasses.FrozenInstanceError):
        res.cost = 0.0
