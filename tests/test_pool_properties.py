"""Pool property tests (hypothesis): random interleavings of
submit / decode / finish / preempt / resume / speculate / cancel /
fault_swap_in schedules — driving the pool exactly the way
``PagedServer`` does (prefix-hit admission, reservation discipline,
copy-on-write appends, swap-out page reclamation, speculative append +
rollback trims, and the exceptional exits: client cancellation at any
lifecycle point and a backing-store fault mid-restore) — must preserve
the pool's conservation laws:

* refcount conservation: sum of refcounts == number of live mappings;
* free + cached-free + referenced partitions the physical pool (no
  double-free, no leak);
* no page reachable from two sequences unless its refcount > 1;
* block tables of running sequences always translate through live RAB
  entries that agree with the page table.

The tiered variant (``TieredSchedulerModel``) additionally drives the
hierarchical prefix cache the way the engine does — evictions demote
indexed pages to a modeled backing store, tiered admissions adopt
spilled hits back onto device (the pool half of async promotion), and
fetch faults drop entries everywhere — and must preserve:

* every indexed page is resident in exactly ONE tier: a content key is
  either device-indexed or spilled, never both, and after the demotion/
  drop queues drain the backing store holds exactly the spilled ids.

The quantized variant (``QuantizedSchedulerModel``) additionally shadows
the int8 engine's per-page dequant-scale slab through the same action
mix — quantize-writes grow a page's running-max scale, CoW copies the
source page's scale to the private copy, preemption swaps scales out
and back in with the page bytes, and demotion parks the scale in the
backing store for promotion to restore — and must keep the scale shadow
in lockstep with content-holding pages:

* a scale row exists for exactly the pages that hold content (mapped or
  cached-free); a cached page never loses its scale before eviction;
* every spilled entry parks a scale alongside its bytes (the engine's
  single packed blob + CRC).

Skipped wholesale when hypothesis is not installed (see
requirements-dev.txt); the deterministic unit tests in ``test_rab.py``
and ``test_hierarchical_cache.py`` always run.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.rab import (  # noqa: E402
    RAB, RABConfig, PagedKVPool, ClusterPagedPool,
)

PAGE_SIZE = 2
NUM_PAGES = 12
MAX_PAGES_PER_SEQ = 8

# prompts engineered to share prefixes at several page boundaries
BASE = [1, 2, 3, 4, 5, 6]
PROMPTS = [
    BASE[:6], BASE[:6],                 # identical (full + tail sharing)
    BASE[:4] + [7, 8], BASE[:4] + [9],  # shared 2-page prefix
    BASE[:2] + [10],                    # shared 1-page prefix
    [11, 12, 13],                       # disjoint
    [14],                               # single token (never cacheable)
]


class SchedulerModel:
    """Host-side mirror of PagedServer's pool driving (chunk=1): admission
    with prefix hits + reservations, per-token appends with prompt-page
    registration, finish, preempt (swap-out), resume (swap-in)."""

    def __init__(self):
        self.rab = RAB(RABConfig(l1_entries=4, l2_entries=16, l2_assoc=4,
                                 l2_banks=2))
        self.pool = PagedKVPool(NUM_PAGES, PAGE_SIZE, MAX_PAGES_PER_SEQ,
                                self.rab)
        self.live = {}          # seq -> state dict
        self.next_seq = 0

    # ------------------------------------------------------------- ops --
    @staticmethod
    def _cow_budget(prompt, max_new):
        # mirror PagedServer._cow_budget: a registered partial prompt tail
        # may be shared under the owner, whose own next append then CoWs
        return 1 if (max_new > 1 and len(prompt) % PAGE_SIZE) else 0

    def submit(self, prompt_idx, max_new):
        prompt = list(PROMPTS[prompt_idx % len(PROMPTS)])
        total = -(-(len(prompt) + max_new - 1) // PAGE_SIZE) \
            + self._cow_budget(prompt, max_new)
        if total > NUM_PAGES or total > MAX_PAGES_PER_SEQ:
            return
        pool = self.pool
        usable, hits = 0, []
        if len(prompt) > 1:
            pages, n = pool.match_prefix(prompt)
            usable = min(n, len(prompt) - 1)
            hits = pages[:-(-usable // PAGE_SIZE)] if usable else []
        need = total - usable // PAGE_SIZE
        cached = sum(1 for p in hits if p in pool.cached_free)
        if pool.available() < need + cached:
            # mirror the server's no-sharing fallback plan
            if pool.available() < total:
                return                  # admission would not fit: skip
            usable, hits, need, cached = 0, [], total, 0
        seq = self.next_seq
        self.next_seq += 1
        for lp, p in enumerate(hits):
            pool.share_page(seq, lp, p)
        if usable:
            pool.seq_len[seq] = usable
        if need:
            pool.reserve(seq, need)
        self.live[seq] = {"prompt": prompt, "max_new": max_new,
                          "reg_pages": usable // PAGE_SIZE,
                          "preempted": False, "swapped": []}

    # Quantized-model hooks: the int8 variant shadows the scales slab by
    # observing the same pool transitions the server's accounting sees.
    def _on_append(self, seq):
        pass

    def _on_cow(self, src, dst):
        pass

    def _on_adopt(self, seq, lp, eid):
        pass

    def _running(self, k):
        seqs = [s for s, v in self.live.items() if not v["preempted"]]
        return seqs[k % len(seqs)] if seqs else None

    def _preempted(self, k):
        seqs = [s for s, v in self.live.items() if v["preempted"]]
        return seqs[k % len(seqs)] if seqs else None

    def decode(self, k):
        seq = self._running(k)
        if seq is None:
            return
        st_, pool = self.live[seq], self.pool
        prompt = st_["prompt"]
        total = len(prompt) + st_["max_new"] - 1
        if pool.seq_len.get(seq, 0) >= total:
            return self.finish(k)
        pool.append_token(seq)
        for (s, lp, src, dst) in pool.drain_cow():
            assert s == seq and pool.page_table[(s, lp)] == dst
            assert dst != src
            self._on_cow(src, dst)
        self._on_append(seq)
        written = min(pool.seq_len[seq], len(prompt))
        if pool.seq_len[seq] <= len(prompt):   # still a prompt token
            for lp in range(st_["reg_pages"], written // PAGE_SIZE):
                pool.register_page(seq, lp, prompt)
            st_["reg_pages"] = max(st_["reg_pages"], written // PAGE_SIZE)
            if written == len(prompt) and written % PAGE_SIZE:
                pool.register_page(seq, written // PAGE_SIZE, prompt)

    def finish(self, k):
        seq = self._running(k)
        if seq is None:
            return
        self.pool.release(seq)
        del self.live[seq]

    def speculate(self, k, n_draft, acc_sel):
        """Mirror PagedServer._spec_iteration's pool driving: append the
        candidate block (x0 + up to ``n_draft`` drafts, capped so writes
        never exceed the admission-time lifetime budget), then roll back
        to an arbitrary accepted prefix with ``trim`` — rejected pages go
        home and their reservation budget is re-credited."""
        seq = self._running(k)
        if seq is None:
            return
        st_, pool = self.live[seq], self.pool
        prompt = st_["prompt"]
        total = len(prompt) + st_["max_new"] - 1
        cur = pool.seq_len.get(seq, 0)
        if cur < len(prompt):           # server drafts only in decode phase
            return
        if cur >= total:
            return self.finish(k)
        kk = min(n_draft, total - cur - 1)   # accepted + 1 <= remaining
        start = cur
        for _ in range(kk + 1):              # x0 + the drafts
            pool.append_token(seq)
            for (s, lp, src, dst) in pool.drain_cow():
                assert s == seq and pool.page_table[(s, lp)] == dst
                assert dst != src
                self._on_cow(src, dst)
            self._on_append(seq)
        accepted = acc_sel % (kk + 1)        # any prefix may be rejected
        freed = pool.trim(seq, start + accepted + 1)
        assert pool.seq_len[seq] == start + accepted + 1
        assert freed >= 0

    def preempt(self, k):
        seq = self._running(k)
        if seq is None:
            return
        pool, st_ = self.pool, self.live[seq]
        mapped = pool.seq_pages(seq)          # full sweep: every mapping
        for lp, _p in mapped:                 # drops (payload checkpointed
            pool.unmap_page(seq, lp)          # host-side by the server)
        pool.reserved.pop(seq, None)
        st_["preempted"] = True
        st_["swapped"] = [lp for lp, _ in mapped]

    def resume(self, k):
        seq = self._preempted(k)
        if seq is None:
            return
        pool, st_ = self.pool, self.live[seq]
        total = -(-(len(st_["prompt"]) + st_["max_new"] - 1) // PAGE_SIZE) \
            + self._cow_budget(st_["prompt"], st_["max_new"])
        need = total
        if pool.available() < need:
            return                      # re-admission would not fit: skip
        if need:
            pool.reserve(seq, need)
        for lp in st_["swapped"]:
            pool.alloc_page(seq, lp)    # the H2D payload restore
        st_["preempted"] = False
        st_["swapped"] = []

    def cancel(self, k):
        """Mirror PagedServer._terminate: an exceptional exit (client
        cancel, deadline timeout, error demotion, shed) at ANY lifecycle
        point — running, mid-prompt, or parked after preemption — must
        release through the same refcount/CoW/reservation-aware path as
        a natural finish."""
        seqs = sorted(self.live)
        if not seqs:
            return
        seq = seqs[k % len(seqs)]
        self.pool.release(seq)
        del self.live[seq]

    def fault_swap_in(self, k, n_alloc):
        """Mirror a BackingStoreError mid-restore: the re-admitted
        sequence has its reservation placed and some (possibly zero,
        possibly all) of its pages re-allocated when the backing store
        fails — the server demotes the request to ``"error"`` and
        releases; no reservation budget or partially restored page may
        leak."""
        seq = self._preempted(k)
        if seq is None:
            return
        pool, st_ = self.pool, self.live[seq]
        total = -(-(len(st_["prompt"]) + st_["max_new"] - 1) // PAGE_SIZE) \
            + self._cow_budget(st_["prompt"], st_["max_new"])
        if pool.available() < total:
            return                      # re-admission would not fit: skip
        if total:
            pool.reserve(seq, total)
        restored = st_["swapped"][:n_alloc % (len(st_["swapped"]) + 1)]
        for lp in restored:
            pool.alloc_page(seq, lp)    # partial restore, then the fault
        pool.release(seq)
        del self.live[seq]

    # ------------------------------------------------------- invariants --
    def check(self):
        pool = self.pool
        pool.check_invariants()
        # no page reachable from two sequences unless refcount > 1
        owners = {}
        for (s, _lp), p in pool.page_table.items():
            owners.setdefault(p, set()).add(s)
        for p, ss in owners.items():
            if len(ss) > 1:
                assert pool.refcount[p] > 1, (p, ss)
        # running sequences' block tables translate through live RAB
        # entries that agree with the page table
        running = [s for s, v in self.live.items() if not v["preempted"]]
        for s in running:
            bt = pool.block_table([s])
            resident = self.rab.resident()
            for (s2, lp), p in pool.page_table.items():
                if s2 != s:
                    continue
                assert bt[0, lp] == p, (s, lp)
                vpage = pool._vpage(s, lp)
                assert resident.get(vpage, p) == p, \
                    f"stale RAB entry for vpage {vpage}"
        # preempted sequences hold exactly their non-swapped mappings
        for s, v in self.live.items():
            if v["preempted"]:
                mapped = {lp for lp, _ in pool.seq_pages(s)}
                assert not (mapped & set(v["swapped"]))


class TieredSchedulerModel(SchedulerModel):
    """The scheduler model with the spill hierarchy enabled: the pool
    demotes evicted indexed pages instead of dropping them, and this
    model mirrors the engine's ``_drain_tier_ops`` (park demotions into
    a host-side store, apply queued drops) plus the admission-time
    adopt-spilled path of ``_place`` — promotion's pool half, with the
    async landing modeled as immediate (lane gating is engine state the
    pool never sees)."""

    def __init__(self):
        super().__init__()
        self.pool.spill_enabled = True
        self.store = {}                  # eid -> content key (the "tiers")

    def drain_tiers(self):
        """Mirror ``PagedServer._drain_tier_ops``: park queued demotions
        (skipping superseded entries) and apply queued spill drops."""
        pool = self.pool
        for _p, key in pool.drain_demotions():
            if key in pool.spilled:      # not superseded meanwhile
                self.store[pool.spilled[key]] = key
        for eid in pool.drain_spill_drops():
            self.store.pop(eid, None)

    def submit(self, prompt_idx, max_new):
        """Tiered admission: device hits are shared, spilled hits are
        fetched from the store and adopted back onto device (consuming
        the reservation the way ``alloc_page`` does in the engine)."""
        prompt = list(PROMPTS[prompt_idx % len(PROMPTS)])
        total = -(-(len(prompt) + max_new - 1) // PAGE_SIZE) \
            + self._cow_budget(prompt, max_new)
        if total > NUM_PAGES or total > MAX_PAGES_PER_SEQ:
            return
        pool = self.pool
        usable, hits = 0, []
        if len(prompt) > 1:
            pages, n = pool.match_prefix_tiered(prompt)
            usable = min(n, len(prompt) - 1)
            hits = pages[:-(-usable // PAGE_SIZE)] if usable else []
            hits = hits[:usable // PAGE_SIZE]      # full pages only
            usable = len(hits) * PAGE_SIZE
        dev_full = sum(1 for kind, _v in hits if kind == "device")
        need = total - dev_full
        cached = sum(1 for kind, v in hits
                     if kind == "device" and v in pool.cached_free)
        if pool.available() < need + cached:
            if pool.available() < total:
                return                  # admission would not fit: skip
            usable, hits, need, cached = 0, [], total, 0
        seq = self.next_seq
        self.next_seq += 1
        # fetch-before-reserve: the engine pulls spilled payloads first
        for kind, v in hits:
            if kind == "spilled":
                assert pool.spilled[v] in self.store, \
                    "spilled hit not parked in the backing store"
        if need:
            pool.reserve(seq, need)
        for lp, (kind, v) in enumerate(hits):
            if kind == "device":
                pool.share_page(seq, lp, v)
            else:
                eid = pool.spilled[v]
                pool.adopt_spilled(seq, lp, v)
                del self.store[eid]     # promoted: store copy dropped
                self._on_adopt(seq, lp, eid)
        if usable:
            pool.seq_len[seq] = usable
        self.live[seq] = {"prompt": prompt, "max_new": max_new,
                          "reg_pages": usable // PAGE_SIZE,
                          "preempted": False, "swapped": []}

    def drop_spilled(self, k):
        """Mirror the fetch-fault path: a spilled entry whose payload the
        store cannot restore is dropped everywhere."""
        pool = self.pool
        keys = sorted(pool.spilled)
        if not keys:
            return
        key = keys[k % len(keys)]
        eid = pool.spilled[key]
        pool.drop_spilled(key)
        self.store.pop(eid, None)

    def check(self):
        super().check()
        pool = self.pool
        # exactly-one-tier: a content key is device-indexed XOR spilled
        for key in pool.spilled:
            assert key not in pool.prefix_index, \
                f"key {key} resident on device AND spilled"
        # queues drained -> the store holds exactly the spilled entries
        assert set(self.store) == set(pool.spilled.values()), \
            "backing store out of sync with the pool's spilled index"
        # spilled entries keep their stable ids (promotion identity)
        for key, eid in pool.spilled.items():
            assert self.store[eid] == key


class QuantizedSchedulerModel(TieredSchedulerModel):
    """The tiered model with the int8 KV pool's scale slab shadowed: a
    per-physical-page running-max dequant scale, driven exactly the way
    ``PagedServer`` drives its device scales array — reset on fresh
    allocation, grown by every quantize-write (scatter-max), copied
    src→dst on CoW before the private write lands, packed with the page
    bytes through preemption swap-out/in, and parked in the backing
    store by demotion for promotion to restore."""

    def __init__(self):
        super().__init__()
        self.scale = {}          # phys -> running-max scale (the "slab")
        self.store_scale = {}    # eid -> scale parked with spilled bytes
        self._pre = {}           # scale state at op start (demotion parks
        self._tok = 0            # bytes as of eviction, not drain, time)

    # ------------------------------------------------- base-model hooks --
    def _on_append(self, seq):
        pool = self.pool
        n = pool.seq_len[seq]
        p = pool.page_table[(seq, (n - 1) // PAGE_SIZE)]
        self._tok += 1
        tok_scale = 1.0 + (self._tok % 5) / 4.0    # varying |max| per token
        self.scale[p] = max(self.scale.get(p, 0.0), tok_scale)

    def _on_cow(self, src, dst):
        self.scale[dst] = self.scale.get(src, 0.0)

    def _on_adopt(self, seq, lp, eid):
        # promotion restores exactly the scale demotion parked
        assert eid in self.store_scale, "promoted bytes without a scale"
        self.scale[self.pool.page_table[(seq, lp)]] = \
            self.store_scale.pop(eid)

    # --------------------------------------------------------- lifecycle --
    def snapshot(self):
        self._pre = dict(self.scale)

    def preempt(self, k):
        seq = self._running(k)
        if seq is not None:
            # the swap blob packs page bytes AND their scales (one CRC)
            self.live[seq]["swapped_scale"] = {
                lp: self.scale.get(p, 0.0)
                for lp, p in self.pool.seq_pages(seq)}
        super().preempt(k)

    def resume(self, k):
        seq = self._preempted(k)
        super().resume(k)
        if seq is not None and seq in self.live \
                and not self.live[seq]["preempted"]:
            saved = self.live[seq].pop("swapped_scale", {})
            for lp, sc in saved.items():    # H2D restore lands the scales
                self.scale[self.pool.page_table[(seq, lp)]] = sc

    def drop_spilled(self, k):
        pool = self.pool
        keys = sorted(pool.spilled)
        if keys:                            # same key the base op drops
            self.store_scale.pop(pool.spilled[keys[k % len(keys)]], None)
        super().drop_spilled(k)

    def drain_tiers(self):
        pool = self.pool
        for p, key in pool.drain_demotions():
            if key in pool.spilled:          # not superseded meanwhile
                eid = pool.spilled[key]
                self.store[eid] = key
                self.store_scale[eid] = self._pre.get(
                    p, self.scale.get(p, 0.0))
        for eid in pool.drain_spill_drops():
            self.store.pop(eid, None)
            self.store_scale.pop(eid, None)

    def reconcile(self):
        """Mirror ``_account_appends``' fresh-page scale reset and the
        slab rows going dead when pages leave the content set."""
        content = set(self.pool.page_table.values()) \
            | set(self.pool.cached_free)
        for p in list(self.scale):
            if p not in content:
                del self.scale[p]            # freed: the row is dead
        for p in content - set(self.scale):
            assert p not in self.pool.cached_free, \
                "a cached page lost its scale before eviction"
            self.scale[p] = 0.0              # fresh allocation: reset

    # ------------------------------------------------------- invariants --
    def check(self):
        super().check()
        pool = self.pool
        content = set(pool.page_table.values()) | set(pool.cached_free)
        assert set(self.scale) == content, \
            "scale rows out of sync with content-holding pages"
        assert all(s >= 0.0 for s in self.scale.values())
        # every spilled entry parks a scale alongside its bytes
        assert set(pool.spilled.values()) <= set(self.store_scale), \
            "spilled bytes without a parked scale"


OPS = st.sampled_from(["submit", "decode", "decode", "decode", "decode",
                       "finish", "preempt", "resume", "speculate",
                       "speculate", "cancel", "fault_swap_in"])
SCHEDULE = st.lists(st.tuples(OPS, st.integers(0, 6), st.integers(1, 4),
                              st.integers(0, 4)),
                    min_size=1, max_size=120)


@settings(deadline=None)
@given(SCHEDULE)
def test_pool_invariants_under_random_schedules(schedule):
    m = SchedulerModel()
    for op, arg, max_new, acc in schedule:
        if op == "submit":
            m.submit(arg, max_new)
        elif op == "decode":
            m.decode(arg)
        elif op == "finish":
            m.finish(arg)
        elif op == "preempt":
            m.preempt(arg)
        elif op == "resume":
            m.resume(arg)
        elif op == "speculate":
            # max_new doubles as the draft depth, acc as the accepted-
            # prefix selector — both arbitrary, so rollback depth is too
            m.speculate(arg, max_new, acc)
        elif op == "cancel":
            m.cancel(arg)
        elif op == "fault_swap_in":
            # acc doubles as the partial-restore depth at fault time
            m.fault_swap_in(arg, acc)
        m.check()
    # drain everything: the pool must return to pristine capacity
    for s in list(m.live):
        m.pool.release(s)
        m.check()
    assert m.pool.free_pages() == NUM_PAGES
    assert sum(m.pool.refcount.values()) == 0 == len(m.pool.page_table)


TIERED_OPS = st.sampled_from(
    ["submit", "submit", "decode", "decode", "decode", "decode",
     "finish", "preempt", "resume", "speculate", "cancel",
     "fault_swap_in", "drop_spilled"])


@settings(deadline=None)
@given(st.lists(st.tuples(TIERED_OPS, st.integers(0, 6),
                          st.integers(1, 4), st.integers(0, 4)),
                min_size=1, max_size=120))
def test_tiered_pool_invariants_under_random_schedules(schedule):
    """The spill-enabled pool under random schedules: demotions park in
    the modeled store, tiered admissions adopt spilled hits back, fetch
    faults drop entries — and after every op (queues drained, the way
    the engine's ``_drain_tier_ops`` call sites guarantee) each indexed
    page is resident in exactly one tier."""
    m = TieredSchedulerModel()
    for op, arg, max_new, acc in schedule:
        if op == "submit":
            m.submit(arg, max_new)
        elif op == "decode":
            m.decode(arg)
        elif op == "finish":
            m.finish(arg)
        elif op == "preempt":
            m.preempt(arg)
        elif op == "resume":
            m.resume(arg)
        elif op == "speculate":
            m.speculate(arg, max_new, acc)
        elif op == "cancel":
            m.cancel(arg)
        elif op == "fault_swap_in":
            m.fault_swap_in(arg, acc)
        elif op == "drop_spilled":
            m.drop_spilled(arg)
        m.drain_tiers()
        m.check()
    for s in list(m.live):
        m.pool.release(s)
        m.drain_tiers()
        m.check()
    assert m.pool.free_pages() == NUM_PAGES
    assert sum(m.pool.refcount.values()) == 0 == len(m.pool.page_table)


@settings(deadline=None)
@given(st.lists(st.tuples(TIERED_OPS, st.integers(0, 6),
                          st.integers(1, 4), st.integers(0, 4)),
                min_size=1, max_size=120))
def test_quantized_scale_slab_under_random_schedules(schedule):
    """The int8 pool's scale slab shadow under the full action mix —
    quantize-writes, CoW, speculative trim, preemption swap, tiered
    demote/promote, fetch faults — must track content-holding pages
    exactly: no live page without a scale row, no cached page losing its
    scale before eviction, no spilled bytes without a parked scale, and
    promotion restoring exactly what demotion parked."""
    m = QuantizedSchedulerModel()
    for op, arg, max_new, acc in schedule:
        m.snapshot()            # demotion parks scales as of eviction time
        if op == "submit":
            m.submit(arg, max_new)
        elif op == "decode":
            m.decode(arg)
        elif op == "finish":
            m.finish(arg)
        elif op == "preempt":
            m.preempt(arg)
        elif op == "resume":
            m.resume(arg)
        elif op == "speculate":
            m.speculate(arg, max_new, acc)
        elif op == "cancel":
            m.cancel(arg)
        elif op == "fault_swap_in":
            m.fault_swap_in(arg, acc)
        elif op == "drop_spilled":
            m.drop_spilled(arg)
        m.reconcile()
        m.drain_tiers()
        m.check()
    for s in list(m.live):
        m.snapshot()
        m.pool.release(s)
        m.reconcile()
        m.drain_tiers()
        m.check()
    assert m.pool.free_pages() == NUM_PAGES
    assert sum(m.pool.refcount.values()) == 0 == len(m.pool.page_table)


@settings(deadline=None)
@given(st.lists(st.tuples(st.integers(0, 6), st.integers(1, 3)),
                min_size=1, max_size=40))
def test_prefix_index_consistency(subs):
    """Whatever the submission order, every prefix-index entry maps a key
    to a page whose owner really holds that token prefix — matches never
    fabricate pages, and revived cached pages keep exact content keys."""
    m = SchedulerModel()
    for prompt_idx, max_new in subs:
        m.submit(prompt_idx, max_new)
        for k in range(10):             # run a few tokens through
            m.decode(k)
        m.check()
        pool = m.pool
        for key, p in pool.prefix_index.items():
            assert pool.page_key[p] == key
            hit, n = pool.match_prefix(list(key))
            assert n == len(key) and hit[-1] == p


# ---------------------------------------------------------------------------
# multi-cluster pool partition (sharded engine)
# ---------------------------------------------------------------------------

CLUSTER_OPS = st.sampled_from(["submit", "append", "append", "append",
                               "release"])


@settings(deadline=None)
@given(st.integers(1, 4),
       st.lists(st.tuples(CLUSTER_OPS, st.integers(0, 7)),
                min_size=1, max_size=80))
def test_cluster_pool_partition(clusters, schedule):
    """Random least-loaded placements and per-sequence page traffic across
    C cluster shards: no physical page is ever owned by two clusters, a
    sequence is resident on exactly its routed cluster, and the shards
    always partition the global page namespace — ``ClusterPagedPool``'s
    invariants, checked after every operation."""
    cp = ClusterPagedPool(clusters, NUM_PAGES, PAGE_SIZE, MAX_PAGES_PER_SEQ,
                          RABConfig(l1_entries=4, l2_entries=16, l2_assoc=4,
                                    l2_banks=2))
    live = {}                       # seq -> cluster
    next_seq = 0
    for op, arg in schedule:
        if op == "submit":
            c = cp.least_loaded()
            pool = cp.pools[c]
            pages = -(-(arg + 1) // PAGE_SIZE)
            if pages > min(pool.available(), MAX_PAGES_PER_SEQ):
                continue
            cp.place(next_seq, c)
            pool.reserve(next_seq, pages)
            live[next_seq] = c
            next_seq += 1
        elif op == "append" and live:
            seq = sorted(live)[arg % len(live)]
            pool = cp.pool_for(seq)
            n = pool.seq_len.get(seq, 0)
            need_page = n % PAGE_SIZE == 0
            budget = pool.reserved.get(seq, 0)
            lp = n // PAGE_SIZE
            if lp >= MAX_PAGES_PER_SEQ or (need_page and budget == 0
                                           and pool.available() < 1):
                continue
            pool.append_token(seq)
            pool.drain_cow()
        elif op == "release" and live:
            seq = sorted(live)[arg % len(live)]
            cp.pool_for(seq).release(seq)
            cp.forget(seq)
            del live[seq]
        cp.check_invariants()
    for seq in list(live):
        cp.pool_for(seq).release(seq)
        cp.forget(seq)
        cp.check_invariants()
    assert cp.free_pages() == clusters * NUM_PAGES
    assert not cp.cluster_of


def test_cluster_pool_rejects_double_placement():
    cp = ClusterPagedPool(2, NUM_PAGES, PAGE_SIZE, MAX_PAGES_PER_SEQ)
    cp.place(0, 0)
    with pytest.raises(AssertionError):
        cp.place(0, 1)
    cp.forget(0)
    cp.place(0, 1)                  # legal again after forget (re-admission)
