"""Quantized KV serving path: ``CacheConfig.kv_dtype="int8"`` stores
pages as int8 with one float32 scale per (page, K/V, kv-head), the fused
scatter quantizes at write, and both attention paths dequantize inside
the K/V fetch.

Deterministic suite (runs in every CI leg; the matrix fixtures pick the
page size / attention path, the int8 engines here are explicit):

* quantization primitives: re-quantizing under an unchanged page scale
  is exactly lossless (the rescale-on-grow repack invariant) and the
  absmax/127 grid bounds per-element error by half a step;
* config surface: ``kv_dtype`` validation, ``CacheStats.bytes_per_token``
  matching the closed-form footprint in both modes, ratio under the
  bench gate's ceiling;
* engine parity: int8 Pallas kernel == int8 ref oracle token-for-token;
  int8 output streams track bf16 closely (agreement floor — int8 may
  legitimately flip near-argmax-ties, so this is NOT an equality gate);
* lifecycle: int8 pages survive preemption swap-out/in, shared-prefix
  CoW, speculative trim/rollback, tier demote/promote, and the
  1-cluster sharded engine — each must reproduce the corresponding
  undisturbed int8 stream exactly (quantization error must be
  deterministic, not path-dependent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.optim.compress import (
    decompress_int8, headwise_scales, quantize_int8,
)
from repro.runtime import (
    CacheConfig, EngineConfig, GenerationRequest, SamplingParams,
    VirtualClock, make_engine,
)

MAX_NEW = 8


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b").smoke()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(vocab, seed=0):
    """Repetitive + random prompts (shared 4-token pattern twice so the
    prefix cache and the drafter both engage)."""
    rng = np.random.default_rng(seed)
    pat = rng.integers(1, vocab, size=4).tolist()
    return [pat * 3, rng.integers(1, vocab, size=12).tolist(),
            pat * 3 + [5, 6], rng.integers(1, vocab, size=9).tolist()]


def _serve(cfg, params, prompts, *, kv_dtype="int8", page_size=4,
           use_kernel=False, max_lanes=2, max_new=MAX_NEW, num_pages=64,
           preempt_rid=None, cache_kw=None, **kw):
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=num_pages, page_size=page_size,
                          max_pages_per_seq=16, kv_dtype=kv_dtype,
                          **(cache_kw or {})),
        max_lanes=max_lanes, chunk=8, use_kernel=use_kernel, **kw))
    try:
        for rid, p in enumerate(prompts):
            srv.submit(GenerationRequest(
                rid=rid, prompt=tuple(p),
                sampling=SamplingParams(max_new=max_new)))
        if preempt_rid is not None:
            for _ in range(6):          # into mid-decode before preempting
                srv.step()
            assert srv.preempt(preempt_rid)
        done = srv.run()
        assert len(done) == len(prompts)
        out = {r.rid: r.tokens for r in done}
        stats = srv.cache_stats()
        (srv.cpool if hasattr(srv, "cpool") else srv.pool).check_invariants()
    finally:
        srv.close()
    return out, stats


# ------------------------------------------------------- primitives --

def test_requantize_under_unchanged_scale_is_lossless():
    """The repack multiplies stored bytes by old_scale/new_scale and
    re-rounds; for pages a new token did not extend that factor is
    exactly 1.0, so round(q * 1.0) == q — byte-identical."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 16), jnp.float32)
    scale = headwise_scales(x)[..., None]
    q = quantize_int8(x, scale)
    again = jnp.round(q.astype(jnp.float32) * 1.0)
    assert jnp.array_equal(again.astype(jnp.int8), q)
    # and quantizing the dequantized value under the same scale is a
    # fixed point (no drift across repeated repacks)
    q2 = quantize_int8(decompress_int8(q, scale), scale)
    assert jnp.array_equal(q2, q)


def test_quantization_error_bounded_by_half_step():
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 32), jnp.float32) * 5.0
    scale = headwise_scales(x)[..., None]
    err = jnp.abs(decompress_int8(quantize_int8(x, scale), scale) - x)
    assert float(jnp.max(err)) <= float(jnp.max(scale)) * 0.5 + 1e-6
    # zero slices carry scale 0 and quantize to exact zeros
    z = jnp.zeros((3, 8))
    assert float(jnp.max(jnp.abs(headwise_scales(z)))) == 0.0
    assert jnp.array_equal(quantize_int8(z, headwise_scales(z)[..., None]),
                           jnp.zeros((3, 8), jnp.int8))


def test_running_max_scale_only_grows():
    """Page scales are a running absmax: folding in a smaller token
    leaves the scale (and existing bytes) untouched."""
    big = jnp.full((1, 4), 8.0)
    small = jnp.full((1, 4), 1.0)
    s0 = headwise_scales(big)
    s1 = jnp.maximum(s0, headwise_scales(small))    # the scatter's .max()
    assert jnp.array_equal(s0, s1)


# ----------------------------------------------------------- config --

def test_kv_dtype_validated():
    with pytest.raises(ValueError):
        CacheConfig(kv_dtype="fp8")
    assert CacheConfig(kv_dtype="int8").kv_dtype == "int8"
    assert CacheConfig().kv_dtype == "bf16"


def test_bytes_per_token_matches_closed_form(cfg, params):
    """bytes_per_token = L * 2 * (Kv*hd * itemsize + scale bytes/token);
    the int8/bf16 ratio is the quantization win the bench gates on."""
    prompts = _prompts(cfg.vocab_size)[:2]
    page = 4
    _, st8 = _serve(cfg, params, prompts, kv_dtype="int8", page_size=page)
    _, st16 = _serve(cfg, params, prompts, kv_dtype="bf16", page_size=page)
    kv, hd, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    param_bytes = jnp.dtype(cfg.param_dtype).itemsize
    assert st8.bytes_per_token == L * 2 * (kv * hd * 1 + 4.0 * kv / page)
    assert st16.bytes_per_token == L * 2 * kv * hd * param_bytes
    assert st8.bytes_per_token / st16.bytes_per_token <= 0.6


# ----------------------------------------------------------- parity --

def test_int8_kernel_matches_ref(cfg, params, matrix_page_size):
    prompts = _prompts(cfg.vocab_size)
    ref, _ = _serve(cfg, params, prompts, page_size=matrix_page_size,
                    use_kernel=False)
    ker, _ = _serve(cfg, params, prompts, page_size=matrix_page_size,
                    use_kernel=True)
    assert ker == ref, "int8 Pallas kernel diverged from the int8 oracle"


def test_int8_tracks_bf16_within_agreement_floor(cfg, params,
                                                 matrix_use_kernel):
    """Greedy int8 streams may flip near-argmax-ties relative to bf16 —
    deterministically, but legitimately — so this asserts a floor on
    positionwise agreement, not equality."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, kv_dtype="bf16",
                     use_kernel=matrix_use_kernel)
    out, _ = _serve(cfg, params, prompts, kv_dtype="int8",
                    use_kernel=matrix_use_kernel)
    agree = sum(int(a == b) for r in base
                for a, b in zip(base[r], out[r]))
    total = sum(len(t) for t in base.values())
    assert agree / total >= 0.9, \
        f"int8 agreed with bf16 on only {agree}/{total} tokens"


# -------------------------------------------------------- lifecycle --

def test_int8_parity_under_preemption(cfg, params, matrix_page_size,
                                      matrix_use_kernel):
    """Swap-out packs int8 page bytes + scales into one checksummed blob;
    the restored lane must continue the exact undisturbed stream."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, page_size=matrix_page_size,
                     use_kernel=matrix_use_kernel)
    out, _ = _serve(cfg, params, prompts, page_size=matrix_page_size,
                    use_kernel=matrix_use_kernel, preempt_rid=0)
    assert out == base, "int8 preemption swap changed tokens"


def test_int8_shared_prefix_cow_parity(cfg, params):
    """Prefix sharing + copy-on-write on quantized pages (the CoW copy
    carries bytes AND the page's scale row): sharing must not change any
    stream relative to the no-sharing int8 engine."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts,
                     cache_kw={"enable_prefix_cache": False})
    out, stats = _serve(cfg, params, prompts)
    assert out == base, "int8 prefix sharing/CoW changed tokens"
    assert stats.prefix_hit_tokens > 0, "workload never shared a prefix"


def test_int8_spec_parity(cfg, params, matrix_page_size, matrix_use_kernel):
    """Speculative verify writes draft tokens through the quant scatter
    and trims rejections; the spec-on int8 stream must equal spec-off."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, page_size=matrix_page_size,
                     use_kernel=matrix_use_kernel)
    out, _ = _serve(cfg, params, prompts, page_size=matrix_page_size,
                    use_kernel=matrix_use_kernel, spec_k=4)
    assert out == base, "int8 speculation changed tokens"


def test_int8_tier_demote_promote_parity(cfg, params):
    """Spilled payloads carry int8 page bytes + scales under one CRC;
    prefix hits restored from the host tier must reproduce the
    device-only int8 streams exactly."""
    # 6 tenants x 16-token system prompts = 24 pages of prefix corpus
    # revisited twice, against a 12-page device pool: revisits after
    # eviction hit the host tier and promote quantized pages back
    systems = {t: [t * 7 + 1, t + 2, t + 3, t + 4] * 4 for t in range(6)}
    reps = [systems[t] + [90 + r, 95 + r] for r in range(2)
            for t in range(6)]
    base, _ = _serve(cfg, params, reps, num_pages=12, max_new=3)
    out, stats = _serve(cfg, params, reps, num_pages=12, max_new=3,
                        cache_kw={"host_tier_pages": 64},
                        clock=VirtualClock())
    assert out == base, "int8 tier round-trip changed tokens"
    assert stats.demoted_pages > 0, "workload never demoted a page"
    assert stats.promoted_pages > 0, "workload never promoted a page"


def test_int8_sharded_one_cluster_parity(cfg, params, matrix_page_size):
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, page_size=matrix_page_size)
    out, _ = _serve(cfg, params, prompts, page_size=matrix_page_size,
                    sharded=True, clusters=1, heads=1)
    assert out == base, "1-cluster sharded int8 diverged from unsharded"
