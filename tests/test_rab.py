"""RAB unit tests: translation correctness, LRU, miss protocol, paged pool
invariants.  Property-based coverage (hypothesis) lives in
``test_rab_properties.py`` so these run even without hypothesis installed."""
import pytest

from repro.core.rab import RAB, RABConfig, PagedKVPool
from repro.core.tracing import TraceBuffer
from repro.core.analysis import (
    layer1_decode, assert_hit_under_miss, assert_wake_follows_handle,
)

CFG = RABConfig(l1_entries=4, l2_entries=16, l2_assoc=4, l2_banks=2)


def test_miss_then_hit():
    rab = RAB(CFG)
    pt = {5: 50, 7: 70}
    p, _ = rab.lookup(5, requester=1)
    assert p is None and 1 in rab.sleeping
    woken = rab.handle_misses(pt)
    assert woken == [1] and 1 not in rab.sleeping
    p, cyc = rab.lookup(5, requester=1)
    assert p == 50 and cyc == CFG.l1_lookup_cycles


def test_l1_eviction_to_l2():
    rab = RAB(CFG)
    pt = {v: v * 10 for v in range(20)}
    for v in range(CFG.l1_entries + 1):
        rab.lookup(v, requester=v)
    rab.handle_misses(pt)
    # the oldest promoted entry was evicted into L2; next lookup is an L2 hit
    rab.stats["l2_hits"] = 0
    for v in range(CFG.l1_entries + 1):
        p, _ = rab.lookup(v, requester=v)
        assert p == v * 10
    assert rab.stats["l2_hits"] >= 1


def test_page_fault_raises():
    rab = RAB(CFG)
    rab.lookup(99, requester=0)
    with pytest.raises(KeyError):
        rab.handle_misses({1: 2})


def test_protocol_events_satisfy_assertions():
    tracer = TraceBuffer()
    rab = RAB(CFG, tracer)
    pt = {v: v for v in range(10)}
    for v in [0, 1, 2, 0, 5, 6, 7, 8, 9, 1]:
        if rab.lookup(v, requester=v % 3)[0] is None:
            rab.handle_misses(pt)
    events = layer1_decode(tracer.drain())
    assert assert_hit_under_miss(events)
    assert assert_wake_follows_handle(events)


# ---------------------------------------------------------------------------
# Paged pool
# ---------------------------------------------------------------------------

def test_pool_alloc_release_cycle():
    pool = PagedKVPool(num_pages=8, page_size=4, max_pages_per_seq=4)
    for t in range(10):
        pool.append_token(1)
    assert pool.seq_len[1] == 10
    bt = pool.block_table([1])
    assert (bt[0, :3] >= 0).all() and bt[0, 3] == -1
    pool.release(1)
    assert len(pool.free) == 8


def test_pool_exhaustion():
    pool = PagedKVPool(num_pages=2, page_size=2, max_pages_per_seq=4)
    pool.append_token(1)
    pool.append_token(1)
    pool.append_token(1)  # second page
    with pytest.raises(MemoryError):
        pool.append_token(2)
    assert pool.can_alloc(0) and not pool.can_alloc(1)


def test_pool_reservations_guard_midstream_alloc():
    pool = PagedKVPool(num_pages=4, page_size=2, max_pages_per_seq=4)
    pool.reserve(1, 3)
    # admission accounting: only one unreserved page remains
    assert pool.available() == 1
    assert pool.can_alloc(1) and not pool.can_alloc(2)
    with pytest.raises(MemoryError):
        pool.reserve(2, 2)
    # an unreserved sequence may use the residue but not the reserved pages
    pool.append_token(3)
    pool.append_token(3)       # still page 1 of seq 3
    with pytest.raises(MemoryError):
        pool.append_token(3)   # page 2 would eat seq 1's reservation
    # seq 1's lazy allocations draw down its reservation, not the residue
    for _ in range(6):
        pool.append_token(1)
    assert pool.reserved[1] == 0 and pool.available() == 0
    pool.release(1)
    pool.release(3)
    assert pool.available() == 4 and not pool.reserved


def test_pool_prefix_sharing_and_cow():
    """Prefix hits map existing pages (refcount bumped, no allocation);
    appending into a shared page copy-on-writes it through the normal
    allocation path."""
    pool = PagedKVPool(num_pages=8, page_size=4, max_pages_per_seq=4)
    prompt = [5, 6, 7, 8, 9, 10]
    for _ in range(len(prompt)):
        pool.append_token(1)
    pool.register_page(1, 0, prompt)          # full page [5,6,7,8]
    pool.register_page(1, 1, prompt)          # partial tail [9,10]
    pool.check_invariants()

    pages, n = pool.match_prefix(prompt)
    assert n == 6 and len(pages) == 2
    assert pool.match_prefix([5, 6, 7, 8, 0, 0]) == ([pages[0]], 4)
    assert pool.match_prefix([1, 2, 3]) == ([], 0)

    for lp, p in enumerate(pages):            # seq 2 shares the whole prefix
        pool.share_page(2, lp, p)
    pool.seq_len[2] = 6
    pool.check_invariants()
    assert pool.refcount[pages[0]] == 2 and pool.refcount[pages[1]] == 2
    free_before = pool.free_pages()

    lpage, slot = pool.append_token(2)        # slot 2 of the shared tail
    assert (lpage, slot) == (1, 2)
    cow = pool.drain_cow()
    assert len(cow) == 1
    s, lp, src, dst = cow[0]
    assert (s, lp, src) == (2, 1, pages[1]) and dst != src
    assert pool.refcount[pages[1]] == 1       # seq 1 kept the original
    assert pool.page_table[(2, 1)] == dst
    assert pool.free_pages() == free_before - 1
    pool.check_invariants()

    # in-place append by the sole owner un-registers the mutating page
    pool.append_token(1)
    assert pool.drain_cow() == []             # refcount was 1: no CoW
    assert pool.match_prefix(prompt)[1] == 4  # tail key gone, full page stays
    pool.check_invariants()


def test_pool_cached_free_revival_and_eviction():
    """Released prefix-indexed pages park on the cached-free LRU: a later
    match revives them without data movement; allocation pressure evicts
    them (dropping the index entry) before failing."""
    pool = PagedKVPool(num_pages=2, page_size=2, max_pages_per_seq=4)
    prompt = [3, 4]
    pool.append_token(7)
    pool.append_token(7)
    pool.register_page(7, 0, prompt)
    pool.release(7)
    assert len(pool.free) == 1 and len(pool.cached_free) == 1
    assert pool.free_pages() == 2 and pool.available() == 2
    pool.check_invariants()

    pages, n = pool.match_prefix(prompt)      # revival
    assert n == 2
    pool.share_page(8, 0, pages[0])
    pool.seq_len[8] = 2
    assert not pool.cached_free and pool.refcount[pages[0]] == 1
    pool.check_invariants()
    pool.release(8)
    assert len(pool.cached_free) == 1         # parked again

    # pressure: two fresh allocations must evict the cached page
    pool.append_token(9)
    pool.append_token(9)
    pool.append_token(9)
    assert pool.stats["cache_evictions"] == 1
    assert pool.match_prefix(prompt) == ([], 0)
    pool.check_invariants()
    with pytest.raises(MemoryError):
        pool.append_token(5)


def test_pool_unmap_and_reservation_interplay():
    """unmap_page (the swap-out path) frees private pages while shared
    pages survive through their other reference; reservations still guard
    mid-stream allocation."""
    pool = PagedKVPool(num_pages=6, page_size=2, max_pages_per_seq=4)
    for _ in range(4):
        pool.append_token(1)                  # seq 1: 2 private pages
    pool.register_page(1, 0, [1, 2, 3, 4])
    pool.share_page(2, 0, pool.page_table[(1, 0)])
    pool.seq_len[2] = 2
    pool.check_invariants()

    shared = pool.page_table[(1, 0)]
    pool.unmap_page(1, 1)                     # private: really freed
    assert len(pool.free) == 5
    pool.unmap_page(1, 0)                     # shared: survives via seq 2
    assert pool.refcount[shared] == 1
    assert pool.page_table[(2, 0)] == shared
    pool.check_invariants()

    pool.reserve(3, 4)
    assert pool.available() == 1
    with pytest.raises(MemoryError):
        pool.reserve(4, 2)
    pool.append_token(5)                      # unreserved residue is usable
    pool.append_token(5)
    with pytest.raises(MemoryError):
        pool.append_token(5)                  # would eat seq 3's reservation
    pool.check_invariants()


def test_rab_backed_pool_translation():
    rab = RAB(RABConfig(l1_entries=2, l2_entries=4, l2_assoc=2, l2_banks=1))
    pool = PagedKVPool(num_pages=16, page_size=2, max_pages_per_seq=8,
                       rab=rab)
    for t in range(9):
        pool.append_token(3)
    bt = pool.block_table([3])
    for lp in range(5):
        assert bt[0, lp] == pool.page_table[(3, lp)]
    assert rab.stats["misses"] > 0  # tiny TLB forced the slow path
