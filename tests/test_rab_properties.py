"""RAB property tests (hypothesis): whatever the access pattern, translation
is never stale and the pool never double-maps.  Skipped wholesale when
hypothesis is not installed (see requirements-dev.txt); the deterministic
unit tests in ``test_rab.py`` always run."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.rab import RAB, RABConfig, PagedKVPool  # noqa: E402

CFG = RABConfig(l1_entries=4, l2_entries=16, l2_assoc=4, l2_banks=2)


@settings(deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=120))
def test_translation_always_correct(vpages):
    """Property: whatever the access pattern, a translation that completes
    always returns the page-table value (TLB never returns stale garbage)."""
    rab = RAB(CFG)
    pt = {v: v * 7 + 1 for v in range(31)}
    for i, v in enumerate(vpages):
        p, _ = rab.lookup(v, requester=i % 8)
        if p is None:
            rab.handle_misses(pt)
            p, _ = rab.lookup(v, requester=i % 8)
        assert p == pt[v]


@settings(deadline=None)
@given(st.lists(st.integers(0, 40), min_size=1, max_size=100))
def test_resident_subset_of_page_table(vpages):
    rab = RAB(CFG)
    pt = {v: v + 100 for v in range(41)}
    for i, v in enumerate(vpages):
        if rab.lookup(v, requester=0)[0] is None:
            rab.handle_misses(pt)
    for v, p in rab.resident().items():
        assert pt[v] == p


@settings(deadline=None)
@given(st.lists(st.sampled_from([("tok", 1), ("tok", 2), ("rel", 1),
                                 ("rel", 2)]), max_size=60))
def test_pool_never_double_maps(ops):
    """Property: no physical page is mapped by two (seq, lpage) keys, and
    free + mapped always partitions the pool."""
    pool = PagedKVPool(num_pages=6, page_size=2, max_pages_per_seq=8)
    for op, seq in ops:
        try:
            if op == "tok":
                pool.append_token(seq)
            else:
                pool.release(seq)
        except MemoryError:
            pool.release(seq)
        mapped = list(pool.page_table.values())
        assert len(mapped) == len(set(mapped))
        assert sorted(mapped + pool.free) == list(range(6))
