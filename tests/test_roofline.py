"""Direct unit tests of the analytic roofline library
(``repro.core.roofline``) — previously these byte/FLOP terms lived
inside ``benchmarks/roofline.py`` and were only exercised indirectly
through the artifact-driven table.  Covers:

* ``param_counts`` — total vs MoE-active parameter split;
* ``model_flops`` — the 6ND / 2ND / 2N-per-token convention;
* ``kv_elt_bytes`` — int8 scale amortization per element;
* ``cache_bytes`` — per-family decode-cache models and the int8
  rescaling applying ONLY to paged-KV terms;
* ``analytic_bytes`` — device scaling and kind dispatch;
* ``kv_bytes_per_token`` — byte-identical to the serving engine's
  ``cache_stats().bytes_per_token`` for both dtypes (the term the
  capacity planner prices iterations with);
* the ``benchmarks/roofline.py`` shim still re-exporting the moved
  functions (old import paths keep working).
"""
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core.roofline import (
    KV_PAGE_SIZE, analytic_bytes, cache_bytes, kv_bytes_per_token,
    kv_elt_bytes, model_flops, param_counts,
)


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b").smoke()


def test_param_counts_dense_total_equals_active(cfg):
    pc = param_counts(cfg)
    assert pc["total"] > 0
    assert pc["active"] == pc["total"]     # dense: every weight is live


def test_param_counts_moe_active_below_total():
    moe = get_config("olmoe-1b-7b").smoke()
    pc = param_counts(moe)
    assert pc["active"] < pc["total"]
    # routed expert weights scale by top_k/E; shared weights stay whole
    assert pc["active"] >= pc["total"] * moe.moe_top_k / moe.moe_num_experts


def test_model_flops_conventions(cfg):
    n = param_counts(cfg)["active"]
    train = SHAPES["train_4k"]
    prefill = SHAPES["prefill_32k"]
    decode = SHAPES["decode_32k"]
    assert model_flops(cfg, train) == \
        6.0 * n * train.global_batch * train.seq_len
    assert model_flops(cfg, prefill) == \
        2.0 * n * prefill.global_batch * prefill.seq_len
    assert model_flops(cfg, decode) == 2.0 * n * decode.global_batch


def test_kv_elt_bytes_amortization():
    assert kv_elt_bytes("bf16", hd=64) == 2.0
    # one f32 scale per (page, K/V, head) over hd*page_size elements
    assert kv_elt_bytes("int8", hd=64, page_size=8) == 1.0 + 4.0 / 512
    # smaller pages amortize worse
    assert kv_elt_bytes("int8", hd=64, page_size=4) > \
        kv_elt_bytes("int8", hd=64, page_size=8)


def test_cache_bytes_int8_only_rescales_paged_kv(cfg):
    shape = SHAPES["decode_32k"]
    bf16 = cache_bytes(cfg, shape, "bf16")
    int8 = cache_bytes(cfg, shape, "int8")
    hd = cfg.resolved_head_dim
    assert int8 / bf16 == pytest.approx(
        kv_elt_bytes("int8", hd, KV_PAGE_SIZE) / 2.0)
    # mLSTM state is not a paged pool: dtype must not change it
    mlstm = get_config("xlstm-350m").smoke()
    assert cache_bytes(mlstm, shape, "int8") == \
        cache_bytes(mlstm, shape, "bf16")


def test_analytic_bytes_decode_is_weights_plus_cache(cfg):
    shape = SHAPES["decode_32k"]
    dev = 4
    got = analytic_bytes(cfg, shape, dev, "bf16")
    want = (param_counts(cfg)["total"] * 2.0 +
            cache_bytes(cfg, shape, "bf16")) / dev
    assert got == pytest.approx(want)
    # more devices -> fewer bytes per device
    assert analytic_bytes(cfg, shape, 8) < got


def test_analytic_bytes_train_includes_optimizer_traffic(cfg):
    shape = SHAPES["train_4k"]
    w_only = param_counts(cfg)["total"] * (2.0 * 3 + 4 * 4 + 2.0) / 16
    assert analytic_bytes(cfg, shape, 16) > w_only


def test_kv_bytes_per_token_matches_engine_cache_stats(cfg):
    # the serving engine's measured bytes_per_token for the smoke model
    # at page_size 4 (committed in BENCH_serve.json: 256 bf16, 136 int8)
    assert kv_bytes_per_token(cfg, "bf16", page_size=4) == 256.0
    assert kv_bytes_per_token(cfg, "int8", page_size=4) == 136.0
    # closed form for any page size
    hd = cfg.resolved_head_dim
    ps = 16
    assert kv_bytes_per_token(cfg, "int8", ps) == \
        cfg.num_layers * 2.0 * (cfg.num_kv_heads * hd +
                                4.0 * cfg.num_kv_heads / ps)


def test_kv_bytes_per_token_int8_always_cheaper(cfg):
    for ps in (2, 4, 8, 64):
        assert kv_bytes_per_token(cfg, "int8", ps) < \
            kv_bytes_per_token(cfg, "bf16", ps)


def test_shim_reexports_library():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    try:
        import roofline as shim
    finally:
        sys.path.pop(0)
    assert shim.param_counts is param_counts
    assert shim.model_flops is model_flops
    assert shim.cache_bytes is cache_bytes
    assert shim._kv_elt_bytes is kv_elt_bytes   # pre-refactor alias
    assert shim.KV_PAGE_SIZE == KV_PAGE_SIZE


def test_costs_are_finite_for_all_archs():
    shape = SHAPES["decode_32k"]
    for arch in ("yi-6b", "olmoe-1b-7b", "deepseek-v2-236b", "xlstm-350m",
                 "hymba-1.5b", "gemma2-2b", "whisper-medium"):
        c = get_config(arch).smoke()
        for kv in ("bf16", "int8"):
            assert math.isfinite(cache_bytes(c, shape, kv))
            assert math.isfinite(analytic_bytes(c, shape, 8, kv))
        assert math.isfinite(model_flops(c, shape))
