"""The unified generation API: on-device sampling determinism (same seed
=> identical streams across the kernel/ref attention paths, page sizes
and the 1-cluster sharded engine), temperature-0 greedy byte-parity,
top-k/top-p semantics, finish reasons (stop / length / aborted),
streaming deltas whose concatenation equals the final results, and the
``make_engine`` factory + ``EngineConfig``/``SamplingParams``
validation."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.runtime import (
    CacheConfig, EngineConfig, GenerationRequest, GenerationResult,
    PagedServer, SamplingParams, ShardedPagedServer, TokenDelta,
    make_engine,
)

MAX_NEW = 8


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b").smoke()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(vocab, n=3, seed=2):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=ln).tolist()
            for ln in rng.integers(3, 11, size=n)]


def _serve(cfg, params, prompts, sampling_for, *, page_size=4,
           use_kernel=False, sharded=False, chunk=4, **kw):
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=page_size,
                          max_pages_per_seq=8),
        max_lanes=2, chunk=chunk, use_kernel=use_kernel, sharded=sharded,
        **kw))
    for rid, p in enumerate(prompts):
        srv.submit(GenerationRequest(rid=rid, prompt=tuple(p),
                                     sampling=sampling_for(rid)))
    done = srv.run()
    assert len(done) == len(prompts)
    return {r.rid: r.tokens for r in done}, srv


def _sampled(rid, seed_base=40, temperature=0.8, top_p=0.9, **kw):
    return SamplingParams(temperature=temperature, top_p=top_p,
                          seed=seed_base + rid, max_new=MAX_NEW, **kw)


# ------------------------------------------------------------ determinism --

@pytest.mark.parametrize("page_size", [4, 8])
def test_same_seed_identical_across_kernel_ref_and_sharded(cfg, params,
                                                           page_size):
    """Same seed => identical sampled streams on the ref path, the Pallas
    kernel path, and the 1-cluster sharded engine: the PRNG key folds by
    (seed, position) only, so neither the attention implementation nor
    the mesh may perturb a request's stream."""
    prompts = _prompts(cfg.vocab_size)
    ref, _ = _serve(cfg, params, prompts, _sampled, page_size=page_size)
    ref2, _ = _serve(cfg, params, prompts, _sampled, page_size=page_size)
    assert ref == ref2, "sampled decoding not reproducible"
    kern, _ = _serve(cfg, params, prompts, _sampled, page_size=page_size,
                     use_kernel=True)
    assert kern == ref, "kernel path diverged from ref under sampling"
    shard, srv = _serve(cfg, params, prompts, _sampled, page_size=page_size,
                        sharded=True, clusters=1, heads=1)
    assert isinstance(srv, ShardedPagedServer)
    assert shard == ref, "1-cluster sharded engine diverged under sampling"


def test_different_seed_changes_stream(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=2)
    a, _ = _serve(cfg, params, prompts, _sampled)
    b, _ = _serve(cfg, params, prompts,
                  lambda rid: _sampled(rid, seed_base=900))
    assert a != b, "12+ sampled tokens identical across different seeds"


def test_sampling_independent_of_chunk_size(cfg, params):
    """The fold position is the token's absolute sequence position, so
    chunked-prefill granularity must not change sampled streams (the
    sampling analogue of the greedy chunk-parity test)."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, _sampled, chunk=1)
    for chunk in (3, 16):
        out, _ = _serve(cfg, params, prompts, _sampled, chunk=chunk)
        assert out == base, chunk


# ---------------------------------------------------------- greedy parity --

def test_temperature_zero_is_greedy_regardless_of_seed(cfg, params):
    """temperature=0 must ride the exact argmax path the engine always
    had: the seed (and top-k/top-p) must be inert, and the default
    SamplingParams() must match — byte-identical greedy."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts,
                     lambda rid: SamplingParams(max_new=MAX_NEW))
    for seed in (0, 7, 123456789):
        out, _ = _serve(cfg, params, prompts,
                        lambda rid: SamplingParams(temperature=0.0,
                                                   seed=seed, top_p=0.5,
                                                   top_k=3,
                                                   max_new=MAX_NEW))
        assert out == base, f"temperature=0 not greedy (seed={seed})"


def test_temperature_zero_greedy_with_speculation_active(cfg, params):
    """Acceptance criterion: temperature=0 output is byte-identical to
    the pre-redesign greedy decode with speculation still engaged."""
    rng = np.random.default_rng(5)
    pat = rng.integers(1, cfg.vocab_size, size=4).tolist()
    prompts = [pat * 3, pat * 3]        # repetitive: the drafter accepts
    base, _ = _serve(cfg, params, prompts,
                     lambda rid: SamplingParams(max_new=12))
    out, srv = _serve(cfg, params, prompts,
                      lambda rid: SamplingParams(temperature=0.0, seed=3,
                                                 max_new=12), spec_k=4)
    assert out == base
    assert srv.spec_accepted > 0, "speculation never engaged"


def test_top_k_one_is_greedy_at_any_temperature(cfg, params):
    """top_k=1 collapses the candidate set to the argmax token, so even a
    hot temperature must reproduce the greedy stream — exercises the
    truncation masks end-to-end."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts,
                     lambda rid: SamplingParams(max_new=MAX_NEW))
    out, _ = _serve(cfg, params, prompts,
                    lambda rid: SamplingParams(temperature=2.0, top_k=1,
                                               seed=rid, max_new=MAX_NEW))
    assert out == base


# --------------------------------------------------------- finish reasons --

def test_finish_reason_length_and_stop(cfg, params):
    prompts = _prompts(cfg.vocab_size, n=1)
    base, srv = _serve(cfg, params, prompts,
                       lambda rid: SamplingParams(max_new=MAX_NEW))
    assert srv.finished[0].finish_reason == "length"
    toks = base[0]
    # stop on the token whose FIRST occurrence is latest, so the expected
    # truncation point is well-defined for any stream shape
    first_occ = {t: toks.index(t) for t in toks}
    stop_tok = max(first_occ, key=lambda t: first_occ[t])
    cut = first_occ[stop_tok]
    out, srv = _serve(
        cfg, params, prompts,
        lambda rid: SamplingParams(max_new=MAX_NEW,
                                   stop_tokens=(stop_tok,)))
    r = srv.finished[0]
    assert r.finish_reason == "stop"
    assert r.tokens == toks[:cut + 1]   # stop token included, then cut
    assert srv.pool.free_pages() == 32  # early exit released everything


def test_stop_token_on_first_generated_token(cfg, params):
    """The very first sampled token being a stop token is the edge case:
    one token out, reason 'stop'."""
    prompts = _prompts(cfg.vocab_size, n=1)
    base, _ = _serve(cfg, params, prompts,
                     lambda rid: SamplingParams(max_new=MAX_NEW))
    first = base[0][0]
    out, srv = _serve(cfg, params, prompts,
                      lambda rid: SamplingParams(max_new=MAX_NEW,
                                                 stop_tokens=(first,)))
    assert out[0] == (first,)
    assert srv.finished[0].finish_reason == "stop"


def test_generate_max_iters_streams_abort_deltas(cfg, params):
    """The streaming front-end surfaces the iteration-cap abort: every
    pending request yields an 'abort' delta and a finished result with
    finish_reason='aborted' (the run(max_iters) regression, observed
    through generate())."""
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=4, max_pages_per_seq=8),
        max_lanes=2, chunk=4, use_kernel=False))
    reqs = [GenerationRequest(rid=rid, prompt=(rid + 1, 2, 3, 4),
                              sampling=SamplingParams(max_new=8))
            for rid in range(4)]
    deltas = list(srv.generate(reqs, max_iters=2))
    aborted = {d.rid for d in deltas if d.event == "abort"}
    assert aborted == {0, 1, 2, 3}
    assert {r.rid: r.finish_reason for r in srv.finished} == \
        {rid: "aborted" for rid in range(4)}
    assert srv.pool.free_pages() == 32 and len(srv.backing) == 0


# -------------------------------------------------------------- streaming --

def test_stream_concatenation_equals_results(cfg, params):
    """Acceptance criterion: for every request — greedy, sampled, and
    preempted mid-flight — the concatenation of its token deltas equals
    the final GenerationResult tokens, and scheduler events (prefix hits,
    preemptions) surface as token-free deltas."""
    sys_p = [9, 9, 8, 2, 5, 5, 1, 3]
    prompts = [sys_p + [20 + i] for i in range(4)] + [[4, 2] * 6]

    def sampling_for(rid):
        if rid == 1:
            return SamplingParams(temperature=0.7, top_p=0.9, seed=5,
                                  max_new=5)
        return SamplingParams(max_new=5)

    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=16, page_size=4, max_pages_per_seq=8),
        max_lanes=2, chunk=4, use_kernel=False))
    reqs = [GenerationRequest(rid=rid, prompt=tuple(p),
                              sampling=sampling_for(rid),
                              priority=5 if rid == 4 else 0)
            for rid, p in enumerate(prompts)]
    streamed: dict = {}
    events: list = []
    for d in srv.generate(reqs):
        assert isinstance(d, TokenDelta)
        streamed.setdefault(d.rid, []).extend(d.tokens)
        if d.event != "token":
            events.append(d.event)
            if d.event in ("prefix_hit", "preempt"):
                assert d.tokens == ()   # scheduler events carry no tokens
    final = {r.rid: list(r.tokens) for r in srv.finished}
    assert streamed == final
    assert "prefix_hit" in events, "shared prompts never hit the cache"
    assert all(isinstance(r, GenerationResult) for r in srv.finished)


def test_preempt_between_iterations_surfaces_in_stream(cfg, params):
    """Regression: events recorded BETWEEN engine iterations — a caller
    invoking preempt() from the generate-loop body — must still reach the
    stream (step() used to clear the delta buffer on entry, silently
    dropping them), and the delta/result token contract must survive the
    preemption round-trip."""
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=4, max_pages_per_seq=8),
        max_lanes=2, chunk=8, use_kernel=False))
    reqs = [GenerationRequest(rid=rid, prompt=(rid + 1, 2, 3, 4, 5),
                              sampling=SamplingParams(max_new=6))
            for rid in range(2)]
    streamed: dict = {}
    events = []
    preempted = False
    for i, d in enumerate(srv.generate(reqs)):
        streamed.setdefault(d.rid, []).extend(d.tokens)
        events.append(d.event)
        if i == 2 and not preempted:
            preempted = srv.preempt(0)      # from the loop body
            assert preempted
    assert "preempt" in events, "between-iteration preempt delta was lost"
    assert streamed == {r.rid: list(r.tokens) for r in srv.finished}
    assert srv.preemptions >= 1


def test_stream_spec_deltas_concatenate(cfg, params):
    """Speculative iterations emit multi-token 'spec' deltas; their
    concatenation must still equal the final stream."""
    rng = np.random.default_rng(9)
    pat = rng.integers(1, cfg.vocab_size, size=4).tolist()
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=4, max_pages_per_seq=8),
        max_lanes=2, chunk=8, use_kernel=False, spec_k=4))
    reqs = [GenerationRequest(rid=0, prompt=tuple(pat * 3),
                              sampling=SamplingParams(max_new=10))]
    streamed: list = []
    saw_spec = False
    for d in srv.generate(reqs):
        streamed.extend(d.tokens)
        saw_spec |= (d.event == "spec" and len(d.tokens) > 1)
    assert tuple(streamed) == srv.finished[0].tokens
    assert saw_spec, "no multi-token speculative delta observed"


# ---------------------------------------------------------------- factory --

def test_make_engine_selects_engine_class(cfg, params):
    ec = EngineConfig(cache=CacheConfig(num_pages=8, page_size=4,
                                        max_pages_per_seq=4),
                      max_lanes=1, use_kernel=False)
    assert type(make_engine(cfg, params, ec)) is PagedServer
    assert isinstance(
        make_engine(cfg, params, dataclasses.replace(ec, sharded=True)),
        ShardedPagedServer)
    assert make_engine(cfg, params, ec).engine_cfg == ec


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(max_new=0)
    sp = SamplingParams(stop_tokens=[1, 2])
    assert sp.stop_tokens == (1, 2) and sp.greedy


def test_generation_request_is_frozen(cfg, params):
    req = GenerationRequest(rid=0, prompt=[1, 2, 3])
    assert req.prompt == (1, 2, 3)      # normalized to a tuple
    with pytest.raises(Exception):
        req.prompt = (9,)
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=8, page_size=4, max_pages_per_seq=4),
        max_lanes=1, use_kernel=False))
    srv.submit(GenerationRequest(rid=0, prompt=(1, 2, 3),
                                 sampling=SamplingParams(max_new=2)))
    srv.run()
    assert req.prompt == (1, 2, 3)      # engine never mutates the request


def test_submit_validation_errors(cfg, params):
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=4, page_size=4, max_pages_per_seq=4),
        max_lanes=1, use_kernel=False))
    with pytest.raises(ValueError):
        srv.submit(GenerationRequest(rid=0, prompt=()))
    with pytest.raises(ValueError):     # 4 pages * 4 slots < 13 + 8 - 1
        srv.submit(GenerationRequest(rid=1, prompt=tuple(range(1, 14)),
                                     sampling=SamplingParams(max_new=8)))
