"""Sharded serving engine: 1-cluster parity with the unsharded engine
(token-for-token, across page sizes), cluster dispatch tracing/balance,
GQA head-shard validation, and — in a subprocess with forced virtual
devices — multi-cluster + head-sharded parity with cluster-local pool
invariants checked every step.  All runs go through the unified
generation API (``EngineConfig`` + ``make_engine``)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import get_config
from repro.core.analysis import layer1_decode, layer2_cluster_balance
from repro.core.tracing import EventType, TraceBuffer
from repro.kernels.paged_attention.ops import validate_head_sharding
from repro.models import model as M
from repro.runtime import (
    CacheConfig, EngineConfig, GenerationRequest, SamplingParams,
    ShardedPagedServer, make_engine,
)

PROMPTS = [[5, 6, 7, 8, 9, 10, 11], [3, 1, 4, 1, 5], [2, 7], [9, 9, 8]]


def _req(rid, prompt, max_new=4, **sampling):
    return GenerationRequest(rid=rid, prompt=tuple(prompt),
                             sampling=SamplingParams(max_new=max_new,
                                                     **sampling))


def _run(cfg, params, *, page_size, use_kernel, kv_dtype="bf16",
         tracer=None, sharded=False, **kw):
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=page_size,
                          max_pages_per_seq=8, kv_dtype=kv_dtype),
        max_lanes=2, chunk=4, use_kernel=use_kernel, sharded=sharded,
        **kw),
        tracer=tracer)
    for rid, p in enumerate(PROMPTS):
        srv.submit(_req(rid, p, max_new=4))
    done = srv.run()
    assert len(done) == len(PROMPTS)
    return {r.rid: r.tokens for r in done}, srv


@pytest.mark.parametrize("page_size", [4, 8])
def test_one_cluster_parity_with_unsharded_engine(page_size,
                                                  matrix_use_kernel,
                                                  matrix_kv_dtype):
    """The 1-cluster sharded engine must be token-for-token identical to
    the unsharded engine — same scheduling, same kernels, the mesh
    collapsed to a single device."""
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    base, _ = _run(cfg, params, page_size=page_size,
                   use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype)
    shard, srv = _run(cfg, params, page_size=page_size,
                      use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype,
                      sharded=True, clusters=1, heads=1)
    assert isinstance(srv, ShardedPagedServer)
    assert shard == base
    srv.cpool.check_invariants()
    assert srv.pool.free_pages() == 32


def test_matrix_engine_combination(matrix_page_size, matrix_use_kernel,
                                   matrix_kv_dtype):
    """The CI matrix's (page size, attention path, KV dtype) cell,
    exercised on the unsharded engine's hot path: chunked admission must
    match token-by-token admission exactly in this configuration."""
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def run(chunk):
        srv = make_engine(cfg, params, EngineConfig(
            cache=CacheConfig(num_pages=32, page_size=matrix_page_size,
                              max_pages_per_seq=8,
                              kv_dtype=matrix_kv_dtype),
            max_lanes=2, chunk=chunk, use_kernel=matrix_use_kernel))
        for rid, p in enumerate(PROMPTS):
            srv.submit(_req(rid, p, max_new=3))
        return {r.rid: r.tokens for r in srv.run()}

    assert run(1) == run(4)


def test_cluster_dispatch_tracing_and_balance(matrix_page_size,
                                              matrix_use_kernel):
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tracer = TraceBuffer(capacity=1 << 14)
    out, srv = _run(cfg, params, page_size=matrix_page_size,
                    use_kernel=matrix_use_kernel, tracer=tracer,
                    sharded=True, clusters=1)
    events = layer1_decode(tracer.drain())
    kinds = [e.etype for e in events]
    assert kinds.count(EventType.CLUSTER_DISPATCH) == len(PROMPTS)
    assert EventType.ALL_GATHER in kinds
    bal = layer2_cluster_balance(events)
    assert bal["clusters"][0]["dispatches"] == len(PROMPTS)
    assert sorted(bal["clusters"][0]["requests"]) == [0, 1, 2, 3]
    assert bal["all_gathers"] == srv.iterations
    assert bal["balance"] == 1.0
    rep = srv.cluster_report()
    assert rep["clusters"] == 1 and rep["peak_pages_per_cluster"][0] > 0


def test_validate_head_sharding_gqa():
    assert validate_head_sharding(8, 4, 2) == 2
    assert validate_head_sharding(8, 4, 4) == 1
    assert validate_head_sharding(4, 2, 1) == 2
    with pytest.raises(ValueError):
        validate_head_sharding(8, 4, 3)     # splits a GQA group
    with pytest.raises(ValueError):
        validate_head_sharding(8, 4, 8)     # more shards than kv heads
    with pytest.raises(ValueError):
        validate_head_sharding(7, 4, 1)     # H not a multiple of Kv


def test_head_axis_must_divide_kv_heads():
    cfg = get_config("yi-6b").smoke()       # Kv = 2
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ShardedPagedServer(cfg, params, EngineConfig(
            clusters=1, heads=max(3, len(jax.devices())),
            cache=CacheConfig(num_pages=8, page_size=4,
                              max_pages_per_seq=4),
            max_lanes=1))


_MULTI_CLUSTER_SCRIPT = textwrap.dedent("""
    import os
    import jax
    jax.config.update("jax_platform_name", "cpu")
    assert len(jax.devices()) >= 8, jax.devices()
    from repro.configs import get_config
    from repro.models import model as M
    from repro.runtime import (CacheConfig, EngineConfig,
                               GenerationRequest, SamplingParams,
                               make_engine)

    KV_DTYPE = os.environ.get("REPRO_KV_DTYPE", "bf16")
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[5, 6, 7, 8, 9, 10, 11], [3, 1, 4, 1, 5], [2, 7], [9, 9, 8]]

    def run(preempt=False, sampled_rid=None, **kw):
        srv = make_engine(cfg, params, EngineConfig(
            cache=CacheConfig(num_pages=16, page_size=4,
                              max_pages_per_seq=8, kv_dtype=KV_DTYPE),
            max_lanes=2, chunk=4, use_kernel=False, **kw))
        for rid, p in enumerate(prompts):
            sp = SamplingParams(max_new=3) if rid != sampled_rid else \\
                SamplingParams(max_new=3, temperature=0.8, seed=13)
            srv.submit(GenerationRequest(rid=rid, prompt=tuple(p),
                                         sampling=sp))
        if preempt:
            srv.step()
            assert srv.preempt(0)      # forced mid-flight preemption
        it = 0
        while srv.step():
            it += 1
            assert it < 300
            if hasattr(srv, "cpool"):
                srv.cpool.check_invariants()
        return {r.rid: r.tokens for r in srv.finished}, srv

    base, _ = run()
    for C, H in [(2, 1), (4, 1), (2, 2)]:
        out, srv = run(sharded=True, clusters=C, heads=H)
        assert out == base, (C, H)
        used = {r.cluster for r in srv.finished}
        assert len(used) > 1, "workload never spread across clusters"
    out, srv = run(preempt=True, sharded=True, clusters=2)
    assert out == base and srv.preemptions >= 1
    # speculative decoding under shard_map: same token stream, fewer or
    # equal engine iterations, cluster invariants intact every step
    out, srv = run(sharded=True, clusters=2, spec_k=4)
    assert out == base, "2-cluster speculative run diverged"
    assert srv.spec_proposed >= srv.spec_accepted >= 0
    # a sampled lane on a 2-cluster mesh: greedy lanes unchanged, and the
    # sampled stream matches the unsharded engine (position-folded keys
    # never see the mesh)
    sbase, _ = run(sampled_rid=1)
    sout, _ = run(sampled_rid=1, sharded=True, clusters=2)
    assert sout == sbase, "sampled lane diverged across the mesh"
    assert all(sout[r] == base[r] for r in (0, 2, 3)), \\
        "a greedy lane changed because another lane sampled"
    print("MULTI_CLUSTER_OK")
""")


def test_multi_cluster_parity_subprocess():
    """2- and 4-cluster (and 2x2 head-sharded) engines match the unsharded
    engine token-for-token, including across a forced preemption and with
    a sampled lane in the mix — run in a subprocess because the virtual
    device count must be fixed before the first jax import."""
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", _MULTI_CLUSTER_SCRIPT],
                       capture_output=True, text=True, env=env, cwd=".",
                       timeout=900)
    assert "MULTI_CLUSTER_OK" in r.stdout, r.stdout + r.stderr
