"""Speculative decoding on the paged engine: greedy parity (spec-on output
must be token-for-token identical to spec-off) across page sizes, kernel
and ref attention paths, under forced mid-decode preemption, and on the
1-cluster sharded engine; drafter unit behavior; adaptive draft depth;
the queue-pressure throttle; the greedy-lane-only drafting restriction
under the sampling API; rollback/trim pool hygiene; and event-stream
conservation (proposed == accepted + rolled back)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.analysis import (
    assert_spec_conserves, layer1_decode, layer2_speculation,
)
from repro.core.rab import PagedKVPool
from repro.core.tracing import EventType, TraceBuffer
from repro.models import model as M
from repro.runtime import (
    CacheConfig, DraftModelDrafter, EngineConfig, GenerationRequest,
    NGramDrafter, SamplingParams, make_engine,
)

MAX_NEW = 16


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b").smoke()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(vocab, seed=0):
    """Two repetitive prompts (the drafter's bread) + two random ones."""
    rng = np.random.default_rng(seed)
    pat = rng.integers(1, vocab, size=4).tolist()
    return [pat * 3, rng.integers(1, vocab, size=12).tolist(),
            [5, 6, 7], rng.integers(1, vocab, size=9).tolist()]


def _serve(cfg, params, prompts, *, spec_k, page_size=4, use_kernel=False,
           kv_dtype="bf16", max_lanes=2, max_new=MAX_NEW, preempt_rid=None,
           tracer=None, sampling_for=None, **kw):
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=64, page_size=page_size,
                          max_pages_per_seq=16, kv_dtype=kv_dtype),
        max_lanes=max_lanes, chunk=8, use_kernel=use_kernel,
        spec_k=spec_k, **kw), tracer=tracer)
    for rid, p in enumerate(prompts):
        sp = sampling_for(rid) if sampling_for is not None else \
            SamplingParams(max_new=max_new)
        srv.submit(GenerationRequest(rid=rid, prompt=tuple(p), sampling=sp))
    if preempt_rid is not None:
        for _ in range(6):          # into mid-decode before preempting
            srv.step()
        assert srv.preempt(preempt_rid)
    done = srv.run()
    assert len(done) == len(prompts)
    return {r.rid: r.tokens for r in done}, srv


# --------------------------------------------------------------- drafters --

def test_ngram_drafter_matches_cycle():
    d = NGramDrafter(max_n=3)
    # ... 7 8 9 7 8 9 — the trigram (7,8,9) recurs; continuation is 7 8 ...
    assert d.propose([1, 7, 8, 9, 7, 8, 9], 2) == [7, 8]
    # a run extends by the longest continuation any occurrence supports
    assert d.propose([3, 5, 5, 5], 3) == [5]
    assert d.propose([3, 5, 5, 5, 5, 5], 3) == [5, 5]
    assert d.propose([3, 5, 5, 5, 5, 5, 5, 5], 3) == [5, 5, 5]


def test_ngram_drafter_prefers_longest_match():
    d = NGramDrafter(max_n=3)
    # suffix (2, 3): trigram (1, 2, 3) recurs at position 0 -> continuation
    # 9; the shorter bigram match at position 4 (-> 7) must not win
    assert d.propose([1, 2, 3, 9, 2, 3, 4, 1, 2, 3], 1) == [9]


def test_ngram_drafter_no_match_or_no_continuation():
    d = NGramDrafter()
    assert d.propose([1, 2, 3, 4, 5], 4) == []      # no repeated suffix
    assert d.propose([], 4) == []
    assert d.propose([1], 4) == []
    assert d.propose([1, 2, 3], 0) == []            # k=0 never proposes


def test_ngram_drafter_caps_at_k():
    d = NGramDrafter(max_n=2)
    out = d.propose([4, 4, 4, 4, 4, 4, 4, 4], 3)
    assert len(out) <= 3 and set(out) == {4}


def test_draft_model_drafter_vocab_check(cfg, params):
    with pytest.raises(ValueError):
        DraftModelDrafter(cfg, params, target_vocab=cfg.vocab_size + 1)


def test_draft_model_drafter_self_draft_fully_accepted(cfg, params):
    """Drafting with the target model itself must be accepted wholesale
    (the verify step recomputes exactly the drafter's greedy argmax), so
    every engine iteration advances spec_k + 1 tokens."""
    drafter = DraftModelDrafter(cfg, params, target_vocab=cfg.vocab_size)
    prompts = [_prompts(cfg.vocab_size)[1]]
    base, _ = _serve(cfg, params, prompts, spec_k=0, max_lanes=1, max_new=8)
    out, srv = _serve(cfg, params, prompts, spec_k=2, max_lanes=1,
                      max_new=8, drafter=drafter)
    assert out == base
    assert srv.spec_rejected == 0 and srv.spec_accepted > 0


# ----------------------------------------------------------------- parity --

@pytest.mark.parametrize("page_size", [4, 8])
def test_spec_parity_across_page_sizes(cfg, params, page_size,
                                       matrix_use_kernel, matrix_kv_dtype):
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, spec_k=0, page_size=page_size,
                     use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype)
    out, srv = _serve(cfg, params, prompts, spec_k=4, page_size=page_size,
                      use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype)
    assert out == base
    assert srv.spec_accepted > 0, "workload never accepted a draft"
    srv.pool.check_invariants()
    assert srv.pool.free_pages() == 64


def test_spec_parity_under_preemption(cfg, params, matrix_page_size,
                                      matrix_use_kernel, matrix_kv_dtype):
    """Forced mid-decode preemption with speculation on: the victim swaps
    out (possibly with just-verified pages), resumes, and still emits the
    exact spec-off token stream."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, spec_k=0,
                     page_size=matrix_page_size,
                     use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype)
    out, srv = _serve(cfg, params, prompts, spec_k=4,
                      page_size=matrix_page_size,
                      use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype,
                      preempt_rid=0)
    assert out == base
    assert srv.preemptions >= 1
    srv.pool.check_invariants()


def test_spec_parity_sharded_one_cluster(cfg, params, matrix_page_size,
                                         matrix_use_kernel, matrix_kv_dtype):
    """The sharded engine runs the same verify step as a shard_map body;
    at 1 cluster it must be token-for-token identical to both the
    unsharded spec-on engine and the plain spec-off stream."""
    prompts = _prompts(cfg.vocab_size)
    base, _ = _serve(cfg, params, prompts, spec_k=0,
                     page_size=matrix_page_size,
                     use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype)
    out, srv = _serve(cfg, params, prompts, spec_k=4,
                      page_size=matrix_page_size,
                      use_kernel=matrix_use_kernel, kv_dtype=matrix_kv_dtype,
                      sharded=True, clusters=1, heads=1)
    assert out == base
    assert srv.spec_accepted > 0
    srv.cpool.check_invariants()


# ------------------------------------------------- scheduler interactions --

class _WrongDrafter:
    """Always proposes k in-vocab tokens the target will reject (the
    verify step's greedy argmax never emits token ids it was fed as
    off-by-one garbage against the model's actual continuation)."""

    def __init__(self, bad=1):
        self.bad = bad
        self.calls = 0

    def propose(self, ctx, k):
        self.calls += 1
        # always wrong: the previous greedy token xor'd to a different id
        return [(ctx[-1] ^ self.bad) & 0xFF or 1] * k


def test_all_rejected_still_parity_and_adaptive_shrink(cfg, params):
    prompts = [_prompts(cfg.vocab_size)[1]]
    base, _ = _serve(cfg, params, prompts, spec_k=0, max_lanes=1)
    drafter = _WrongDrafter()
    out, srv = _serve(cfg, params, prompts, spec_k=4, max_lanes=1,
                      drafter=drafter)
    assert out == base                  # rejected drafts never leak tokens
    assert srv.spec_accepted == 0
    assert srv.spec_rejected == srv.spec_proposed > 0
    # zero acceptance halves the lane's draft depth down to 1
    assert srv.finished[0].spec_k_final == 1
    srv.pool.check_invariants()
    assert srv.pool.free_pages() == 64  # every rolled-back page went home


def test_adaptive_depth_grows_on_full_acceptance(cfg, params):
    drafter = DraftModelDrafter(cfg, params)      # always fully accepted
    prompts = [_prompts(cfg.vocab_size)[1]]
    _, srv = _serve(cfg, params, prompts, spec_k=3, max_lanes=1,
                    max_new=12, drafter=drafter)
    r = srv.finished[0]
    assert r.spec_k_final == 3 and r.spec_rejected == 0
    assert r.spec_accepted > 0


def test_drafting_throttled_while_queue_waits(cfg, params):
    """One lane, two requests: while request 1 waits in the queue
    (preemption pressure), request 0 must decode WITHOUT drafting; once
    the queue drains, request 1 speculates freely."""
    rng = np.random.default_rng(1)
    pat = rng.integers(1, cfg.vocab_size, size=3).tolist()
    prompts = [pat * 4, pat * 4]
    out, srv = _serve(cfg, params, prompts, spec_k=4, max_lanes=1)
    r0 = next(r for r in srv.finished if r.rid == 0)
    r1 = next(r for r in srv.finished if r.rid == 1)
    assert r0.spec_proposed == 0, "drafted while the queue was non-empty"
    assert r1.spec_proposed > 0, "never drafted after the queue drained"
    base, _ = _serve(cfg, params, prompts, spec_k=0, max_lanes=1)
    assert out == base


def test_sampled_lanes_never_draft_but_ride_along(cfg, params):
    """The greedy-lane-only restriction: with a sampled request sharing
    the batch, greedy lanes keep drafting (their stream unchanged from
    spec-off) and the sampled lane advances by exactly its plain-decode
    sampled stream — the verify step's bonus-token sampler is
    position-folded just like the decode step's."""
    prompts = _prompts(cfg.vocab_size)

    def sampling_for(rid):
        if rid == 1:
            return SamplingParams(temperature=0.8, seed=21, max_new=MAX_NEW)
        return SamplingParams(max_new=MAX_NEW)

    base, _ = _serve(cfg, params, prompts, spec_k=0,
                     sampling_for=sampling_for)
    out, srv = _serve(cfg, params, prompts, spec_k=4,
                      sampling_for=sampling_for)
    assert out == base
    sampled = next(r for r in srv.finished if r.rid == 1)
    assert sampled.spec_proposed == 0, "a sampled lane proposed drafts"
    assert srv.spec_accepted > 0, "greedy lanes stopped drafting"
    srv.pool.check_invariants()


def test_spec_events_conserve_and_match_counters(cfg, params):
    tracer = TraceBuffer(capacity=1 << 14)
    prompts = _prompts(cfg.vocab_size)
    _, srv = _serve(cfg, params, prompts, spec_k=4, tracer=tracer)
    events = layer1_decode(tracer.drain())
    assert assert_spec_conserves(events)
    sp = layer2_speculation(events)
    assert sp["proposed"] == srv.spec_proposed
    assert sp["accepted"] == srv.spec_accepted
    assert sp["wasted_verify_tokens"] == srv.spec_rejected
    assert 0.0 <= sp["acceptance_rate"] <= 1.0
    kinds = [e.etype for e in events]
    assert EventType.SPEC_PROPOSE in kinds
    assert EventType.SPEC_ACCEPT in kinds


def test_spec_respects_max_new_budget(cfg, params):
    """accepted + 1 can never overshoot max_new: the per-lane draft cap is
    remaining - 1, so the last token of every request is engine-sampled."""
    prompts = _prompts(cfg.vocab_size)
    for max_new in (1, 2, 5):
        out, srv = _serve(cfg, params, prompts, spec_k=4, max_new=max_new)
        assert all(len(o) == max_new for o in out.values())
        assert all(r.finish_reason == "length" for r in srv.finished)
        srv.pool.check_invariants()


def test_spec_stop_token_truncates_verified_run(cfg, params):
    """A stop token emitted inside an accepted draft run must end the
    request there: later accepted drafts are discarded from the output
    and the finish_reason is 'stop'."""
    prompts = [_prompts(cfg.vocab_size)[0]]     # repetitive: drafts accept
    base, _ = _serve(cfg, params, prompts, spec_k=4, max_lanes=1)
    tokens = base[0]
    stop_tok = tokens[min(2, len(tokens) - 1)]
    cut = tokens.index(stop_tok) + 1

    def sampling_for(rid):
        return SamplingParams(max_new=MAX_NEW, stop_tokens=(stop_tok,))

    out, srv = _serve(cfg, params, prompts, spec_k=4, max_lanes=1,
                      sampling_for=sampling_for)
    assert out[0] == tokens[:cut]
    assert srv.finished[0].finish_reason == "stop"
    srv.pool.check_invariants()
    assert srv.pool.free_pages() == 64


# ------------------------------------------------------------ pool rollback --

def test_pool_trim_rolls_back_pages_and_credits_reservation():
    pool = PagedKVPool(num_pages=8, page_size=2, max_pages_per_seq=8)
    pool.reserve(0, 4)
    for _ in range(7):                  # 4 pages: 3 full + 1 partial
        pool.append_token(0)
    assert pool.reserved[0] == 0
    pool.check_invariants()
    freed = pool.trim(0, 3)             # keep 2 pages (3 tokens)
    assert freed == 2
    assert pool.seq_len[0] == 3
    assert pool.reserved[0] == 2        # budget restored for re-append
    pool.check_invariants()
    # re-appending after the rollback walks the same reservation
    for _ in range(4):
        pool.append_token(0)
    assert pool.seq_len[0] == 7 and pool.reserved[0] == 0
    pool.check_invariants()
    pool.release(0)
    assert pool.free_pages() == 8


def test_pool_trim_within_page_frees_nothing():
    pool = PagedKVPool(num_pages=4, page_size=4, max_pages_per_seq=4)
    pool.reserve(1, 1)
    for _ in range(3):
        pool.append_token(1)
    assert pool.trim(1, 2) == 0         # same page, no unmap
    assert pool.seq_len[1] == 2
    pool.check_invariants()


def test_pool_trim_to_zero_clears_sequence():
    pool = PagedKVPool(num_pages=4, page_size=2, max_pages_per_seq=4)
    pool.reserve(2, 2)
    for _ in range(3):
        pool.append_token(2)
    assert pool.trim(2, 0) == 2
    assert 2 not in pool.seq_len
    assert pool.reserved[2] == 2
    pool.release(2)
    pool.check_invariants()
    assert pool.free_pages() == 4


def test_pool_trim_shared_page_drops_only_this_mapping():
    """Trimming a page another sequence still shares must only drop this
    sequence's refcount — the sharer keeps the page and its content."""
    pool = PagedKVPool(num_pages=6, page_size=2, max_pages_per_seq=4)
    pool.reserve(0, 2)
    for _ in range(4):
        pool.append_token(0)
    pool.register_page(0, 0, [1, 2, 3, 4])
    pool.register_page(0, 1, [1, 2, 3, 4])
    pool.share_page(7, 0, pool.page_table[(0, 0)])
    pool.share_page(7, 1, pool.page_table[(0, 1)])
    pool.seq_len[7] = 4
    shared = pool.page_table[(7, 1)]
    assert pool.refcount[shared] == 2
    assert pool.trim(7, 2) == 1         # drops (7,1) only
    assert pool.refcount[shared] == 1
    assert pool.page_table[(0, 1)] == shared
    pool.check_invariants()
