"""End-to-end system behaviour: offload semantics, fault-tolerant training,
checkpoint round-trip + elastic resharding, paged serving engine, config
matrix, sharding rules."""
import os
import shutil
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_shape
from repro.core import (
    OffloadTarget, SVMSpace, AddressCollision, ConfigGraph, hero_test_matrix,
    TraceBuffer, EventType,
)
from repro.core.analysis import layer1_decode
from repro.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step, AsyncCheckpointer,
)
from repro.data import MarkovChainData, SyntheticLMData, Prefetcher
from repro.models import model as M
from repro.runtime import Trainer, TrainerConfig, FailureInjector, \
    PagedServer, CacheConfig, EngineConfig, GenerationRequest, \
    SamplingParams, make_engine


def _req(rid, prompt, max_new=8, priority=0, **sampling):
    return GenerationRequest(rid=rid, prompt=tuple(prompt),
                             sampling=SamplingParams(max_new=max_new,
                                                     **sampling),
                             priority=priority)


# ---------------------------------------------------------------------------
# C1: offload semantics
# ---------------------------------------------------------------------------

def test_offload_copy_vs_zero_copy_equivalent():
    tgt = OffloadTarget(tracer=TraceBuffer())
    a = np.random.default_rng(0).standard_normal((64, 64)).astype(np.float32)
    b = np.random.default_rng(1).standard_normal((64, 64)).astype(np.float32)

    def kern(a, b):
        return a @ b

    out_copy, rep_copy = tgt.run_copy_based(kern, a, b)
    ha = tgt.svm.share(jax.device_put(a))
    hb = tgt.svm.share(jax.device_put(b))
    out_h, rep_zc = tgt.run_zero_copy(kern, ha, hb)
    out_zc = np.asarray(tgt.svm.deref(out_h))
    np.testing.assert_allclose(out_copy, out_zc, rtol=1e-6)
    assert rep_copy.mode == "copy" and rep_zc.mode == "zero_copy"
    assert rep_copy.bytes_to > 0 and rep_zc.writeback_s == 0.0
    # the offload event protocol was traced
    events = layer1_decode(tgt.tracer.drain())
    kinds = {e.etype for e in events}
    assert EventType.OFFLOAD_COPY_TO in kinds
    assert EventType.OFFLOAD_KERNEL_BEGIN in kinds


def test_svm_reserved_aperture():
    svm = SVMSpace(reserved=((0, 100),))
    with pytest.raises(AddressCollision):
        svm.share(jnp.ones(3), handle=5)
    h = svm.share(jnp.ones(3))
    assert h >= 100 and h in svm


# ---------------------------------------------------------------------------
# training: fault tolerance + determinism
# ---------------------------------------------------------------------------

def _mk_trainer(tmp, total=8):
    from repro.optim import AdamWConfig
    cfg = get_config("yi-6b").smoke()
    shape = smoke_shape("train")
    data = MarkovChainData(cfg, shape, seed=0)
    # short warmup so loss moves within the test's step budget
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=total)
    return Trainer(cfg, shape, data,
                   TrainerConfig(total_steps=total, ckpt_every=4,
                                 ckpt_dir=tmp, log_every=2), opt_cfg=opt)


def test_trainer_recovers_from_injected_failure():
    tmp = tempfile.mkdtemp()
    try:
        tr = _mk_trainer(tmp)
        res = tr.run_with_recovery(FailureInjector([5]))
        assert res["final_step"] == 8
        assert tr.restarts == 1
        assert latest_step(tmp) == 8
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_trainer_loss_decreases_on_markov_data():
    tmp = tempfile.mkdtemp()
    try:
        tr = _mk_trainer(tmp, total=30)
        res = tr.run()
        losses = [m["loss"] for m in res["metrics"]]
        assert losses[-1] < losses[0] - 0.3, losses
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_data_determinism_and_host_sharding():
    cfg = get_config("yi-6b").smoke()
    shape = smoke_shape("train")
    a = SyntheticLMData(cfg, shape, seed=3).batch(7)
    b = SyntheticLMData(cfg, shape, seed=3).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    h0 = SyntheticLMData(cfg, shape, seed=3, num_hosts=2, host_id=0).batch(7)
    h1 = SyntheticLMData(cfg, shape, seed=3, num_hosts=2, host_id=1).batch(7)
    assert h0["tokens"].shape[0] == shape.global_batch // 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher_delivers_in_order():
    cfg = get_config("yi-6b").smoke()
    data = SyntheticLMData(cfg, smoke_shape("train"), seed=0)
    pf = Prefetcher(data, start_step=0)
    try:
        s0, b0 = next(pf)
        s1, b1 = next(pf)
        assert (s0, s1) == (0, 1)
        np.testing.assert_array_equal(b0["tokens"], data.batch(0)["tokens"])
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# checkpoint: round-trip, atomicity, elastic resharding
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_bf16():
    tmp = tempfile.mkdtemp()
    try:
        state = {"a": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
                 "n": {"b": jnp.arange(6, dtype=jnp.int32)}}
        save_checkpoint(tmp, 3, state)
        out, step = restore_checkpoint(tmp, state)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(state["a"], np.float32))
        assert out["a"].dtype == jnp.bfloat16
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_async_checkpointer_and_latest():
    tmp = tempfile.mkdtemp()
    try:
        ck = AsyncCheckpointer(tmp)
        ck.save(1, {"x": jnp.zeros(3)})
        ck.save(2, {"x": jnp.ones(3)})
        ck.close()
        assert latest_step(tmp) == 2
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile, shutil
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint

tmp = tempfile.mkdtemp()
mesh_a = jax.make_mesh((4,), ("data",))
x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                   NamedSharding(mesh_a, P("data")))
save_checkpoint(tmp, 1, {"x": x})

mesh_b = jax.make_mesh((2, 4), ("data", "model"))
sh = {"x": NamedSharding(mesh_b, P("data", "model"))}
out, step = restore_checkpoint(tmp, {"x": x}, shardings=sh)
assert step == 1
np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
assert out["x"].sharding.spec == P("data", "model")
shutil.rmtree(tmp)
print("ELASTIC_OK")
"""


def test_elastic_reshard_across_meshes():
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=".")
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_paged_server_continuous_batching():
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=4,
                          max_pages_per_seq=8),
        max_lanes=2, use_kernel=False))
    for rid in range(4):
        srv.submit(_req(rid, [rid + 1, 2, 3], max_new=3))
    done = srv.run()
    assert len(done) == 4
    assert all(len(r.tokens) == 3 for r in done)
    assert all(r.finish_reason == "length" for r in done)
    # all pages returned (prefix-indexed ones park on the cached-free list)
    assert srv.pool.free_pages() == 32
    assert srv.rab.stats["l1_hits"] + srv.rab.stats["misses"] > 0


def test_paged_server_legacy_kwargs_removed():
    """The one-PR DeprecationWarning shim is gone: the pre-EngineConfig
    kwargs sprawl now raises TypeError and ``runtime.Request`` no longer
    exists — EngineConfig / GenerationRequest are the only spellings."""
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(TypeError):
        PagedServer(cfg, params, num_pages=32, page_size=4,
                    max_lanes=2, max_pages_per_seq=8, use_kernel=False)
    with pytest.raises(TypeError):
        from repro.runtime import ShardedPagedServer
        ShardedPagedServer(cfg, params, clusters=1, num_pages=32)
    with pytest.raises(ImportError):
        from repro.runtime import Request  # noqa: F401


def test_paged_server_kernel_matches_ref():
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def run(use_kernel):
        srv = make_engine(cfg, params, EngineConfig(
            cache=CacheConfig(num_pages=32, page_size=4,
                              max_pages_per_seq=8),
            max_lanes=2, use_kernel=use_kernel))
        srv.submit(_req(0, [5, 6, 7], max_new=4))
        return srv.run()[0].tokens

    assert run(False) == run(True)


def test_paged_server_chunked_prefill_matches_token_by_token():
    """Chunked admission must not change sampled tokens, only iteration
    count and transfer traffic."""
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[5, 6, 7, 8, 9, 10, 11], [3, 1, 4, 1, 5], [2, 7]]

    def run(chunk):
        srv = make_engine(cfg, params, EngineConfig(
            cache=CacheConfig(num_pages=32, page_size=4,
                              max_pages_per_seq=8),
            max_lanes=2, chunk=chunk, use_kernel=False))
        for rid, p in enumerate(prompts):
            srv.submit(_req(rid, p, max_new=3))
        done = srv.run()
        assert srv.pool.free_pages() == 32
        return {r.rid: r.tokens for r in done}, srv.iterations

    base, base_iters = run(1)
    for chunk in (3, 4, 16):
        outs, iters = run(chunk)
        assert outs == base, chunk
        assert iters < base_iters


def test_run_iteration_cap_aborts_pending_requests():
    """Regression: ``run(max_iters)`` used to exit at the cap silently
    abandoning queued/running requests — they must surface as finished
    results with ``finish_reason='aborted'`` and leave the pool clean."""
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=32, page_size=4,
                          max_pages_per_seq=8),
        max_lanes=2, chunk=4, use_kernel=False))
    for rid in range(4):        # 4 requests, 2 lanes: two stay queued
        srv.submit(_req(rid, [rid + 1, 2, 3, 4, 5], max_new=8))
    done = srv.run(max_iters=3)
    assert len(done) == 4, "requests were dropped at the iteration cap"
    reasons = {r.rid: r.finish_reason for r in done}
    assert all(v == "aborted" for v in reasons.values()), reasons
    # aborted mid-prefill/queued requests release everything they held
    assert srv.pool.free_pages() == 32
    assert len(srv.backing) == 0
    assert not srv.queue and all(x is None for x in srv.lanes)
    # and a fresh submission still serves normally afterwards
    srv.submit(_req(9, [7, 7, 7], max_new=2))
    assert srv.run()[-1].finish_reason == "length"


@pytest.mark.parametrize("page_size", [4, 8])
def test_prefix_cache_parity_and_forced_preemption(page_size):
    """Serving the same prompts with prefix caching on vs off is
    token-for-token identical, and a forced mid-decode preemption (swap
    out to host, swap back in) leaves outputs unchanged."""
    from repro.core.analysis import assert_swaps_balanced

    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sys_p = [11, 12, 13, 14, 15, 16, 17, 18]     # one full page at size 8
    prompts = [sys_p + [21], sys_p + [22], sys_p + [23]]

    def run(enable, preempt_rid=None):
        tracer = TraceBuffer()
        srv = make_engine(cfg, params, EngineConfig(
            cache=CacheConfig(num_pages=32, page_size=page_size,
                              max_pages_per_seq=8,
                              enable_prefix_cache=enable),
            max_lanes=2, chunk=4, use_kernel=False), tracer=tracer)
        srv.submit(_req(0, prompts[0], max_new=4))
        srv.step()
        srv.step()       # rid 0 reaches decode; its prefix pages published
        for rid in (1, 2):
            srv.submit(_req(rid, prompts[rid], max_new=4))
        if preempt_rid is not None:
            srv.step()
            assert srv.preempt(preempt_rid)
        it = 0
        while srv.step():
            srv.pool.check_invariants()
            it += 1
            assert it < 500, "engine did not drain"
        srv.pool.check_invariants()
        assert srv.pool.free_pages() == 32
        return {r.rid: r.tokens for r in srv.finished}, srv, tracer.drain()

    base, _, _ = run(False)
    cached, csrv, _ = run(True)
    assert cached == base
    assert csrv.pool.stats["prefix_hit_tokens"] > 0

    pre, psrv, events = run(True, preempt_rid=0)
    assert pre == base
    assert psrv.preemptions >= 1
    kinds = [int(e[2]) for e in events]
    assert kinds.count(int(EventType.SWAP_OUT)) >= 1
    assert kinds.count(int(EventType.SWAP_IN)) >= 1
    assert assert_swaps_balanced(layer1_decode(events))


def test_prefix_cache_never_starves_admission():
    """When cached-free prefix hits would cost more evictable capacity
    than a plain admission, the scheduler falls back to a no-sharing plan
    instead of queueing the request forever."""
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = make_engine(cfg, params, EngineConfig(
        cache=CacheConfig(num_pages=3, page_size=4,
                          max_pages_per_seq=4),
        max_lanes=2, chunk=8, use_kernel=False))
    srv.submit(_req(0, [1, 2, 3, 4, 5, 6], max_new=1))
    it = 0
    while srv.step():
        it += 1
        assert it < 100
    assert len(srv.pool.cached_free) > 0    # donor parked indexed pages
    srv.submit(_req(1, [1, 2, 3, 4, 5, 6], max_new=3))
    while srv.step():
        srv.pool.check_invariants()
        it += 1
        assert it < 300, "request starved behind its own prefix hits"
    assert len(srv.finished) == 2


def test_priority_preemption_under_pool_pressure():
    """A higher-priority request arriving into an exhausted pool preempts
    the running low-priority lane; both finish with the same outputs as an
    uncontended run."""
    cfg = get_config("yi-6b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def run(num_pages):
        srv = make_engine(cfg, params, EngineConfig(
            cache=CacheConfig(num_pages=num_pages, page_size=4,
                              max_pages_per_seq=8,
                              enable_prefix_cache=False),
            max_lanes=2, chunk=4, use_kernel=False))
        srv.submit(_req(0, [3, 1, 4, 1, 5, 9, 2, 6], max_new=10,
                        priority=0))
        srv.step()
        srv.step()
        srv.submit(_req(1, [2, 7, 1, 8, 2, 8, 1, 8], max_new=10,
                        priority=5))
        it = 0
        while srv.step():
            srv.pool.check_invariants()
            it += 1
            assert it < 500
        return {r.rid: r.tokens for r in srv.finished}, srv

    base, _ = run(32)            # ample pool: no preemption needed
    out, srv = run(8)            # each request needs 5 pages; 8 force a swap
    assert out == base
    assert srv.preemptions >= 1
    assert len(srv.backing) == 0          # everything swapped back in
    assert srv.backing.bytes_out == srv.backing.bytes_in > 0


# ---------------------------------------------------------------------------
# C5: config matrix
# ---------------------------------------------------------------------------

def test_hero_test_matrix_counts():
    g = hero_test_matrix()
    cells = g.cells()
    # 10 archs x 4 shapes x 2 meshes minus long_500k skips (8 archs x 2)
    assert len(cells) == 10 * 4 * 2 - 8 * 2
    assert all(c["kind"] in ("train", "prefill", "decode") for c in cells)


def test_config_graph_constraints():
    g = (ConfigGraph()
         .axis("a", [1, 2, 3])
         .axis("b", ["x", "y"])
         .constraint(lambda c: not (c["a"] == 3 and c["b"] == "y"))
         .annotate(lambda c: {"tag": f"{c['a']}{c['b']}"}))
    cells = g.cells()
    assert len(cells) == 5
    assert {c["tag"] for c in cells} == {"1x", "1y", "2x", "2y", "3x"}


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_logical_pspec_divisibility():
    from repro.parallel.sharding import logical_pspec
    from jax.sharding import PartitionSpec as P
    # single-device mesh: every logical axis drops to replication
    mesh = jax.make_mesh((1,), ("model",))
    assert logical_pspec((25, 64), ("tp", None), mesh) == P()

    sub = subprocess.run(
        [sys.executable, "-c", r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax
from jax.sharding import PartitionSpec as P
from repro.parallel.sharding import logical_pspec
mesh = jax.make_mesh((2, 4), ("data", "model"))
assert logical_pspec((32, 64), ("dp", "tp"), mesh) == P("data", "model")
assert logical_pspec((25, 64), ("tp", None), mesh) == P()
assert logical_pspec((8, 25), ("dp", "tp"), mesh) == P("data")
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
assert logical_pspec((8, 8), ("fsdp", "tp"), mesh3) == P(("pod", "data"), "model")
print("PSPEC_OK")
"""],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=".")
    assert "PSPEC_OK" in sub.stdout, sub.stdout + sub.stderr


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    from repro.optim import AdamWConfig, init_opt_state, adamw_update
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    st = init_opt_state(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, st, _ = adamw_update(params, grads, st, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_int8_error_feedback_bounded():
    from repro.optim.compress import ef_compress_grads, init_residual
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(512).astype(np.float32))}
    resid = init_residual(g)
    acc_true = np.zeros(512, np.float32)
    acc_comp = np.zeros(512, np.float32)
    for _ in range(20):
        d, resid = ef_compress_grads(g, resid)
        acc_true += np.asarray(g["w"])
        acc_comp += np.asarray(d["w"])
    # error feedback keeps the *accumulated* error bounded by one quantum
    quantum = float(jnp.abs(g["w"]).max()) / 127.0
    assert np.abs(acc_true - acc_comp).max() <= 2 * quantum + 1e-5
