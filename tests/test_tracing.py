"""Tracer tests: in-step recording is pure, lossless under capacity,
host/device domains merge, analysis layers decode."""
import jax
import jax.numpy as jnp

from repro.core.tracing import TraceBuffer, EventType, HOST_TRACER_ID
from repro.core.analysis import layer1_decode, layer2_per_core, \
    layer2_tlb_transactions, render_timeline


def test_device_record_inside_jit():
    tb = TraceBuffer(capacity=16)

    @jax.jit
    def step(dev, x):
        dev = TraceBuffer.record(dev, 1, EventType.STEP_BEGIN, 0, 0)
        y = x * 2
        dev = TraceBuffer.tick(dev, 3)
        dev = TraceBuffer.record(dev, 1, EventType.STEP_END, 0, 0)
        return dev, y

    dev = tb.device_init()
    dev, y = step(dev, jnp.ones(4))
    rows = tb.drain(dev)
    assert rows.shape == (2, 5)
    assert rows[0, 2] == EventType.STEP_BEGIN
    assert rows[1, 2] == EventType.STEP_END
    assert rows[1, 0] - rows[0, 0] == 4  # 1 record + 3 ticks


def test_capacity_saturation_counts_drops():
    tb = TraceBuffer(capacity=4)
    dev = tb.device_init()
    for _ in range(7):
        dev = TraceBuffer.record(dev, 2, EventType.MEM_READ, 0, 0)
    rows = tb.drain(dev)
    assert rows.shape[0] == 4
    assert tb.dropped == 3


def test_host_device_merge():
    tb = TraceBuffer(capacity=8)
    dev = tb.device_init()
    dev = TraceBuffer.record(dev, 1, EventType.MEM_WRITE, 5, 6)
    tb.record_host(EventType.OFFLOAD_BEGIN, 1, 2)
    rows = tb.drain(dev)
    tracers = set(rows[:, 1].tolist())
    assert tracers == {1, HOST_TRACER_ID}


def test_analysis_layers():
    tb = TraceBuffer()
    tb.record_host(EventType.TLB_MISS, 0, 7)
    tb.record_host(EventType.TLB_L1_HIT, 1, 3)
    tb.record_host(EventType.MISS_HANDLED, 0, 7)
    tb.record_host(EventType.CORE_WAKE, 0, 7)
    events = layer1_decode(tb.drain())
    per_core = layer2_per_core(events)
    assert set(per_core) == {0, 1}
    txs = layer2_tlb_transactions(events)
    kinds = {t["kind"] for t in txs}
    assert kinds == {"miss", "hit_l1"}
    miss = [t for t in txs if t["kind"] == "miss"][0]
    assert miss["latency"] > 0
    assert "core   0" in render_timeline(events)
